"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_datapath     — Table 4 / Fig 2-3 (timing exposure, TPU-adapted)
  bench_functional   — Section 6 (mode-specific byte-exact oracles)
  bench_convergence  — Fig 4 / Fig 5 / Tables 5-6 (regimes + boundary)
  bench_recovery     — Fig 6 (guarded-recovery control pilot)
  bench_comm_model   — Fig 7 (modeled gradient-communication component)
  bench_hardware     — Table 7 / Fig 8 (datapath cost analogue)
  bench_roofline     — §Roofline source (reads results/dryrun)
  bench_sim          — repro.sim scenario sweep (writes BENCH_sim.json)
  bench_serve        — repro.serve trace replay (writes BENCH_serve.json)
  bench_elastic      — repro.elastic fault replay (writes BENCH_elastic.json)
  bench_tune         — repro.tune autotuner vs presets (writes BENCH_tune.json)

Usage: python -m benchmarks.run [--modules datapath,comm_model]
(``--only`` is accepted as a legacy alias of ``--modules``.)
"""
import argparse
import sys
import time

MODULES = ("datapath", "functional", "hardware", "comm_model", "sim",
           "serve", "roofline", "recovery", "convergence", "elastic",
           "tune")


def parse_modules(spec: str | None) -> list[str]:
    """``--modules`` value -> validated module list (None = all).

    Unknown names fail fast with the available set — a CI smoke job
    filtering on a misspelled module would otherwise silently run
    nothing and pass its gate.
    """
    if not spec:
        return list(MODULES)
    selected = [m.strip() for m in spec.split(",") if m.strip()]
    unknown = [m for m in selected if m not in MODULES]
    if unknown:
        raise SystemExit(
            f"unknown benchmark module(s) {','.join(unknown)}; "
            f"available: {','.join(MODULES)}")
    if not selected:
        raise SystemExit("empty --modules filter; available: "
                         + ",".join(MODULES))
    return selected


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--modules", "--only", default=None, dest="modules",
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    selected = parse_modules(args.modules)

    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        if mod not in selected:
            continue
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.bench_{mod}", fromlist=["rows"])
            for name, us, derived in m.rows():
                print(f"{name},{us:.2f},{str(derived).replace(',', ';')}",
                      flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"bench_{mod}/ERROR,0,{type(e).__name__}: "
                  f"{str(e)[:120].replace(',', ';')}", flush=True)
        print(f"bench_{mod}/elapsed_s,{(time.time()-t0)*1e6:.0f},",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
