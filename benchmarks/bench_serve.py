"""Serving-engine benchmark: continuous batching + machine-readable output.

Drives :class:`repro.serve.ServeEngine` over a staggered mixed-length
request trace on a deliberately small block pool (so preemption and CXL
spill are exercised), once per KV codec, and writes ``BENCH_serve.json``
(tokens/s, KV-block utilization, preemption count, int4-vs-fp32 cache
bytes) so the serving-path trajectory is tracked run-over-run by CI.
"""
import json
import os
import time

from repro.models import ModelConfig
from repro.serve import ServeEngine

#: where the machine-readable serving summary lands (cwd of the run)
BENCH_SERVE_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")

#: KV codecs swept (identity fp32 baseline vs 4-bit quantized cache)
KV_CODECS = ("fp32", "int4")

#: staggered arrivals, mixed prompt/budget lengths — enough resident KV
#: to overflow the pool below and force preempt-spill-resume cycles
TRACE = (
    {"prompt": list(range(2, 12)), "max_new_tokens": 10, "arrival_step": 0},
    {"prompt": list(range(5, 11)), "max_new_tokens": 14, "arrival_step": 0},
    {"prompt": list(range(1, 9)), "max_new_tokens": 8, "arrival_step": 1},
    {"prompt": list(range(3, 10)), "max_new_tokens": 12, "arrival_step": 2},
)


def _toy_cfg() -> ModelConfig:
    return ModelConfig(name="bench_serve_toy", family="dense", num_layers=2,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                       vocab_size=97, dtype="float32", remat=False)


def run_trace(kv_codec: str) -> dict:
    """One full serve of TRACE under ``kv_codec``; summary facts."""
    eng = ServeEngine(_toy_cfg(), max_batch=3, max_seq=32, num_blocks=10,
                      block_size=4, kv_codec=kv_codec)
    t0 = time.perf_counter()
    outputs = eng.serve(TRACE)
    dt = time.perf_counter() - t0
    tl = eng.timeline()
    utils = [s.utilization for s in tl.steps]
    return {
        "kv_codec": kv_codec,
        "num_requests": len(outputs),
        "num_steps": tl.num_steps,
        "total_new_tokens": tl.total_new_tokens,
        "tokens_per_s": tl.total_new_tokens / dt,
        "kv_block_utilization_peak": max(utils),
        "kv_block_utilization_mean": sum(utils) / len(utils),
        "preemptions": tl.total_preemptions,
        "cxl_spills": eng.cache.tier.spills,
        "cxl_fetches": eng.cache.tier.fetches,
        "cache_wire_bytes": tl.total_wire_bytes,
        "sim_cxl_direct_step_s": eng.simulate(tl).step_time_s,
    }


def rows():
    out = []
    bench = {}
    for codec in KV_CODECS:
        rep = run_trace(codec)
        bench[codec] = rep
        us = 1e6 * rep["total_new_tokens"] / rep["tokens_per_s"]
        out.append((f"serve/{codec}", us,
                    f"tok_per_s={rep['tokens_per_s']:.1f} "
                    f"steps={rep['num_steps']} "
                    f"preemptions={rep['preemptions']} "
                    f"util_peak={rep['kv_block_utilization_peak']:.2f}"))
    ratio = (bench["int4"]["cache_wire_bytes"]
             / bench["fp32"]["cache_wire_bytes"])
    bench["int4_vs_fp32_cache_bytes"] = ratio
    with open(BENCH_SERVE_JSON, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    out.append(("serve/int4_vs_fp32_bytes", 0.0, f"ratio={ratio:.4f}"))
    out.append(("serve/bench_json", 0.0,
                f"wrote {BENCH_SERVE_JSON} ({len(KV_CODECS)} codecs)"))
    return out
