"""Paper Section 9 (Fig 7): modeled gradient-communication component.

One GPT-2 XL gradient payload (~1.56B params) aggregated over 32 DP workers
on v5e ICI constants, per path.  Like the paper's figure these are modeled
communication times for the gradient component only — not end-to-end step
speedups.  SignOfMean is included only as the optimizer reference (its
communication is the FP32 path, the sign is taken after the mean).

Paths are named by their *registered schedule backend* — the baselines
resolve through the same ``repro.fabric`` registry the production
schedules use, so a newly registered collective shows up here by name.

The ``fused`` rows add the per-launch term: the same GPT-2 XL payload
split into its per-leaf tensors (one collective each) vs fused into
32 MiB buckets (one collective per bucket) — identical bytes, O(leaves)
vs O(buckets) launch latencies.
"""
import json
import os

import jax

from repro.core.buckets import (AdmissionPlan, DEFAULT_BUCKET_BYTES,
                                group_sizes, plan_buckets, resolve_policies)
from repro.core.modes import AggregationMode, Schedule
from repro.core.traffic import (GPT2_XL_PARAMS, IciModel,
                                hop_wire_bytes_per_device,
                                modeled_comm_time, modeled_layout_comm_time,
                                modeled_layout_multihop_time,
                                plan_traffic_ratio, wire_bytes_per_device)
from repro.fabric import available_codecs, get_codec, get_schedule

#: where the machine-readable per-codec summary lands (cwd of the run)
BENCH_CODECS_JSON = os.environ.get("BENCH_CODECS_JSON", "BENCH_codecs.json")
#: where the hierarchical (per-hop) accounting lands; bench_sim merges
#: its multihop exposure figures into the same file
BENCH_HIERARCHICAL_JSON = os.environ.get("BENCH_HIERARCHICAL_JSON",
                                         "BENCH_hierarchical.json")

#: the built-in hop plans benchmarked on the GPT-2 XL census
HIER_PLANS = ("hier_fp32_gbinary", "hier_fp32_gternary", "hier_fp32_int4")

W = 32
PATHS = [
    ("fp32_ring_allreduce", AggregationMode.FP32, "psum"),
    ("gbinary_vote_psum", AggregationMode.G_BINARY, "vote_psum"),
    ("gbinary_packed_a2a", AggregationMode.G_BINARY, "packed_a2a"),
    ("gternary_packed_a2a", AggregationMode.G_TERNARY, "packed_a2a"),
    ("majority_sign_sgd(sw)", AggregationMode.G_BINARY, "majority_sign_sgd"),
    ("sign_of_mean(ref)", AggregationMode.FP32, "sign_of_mean"),
]


def _gpt2_xl_leaves():
    """GPT-2 XL-shaped abstract param census (48 layers, d=1600)."""
    d, layers, sds = 1600, 48, jax.ShapeDtypeStruct
    f32 = "float32"
    tree = {"wte": sds((50257, d), f32), "wpe": sds((1024, d), f32)}
    for i in range(layers):
        tree[f"h{i:02d}"] = {
            "qkv": sds((d, 3 * d), f32), "proj": sds((d, d), f32),
            "fc_in": sds((d, 4 * d), f32), "fc_out": sds((4 * d, d), f32),
            "ln1_scale": sds((d,), f32), "ln1_bias": sds((d,), f32),
            "ln2_scale": sds((d,), f32), "ln2_bias": sds((d,), f32),
        }
    return tree


def _fused_rows(ici):
    params = _gpt2_xl_leaves()
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                    schedule=Schedule.PACKED_A2A)
    policies = resolve_policies(params, plan)
    per_leaf = plan_buckets(params, policies, bucket_bytes=1)
    fused = plan_buckets(params, policies,
                         bucket_bytes=DEFAULT_BUCKET_BYTES)
    t_leaf = modeled_layout_comm_time(per_leaf, W, ici)
    t_fused = modeled_layout_comm_time(fused, W, ici)
    return [
        ("comm_model/gpt2xl_tree/per_leaf", t_leaf * 1e6,
         f"launches={per_leaf.num_launches}"),
        ("comm_model/gpt2xl_tree/fused_32MiB", t_fused * 1e6,
         f"launches={fused.num_launches} speedup={t_leaf/t_fused:.1f}x"),
    ]


def _codec_rows(ici):
    """One row per *registered codec* on the GPT-2 XL backbone plan.

    Every registered codec — built-in or extension — is accounted the
    same way: bits/element from the codec, modeled traffic ratio of a
    low-bit-backbone plan, fused-launch count of the resulting bucket
    layout, and the modeled layout comm time.  The per-codec summary is
    merged into ``BENCH_codecs.json`` (read-modify-write: the
    ``fused_datapath`` keys written by ``bench_datapath`` survive, in
    either run order) so the perf trajectory of a newly registered
    codec is tracked run-over-run.
    """
    params = _gpt2_xl_leaves()
    sizes = group_sizes(params)
    out, bench = [], {}
    for name in available_codecs():
        codec = get_codec(name)
        plan = AdmissionPlan.lowbit_backbone(name)
        policies = resolve_policies(params, plan)
        layout = plan_buckets(params, policies,
                              bucket_bytes=DEFAULT_BUCKET_BYTES)
        ratio = plan_traffic_ratio(sizes, plan)
        t = modeled_layout_comm_time(layout, W, ici)
        bench[name] = {
            "bits_per_element": codec.bits_per_element,
            "reduction": codec.reduction,
            "default_schedule": codec.default_schedule,
            "traffic_ratio_backbone_plan": ratio,
            "fused_launches": layout.num_launches,
            "modeled_layout_comm_time_s": t,
        }
        out.append((f"comm_model/codec/{name}", t * 1e6,
                    f"bits={codec.bits_per_element:.3g} "
                    f"traffic_ratio={ratio:.4f} "
                    f"launches={layout.num_launches}"))
    from benchmarks.bench_datapath import merge_bench_json
    merge_bench_json(BENCH_CODECS_JSON, bench)
    out.append(("comm_model/codec/bench_json", 0.0,
                f"merged {BENCH_CODECS_JSON} ({len(bench)} codecs)"))
    return out


def _hierarchical_rows():
    """Per-hop byte accounting for the built-in hop plans.

    For every registered hierarchical route, the GPT-2 XL payload's
    per-leg wire bytes at W=32 (8-wide intra-node FP32, 4-wide
    inter-node low-bit), each leg as a ratio of the flat FP32 ring, and
    the scarce *inter-node* leg against the same codec run flat at full
    width — the paper-style win a single-codec plan cannot express.
    The summary seeds ``BENCH_hierarchical.json``; ``bench_sim`` merges
    its multihop exposure figures into the same file.
    """
    n = GPT2_XL_PARAMS
    fp32_total = wire_bytes_per_device(n, AggregationMode.FP32, "psum", W)
    params = _gpt2_xl_leaves()
    out, bench = [], {}
    for name in HIER_PLANS:
        codec = get_codec(name)
        backbone = codec.plan.hops[-1].codec
        legs = hop_wire_bytes_per_device(n, name, "hierarchical", W)
        flat_backbone = wire_bytes_per_device(
            n, backbone, get_codec(backbone).default_schedule, W)
        layout = plan_buckets(params,
                              resolve_policies(
                                  params, AdmissionPlan.lowbit_backbone(name)),
                              bucket_bytes=DEFAULT_BUCKET_BYTES)
        t_multihop = modeled_layout_multihop_time(layout, W)
        bench[name] = {
            "hop_signature": codec.hop_signature,
            "per_hop_bytes": list(legs),
            "per_hop_bytes_ratio_vs_fp32": [b / fp32_total for b in legs],
            "inter_node_bytes": legs[-1],
            "inter_node_ratio_vs_fp32": legs[-1] / fp32_total,
            "flat_backbone_bytes": flat_backbone,
            "inter_node_vs_flat_backbone": legs[-1] / flat_backbone,
            "modeled_layout_multihop_time_s": t_multihop,
        }
        out.append((f"comm_model/hier/{name}", t_multihop * 1e6,
                    f"legs={'+'.join(f'{b/2**30:.3f}GiB' for b in legs)} "
                    f"inter_node_vs_fp32={legs[-1]/fp32_total:.4f} "
                    f"inter_node_vs_flat_{backbone}="
                    f"{legs[-1]/flat_backbone:.4f}"))
    with open(BENCH_HIERARCHICAL_JSON, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    out.append(("comm_model/hier/bench_json", 0.0,
                f"wrote {BENCH_HIERARCHICAL_JSON} ({len(bench)} plans)"))
    return out


def rows():
    out = []
    ici = IciModel()
    base = None
    for name, mode, sched in PATHS:
        backend = get_schedule(sched)            # resolves or raises
        b = backend.wire_bytes_per_device(GPT2_XL_PARAMS, mode, W)
        t = ici.collective_time(b, W)
        # the module-level accounting agrees with the backend's own model
        assert b == wire_bytes_per_device(GPT2_XL_PARAMS, mode,
                                          backend.name, W)
        assert t == modeled_comm_time(GPT2_XL_PARAMS, mode, backend.name, W,
                                      ici)
        if base is None:
            base = t
        out.append((f"comm_model/gpt2xl/{name}", t * 1e6,
                    f"wire={b/2**30:.2f}GiB speedup={base/t:.1f}x"))
    out.extend(_fused_rows(ici))
    out.extend(_codec_rows(ici))
    out.extend(_hierarchical_rows())
    return out
