"""Paper Section 9 (Fig 7): modeled gradient-communication component.

One GPT-2 XL gradient payload (~1.56B params) aggregated over 32 DP workers
on v5e ICI constants, per path.  Like the paper's figure these are modeled
communication times for the gradient component only — not end-to-end step
speedups.  SignOfMean is included only as the optimizer reference (its
communication is the FP32 path, the sign is taken after the mean).
"""
from repro.core.modes import AggregationMode, Schedule
from repro.core.traffic import (GPT2_XL_PARAMS, IciModel, modeled_comm_time,
                                wire_bytes_per_device)

W = 32
PATHS = [
    ("fp32_ring_allreduce", AggregationMode.FP32, Schedule.PSUM),
    ("gbinary_vote_psum", AggregationMode.G_BINARY, Schedule.VOTE_PSUM),
    ("gbinary_packed_a2a", AggregationMode.G_BINARY, Schedule.PACKED_A2A),
    ("gternary_packed_a2a", AggregationMode.G_TERNARY, Schedule.PACKED_A2A),
    ("majority_sign_sgd(sw)", AggregationMode.G_BINARY, Schedule.VOTE_PSUM),
    ("sign_of_mean(ref)", AggregationMode.FP32, Schedule.PSUM),
]


def rows():
    out = []
    ici = IciModel()
    base = None
    for name, mode, sched in PATHS:
        t = modeled_comm_time(GPT2_XL_PARAMS, mode, sched, W, ici)
        b = wire_bytes_per_device(GPT2_XL_PARAMS, mode, sched, W)
        if base is None:
            base = t
        out.append((f"comm_model/gpt2xl/{name}", t * 1e6,
                    f"wire={b/2**30:.2f}GiB speedup={base/t:.1f}x"))
    return out
