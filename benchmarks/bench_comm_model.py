"""Paper Section 9 (Fig 7): modeled gradient-communication component.

One GPT-2 XL gradient payload (~1.56B params) aggregated over 32 DP workers
on v5e ICI constants, per path.  Like the paper's figure these are modeled
communication times for the gradient component only — not end-to-end step
speedups.  SignOfMean is included only as the optimizer reference (its
communication is the FP32 path, the sign is taken after the mean).

Paths are named by their *registered schedule backend* — the baselines
resolve through the same ``repro.fabric`` registry the production
schedules use, so a newly registered collective shows up here by name.
"""
from repro.core.modes import AggregationMode
from repro.core.traffic import (GPT2_XL_PARAMS, IciModel, modeled_comm_time,
                                wire_bytes_per_device)
from repro.fabric import get_schedule

W = 32
PATHS = [
    ("fp32_ring_allreduce", AggregationMode.FP32, "psum"),
    ("gbinary_vote_psum", AggregationMode.G_BINARY, "vote_psum"),
    ("gbinary_packed_a2a", AggregationMode.G_BINARY, "packed_a2a"),
    ("gternary_packed_a2a", AggregationMode.G_TERNARY, "packed_a2a"),
    ("majority_sign_sgd(sw)", AggregationMode.G_BINARY, "majority_sign_sgd"),
    ("sign_of_mean(ref)", AggregationMode.FP32, "sign_of_mean"),
]


def rows():
    out = []
    ici = IciModel()
    base = None
    for name, mode, sched in PATHS:
        backend = get_schedule(sched)            # resolves or raises
        b = backend.wire_bytes_per_device(GPT2_XL_PARAMS, mode, W)
        t = ici.collective_time(b, W)
        # the module-level accounting agrees with the backend's own model
        assert b == wire_bytes_per_device(GPT2_XL_PARAMS, mode,
                                          backend.name, W)
        assert t == modeled_comm_time(GPT2_XL_PARAMS, mode, backend.name, W,
                                      ici)
        if base is None:
            base = t
        out.append((f"comm_model/gpt2xl/{name}", t * 1e6,
                    f"wire={b/2**30:.2f}GiB speedup={base/t:.1f}x"))
    return out
