"""Paper Section 8 (Fig 6): guarded-recovery control-plane pilot.

Four traces on the hard task: always-FP32 and always-G-Binary references,
FP32-default (tests admission), and G-Binary-default with an injected
degradation window (tests CUSUM recovery + re-admission).  Reported:
final accuracy, fraction of low-bit steps, and average traffic vs FP32 —
the paper's Fig 6 callouts.
"""
import numpy as np

from repro.core.admission import Commander, CusumGuard, Supervisor
from repro.core.experiments import hard_task, run_training
from repro.fabric.control import Telemetry, make_controller

STEPS = 600
BATCH = 64
LR = 2e-4


def _pilot(degrade=None):
    """G-Binary-default policy with a Supervisor that recovers to FP32."""
    cp = make_controller(
        "paper",
        commander=Commander(tau_binary=0.2),
        supervisor=Supervisor(guard=CusumGuard(kappa=0.02, h=0.6),
                              cooldown_steps=60),
        warmup_steps=50)
    trace = {"lowbit_steps": 0, "total": 0, "traffic": 0.0}

    def callback(step, loss):
        plan = cp.observe(Telemetry(step=step, loss=loss, cosines={
            "backbone": {"gbinary": 0.8, "gternary": 0.7},
            "head": {"gbinary": 0.8, "gternary": 0.7}}))
        lowbit = "gbinary" in plan.signature()
        trace["total"] += 1
        trace["lowbit_steps"] += int(lowbit)
        trace["traffic"] += 1.0 / 32.0 if lowbit else 1.0
        return ("gbinary", "gbinary") if lowbit else ("fp32", "fp32")

    r = run_training(hard_task(), policy="fp32", steps=STEPS, batch=BATCH,
                     lr=LR, warmup_fp32=0, degrade=degrade,
                     plan_callback=callback, seed=0)
    return r, trace, cp


def rows():
    out = []
    # fixed-mode references
    r_fp = run_training(hard_task(), policy="fp32", steps=STEPS, batch=BATCH,
                        seed=0, warmup_fp32=50)
    r_gb = run_training(hard_task(), policy="gbinary", steps=STEPS,
                        batch=BATCH, lr=LR, seed=0, warmup_fp32=50)
    out.append(("recovery/always_fp32", 0.0, f"acc={r_fp.final_acc:.3f}"))
    out.append(("recovery/always_gbinary", 0.0, f"acc={r_gb.final_acc:.3f}"))

    # guarded pilot with injected degradation window
    r, tr, cp = _pilot(degrade=(250, 280))
    frac = tr["lowbit_steps"] / max(tr["total"], 1)
    avg_traffic = tr["traffic"] / max(tr["total"], 1)
    kinds = [e.kind for e in cp.events]
    out.append(("recovery/guarded_pilot", 0.0,
                f"acc={r.final_acc:.3f} lowbit_steps={100*frac:.1f}pct "
                f"avg_traffic={avg_traffic:.3f}x"))
    out.append(("recovery/events", 0.0,
                f"admitted={'admitted' in kinds} "
                f"recovered={'recovery' in kinds} "
                f"readmitted={'readmitted' in kinds}"))

    # elastic recovery rows (crash->rejoin, straggler ladder, replay);
    # bench_elastic caches the scenario runs, so this never recomputes
    from benchmarks.bench_elastic import elastic_rows
    out.extend(elastic_rows())
    return out
