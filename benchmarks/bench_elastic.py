"""Elastic-training smoke bench: crash→rejoin, straggler ladder, replay.

Three scenarios on a tiny dense LM over four virtual workers, reported
as CSV rows and written machine-readably to ``BENCH_elastic.json`` (the
nightly CI greps both):

  * ``crash_rejoin`` — a scripted crash at step 9 (checkpoint interval
    4 → one replayed step) with rejoin at 14, through the full
    ElasticTrainer rollback/re-plan path: steps-to-recover and the
    traffic overhead of replay;
  * ``straggler``    — a 6x slowdown window on one worker under the
    ``straggler_aware`` controller: the detector's Telemetry must flip
    the admission ladder to low-bit and recover to FP32;
  * ``replay``       — the same crash→rejoin-plus-straggler schedule
    priced offline through ``repro.sim`` with per-phase exposed time.

Results are computed once per process and shared with
``bench_recovery`` (which appends the elastic rows to its Fig-6 table).
"""
import json
import os

import jax
import numpy as np

BENCH_ELASTIC_JSON = os.environ.get("BENCH_ELASTIC_JSON",
                                    "BENCH_elastic.json")

STEPS = 20
WORKERS = 4
CRASH = {"worker": 3, "step": 9, "rejoin_step": 14}
STRAGGLER = {"worker": 1, "start": 3, "stop": 12, "factor": 6.0}

_CACHE = {}


def _cfg():
    from repro.models import ModelConfig
    return ModelConfig(name="bench-el", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=128, dtype="float32", remat=False)


def _run_scenarios() -> dict:
    if _CACHE:
        return _CACHE
    import tempfile

    from repro.core import AdmissionPlan, AggregationMode, Schedule
    from repro.data import SyntheticLMStream
    from repro.elastic import (ElasticConfig, ElasticTrainer,
                               StragglerAwareController, replay_schedule)
    from repro.models import init_params
    from repro.optim import SgdMomentum

    cfg = _cfg()
    data = SyntheticLMStream(vocab=128, seq_len=16, batch=4, seed=0)
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.VOTE_PSUM,
                                         error_feedback=True)

    def ecfg(**kw):
        return ElasticConfig(synthetic_step_time_s=1e-3,
                             log_interval=10_000, **kw)

    # -- scenario 1: scripted crash -> rejoin through ElasticTrainer ----
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = ElasticTrainer(cfg, SgdMomentum(peak_lr=0.2, total_steps=60),
                            data, WORKERS, plan=plan, ckpt_dir=ckpt_dir,
                            faults=[("crash", CRASH)],
                            ecfg=ecfg(checkpoint_interval=4))
        hist = tr.run(STEPS)
    rep = tr.report()
    crash_rejoin = {
        **rep,
        "final_loss": float(hist[-1]["loss"]),
        "loss_finite": bool(all(np.isfinite(h["loss"]) for h in hist)),
        "recovery_complete": bool(
            rep["restarts"] == 1
            and rep["final_view"]["workers"] == list(range(WORKERS))
            and rep["steps"] == STEPS),
    }

    # -- scenario 2: straggler flips the admission ladder ---------------
    ctrl = StragglerAwareController(demote_after=2, recover_after=6)
    tr2 = ElasticTrainer(cfg, SgdMomentum(peak_lr=0.1, total_steps=80),
                         data, WORKERS, controller=ctrl,
                         faults=[("straggler", STRAGGLER)], ecfg=ecfg())
    h2 = tr2.run(24)
    kinds = [e.kind for e in ctrl.events]
    straggler = {
        "flagged_steps": int(sum(1 for h in h2 if h["stragglers"])),
        "demoted": bool("demoted" in kinds),
        "recovered": bool("recovered" in kinds),
        "events": [{"step": e.step, "kind": e.kind} for e in ctrl.events],
        "lowbit_steps": int(sum(1 for h in h2 if "gbinary" in h["plan"])),
    }

    # -- scenario 3: the same schedule priced offline through the DES ---
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    replay = replay_schedule(
        params, plan, WORKERS, STEPS,
        faults=[("crash", CRASH), ("straggler", STRAGGLER)],
        topology="cxl_direct", compute_time_s=1e-4)

    _CACHE.update(crash_rejoin=crash_rejoin, straggler=straggler,
                  replay=replay.to_jsonable())
    return _CACHE


def elastic_rows():
    """The elastic rows (shared with bench_recovery)."""
    r = _run_scenarios()
    cj, st, rp = r["crash_rejoin"], r["straggler"], r["replay"]
    rec = cj["recoveries"][0]
    out = [
        ("elastic/crash_rejoin", 0.0,
         f"steps_to_recover={rec['steps_to_recover']} "
         f"traffic_overhead={cj['traffic_overhead']:.4f}x "
         f"recovered={cj['recovery_complete']}"),
        ("elastic/epoch_cache", 0.0,
         f"compiled_steps={cj['compiled_steps']} "
         f"final_epoch={cj['final_view']['epoch']}"),
        ("elastic/straggler", 0.0,
         f"flagged_steps={st['flagged_steps']} demoted={st['demoted']} "
         f"recovered={st['recovered']}"),
        ("elastic/replay", 0.0,
         f"phases={rp['num_phases']} exposed_pct={rp['exposed_pct']:.3f} "
         f"total_time_s={rp['total_time_s']:.5f}"),
    ]
    return out


def rows():
    out = elastic_rows()
    with open(BENCH_ELASTIC_JSON, "w") as f:
        json.dump(_run_scenarios(), f, indent=1, sort_keys=True)
    return out
