"""Paper Section 10 (Table 7, Fig 8): datapath hardware-cost analogue.

The paper bounds its 512-bit datapath with gate-equivalents and FPGA
routing; the TPU analogue bounds the Pallas datapath with its structural
costs: VMEM block footprint, vector-ops per value, modeled VPU cycles per
64-byte "line" at the v5e clock, swept over block widths (the paper's
width sweep).  Plus measured interpret-path throughput as the functional
reference.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels as K
from repro.core.exposure import TpuDatapathModel


def rows():
    out = []
    model = TpuDatapathModel()
    w = 8

    # Table 7 analogue: per-stage structural cost of the 32-sign word path
    ops_per_value = {
        "pack": model.ops_per_value_pack,
        "popcount_w8": model.ops_per_value_popcount_per_worker * w,
        "majority": model.ops_per_value_majority,
        "unpack": model.ops_per_value_unpack,
    }
    total_ops = sum(ops_per_value.values())
    line_values = 512            # one 64-byte CXL line = 512 sign bits
    cycles_per_line = total_ops * line_values / model.vpu_lanes
    out.append(("hardware/vpu_cycles_per_512b_line", 0.0,
                f"{cycles_per_line:.2f} cycles @ {model.clock_hz/1e6:.0f}MHz "
                f"(paper: 5-cycle 512-bit datapath)"))
    for stage, ops in ops_per_value.items():
        out.append((f"hardware/ops_per_value/{stage}", 0.0, f"{ops:.3f}"))

    # Fig 8 analogue: width sweep — VMEM footprint + throughput per block
    rng = np.random.RandomState(0)
    for wb in (1, 2, 4, 8, 16):
        rows_v = 32 * wb
        plane = jnp.asarray(rng.randn(rows_v * 8, 128), jnp.float32)
        t0 = time.perf_counter()
        r = K.pack_signs(plane)
        jax.block_until_ready(r)
        vmem_kib = (rows_v * 128 * 4 + wb * 128 * 4) / 1024
        out.append((f"hardware/width_sweep/block_words_{wb}",
                    (time.perf_counter() - t0) * 1e6,
                    f"vmem_block={vmem_kib:.0f}KiB "
                    f"signs_per_block={rows_v*128}"))
    return out
