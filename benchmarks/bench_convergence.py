"""Paper Section 7 (Fig 4, Fig 5, Tables 5-6): convergence and boundary.

Virtual-worker (W=8) training on synthetic cluster tasks engineered to
exhibit the paper's regimes (CIFAR-10/CIFAR-100 are not available offline;
see EXPERIMENTS.md for the regime mapping):

  * easy task — G-Binary / G-Ternary stay near FP32 (validated regime);
  * hard fine-grained task — full-path low-bit lags FP32 by ~double-digit
    accuracy (the boundary);
  * layer-aware admission — low-bit backbone + FP32 head (at the low-bit
    learning rate, the paper's Section 7.3 ablation) recovers the gap at a
    fraction of the traffic; the reverse split is weaker and keeps almost
    all FP32 traffic;
  * Table 5 analogue — end-of-warm-up cosine diagnostics per group.

Seeds follow the paper protocol (mean +/- std).
"""
import time

import numpy as np

from repro.core.experiments import (RunResult, easy_task, hard_task,
                                    run_training)

SEEDS = (0, 1)
EASY = dict(steps=300, batch=256, warmup_fp32=50)
HARD = dict(steps=700, batch=64, warmup_fp32=50)
SIGN_LR_EASY = 5e-4
SIGN_LR_HARD = 2e-4


def _multi(task, seeds, **kw):
    rs = [run_training(task, seed=s, **kw) for s in seeds]
    accs = [r.final_acc for r in rs]
    return float(np.mean(accs)), float(np.std(accs)), rs[0]


def rows():
    out = []
    et, ht = easy_task(), hard_task()
    t0 = time.perf_counter()

    # --- Fig 4: validated regimes (easy task) ---------------------------
    for pol, lr in (("fp32", None), ("gbinary", SIGN_LR_EASY),
                    ("gternary", SIGN_LR_EASY),
                    ("majority_sign_sgd", SIGN_LR_EASY),
                    ("sign_of_mean", SIGN_LR_EASY)):
        m, s, r = _multi(et, SEEDS, policy=pol, lr=lr, **EASY)
        out.append((f"convergence/easy/{pol}", 0.0,
                    f"acc={m:.3f}+-{s:.3f} traffic={r.traffic_ratio:.4f}"))

    # --- Fig 5 + Table 6: hard-task boundary + layer-aware admission ----
    hard_rows = [
        ("fp32_all", dict(policy="fp32")),
        ("gbinary_all", dict(policy="gbinary", lr=SIGN_LR_HARD)),
        ("gternary_all", dict(policy="gternary", lr=SIGN_LR_HARD)),
        ("majority_sign_sgd", dict(policy="majority_sign_sgd",
                                   lr=SIGN_LR_HARD)),
        ("sign_of_mean", dict(policy="sign_of_mean", lr=SIGN_LR_HARD)),
        # layer-aware operating point (paper ablation: low-bit lr for the
        # FP32 head as well)
        ("gbinary_backbone_fp32_head",
         dict(policy="gbinary", head_policy="fp32", lr=SIGN_LR_HARD)),
        ("gternary_backbone_fp32_head",
         dict(policy="gternary", head_policy="fp32", lr=SIGN_LR_HARD)),
        # reverse split (paper: weaker, keeps ~all FP32 traffic)
        ("fp32_backbone_gbinary_head",
         dict(policy="fp32", head_policy="gbinary", lr=SIGN_LR_HARD)),
    ]
    accs = {}
    for name, kw in hard_rows:
        m, s, r = _multi(ht, SEEDS, **HARD, **kw)
        accs[name] = m
        out.append((f"convergence/hard/{name}", 0.0,
                    f"acc={m:.3f}+-{s:.3f} traffic={r.traffic_ratio:.4f}"))

    # boundary + recovery verdicts (the paper's qualitative claims)
    gap = accs["fp32_all"] - accs["gbinary_all"]
    rec = accs["gbinary_backbone_fp32_head"] - accs["gbinary_all"]
    out.append(("convergence/hard/boundary_gap_pts", 0.0,
                f"{100*gap:.1f} (paper: 11.6 on CIFAR-100)"))
    out.append(("convergence/hard/layer_aware_recovery_pts", 0.0,
                f"{100*rec:.1f} recovered by FP32 head"))

    # --- Table 5 analogue: end-of-warm-up cosine diagnostics ------------
    r = run_training(ht, policy="fp32", diagnose_at=49, seed=0, **HARD)
    c = r.cosines
    out.append(("diagnostics/hard/backbone_cos_gbinary", 0.0,
                f"{c['backbone']['gbinary']:.3f}"))
    out.append(("diagnostics/hard/head_cos_gbinary", 0.0,
                f"{c['head']['gbinary']:.3f}"))
    out.append(("convergence/wall_time_s",
                (time.perf_counter() - t0) * 1e6, "total"))
    return out
