"""Fabric-simulator benchmark: scenario sweep + machine-readable output.

Replays the paper's operating points and a GPT-2 XL fused bucket layout
across every built-in topology through the :mod:`repro.sim`
discrete-event simulator, and writes ``BENCH_sim.json`` (exposed %,
launch count, link utilization, step time per scenario) so the perf
trajectory of the simulated timeline is tracked run-over-run by CI.
"""
import json
import os

from repro.core.buckets import (AdmissionPlan, DEFAULT_BUCKET_BYTES,
                                plan_buckets, resolve_policies)
from repro.core.modes import AggregationMode, Schedule
from repro.sim import (available_topologies, paper_operating_points,
                       simulate_layout)

from benchmarks.bench_comm_model import (BENCH_HIERARCHICAL_JSON, HIER_PLANS,
                                         W, _gpt2_xl_leaves)

#: where the machine-readable scenario summary lands (cwd of the run)
BENCH_SIM_JSON = os.environ.get("BENCH_SIM_JSON", "BENCH_sim.json")

#: modeled backward-pass time for the GPT-2 XL scenario (6*N*B*S at
#: derated v5e peak, order-of-magnitude — the sim cares about overlap
#: structure, not the absolute value)
GPT2_XL_COMPUTE_S = 25e-3


def _gpt2_xl_layout():
    params = _gpt2_xl_leaves()
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.PACKED_A2A)
    policies = resolve_policies(params, plan)
    return plan_buckets(params, policies, bucket_bytes=DEFAULT_BUCKET_BYTES)


def _hier_layout(plan_name):
    params = _gpt2_xl_leaves()
    plan = AdmissionPlan.lowbit_backbone(plan_name)
    policies = resolve_policies(params, plan)
    return plan_buckets(params, policies, bucket_bytes=DEFAULT_BUCKET_BYTES)


def scenario_reports():
    """name -> SimReport for every benchmark scenario."""
    reports = dict(paper_operating_points())
    layout = _gpt2_xl_layout()
    for topo in available_topologies():
        reports[f"gpt2xl_fused/{topo}"] = simulate_layout(
            layout, W, topology=topo, compute_time_s=GPT2_XL_COMPUTE_S)
    # hierarchical routes replayed leg-by-leg on the multihop topology
    for plan_name in HIER_PLANS:
        reports[f"gpt2xl_hier/{plan_name}/multihop"] = simulate_layout(
            _hier_layout(plan_name), W, topology="multihop",
            compute_time_s=GPT2_XL_COMPUTE_S)
    return reports


def _merge_hier_exposure(bench):
    """Fold the multihop exposure figures of the hierarchical scenarios
    into ``BENCH_hierarchical.json`` (seeded by bench_comm_model)."""
    hier = {}
    if os.path.exists(BENCH_HIERARCHICAL_JSON):
        with open(BENCH_HIERARCHICAL_JSON) as f:
            hier = json.load(f)
    for plan_name in HIER_PLANS:
        summary = bench.get(f"gpt2xl_hier/{plan_name}/multihop")
        if summary is not None:
            hier.setdefault(plan_name, {})["multihop_sim"] = summary
    with open(BENCH_HIERARCHICAL_JSON, "w") as f:
        json.dump(hier, f, indent=1, sort_keys=True)


def rows():
    out = []
    bench = {}
    for name, rep in sorted(scenario_reports().items()):
        bench[name] = rep.summary()
        out.append((f"sim/{name}", rep.step_time_s * 1e6,
                    f"exposed_pct={rep.exposed_pct:.3f} "
                    f"launches={rep.num_launches} hidden={rep.hidden}"))
    with open(BENCH_SIM_JSON, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    out.append(("sim/bench_json", 0.0,
                f"wrote {BENCH_SIM_JSON} ({len(bench)} scenarios)"))
    _merge_hier_exposure(bench)
    out.append(("sim/hier_bench_json", 0.0,
                f"merged multihop exposure into {BENCH_HIERARCHICAL_JSON}"))
    return out
