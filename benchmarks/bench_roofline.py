"""Roofline table reader: per (arch x shape x plan x mesh) from the dry-run.

Reads ``results/dryrun`` JSONs (produced by ``repro.launch.dryrun``) and
emits the three roofline terms, the dominant bottleneck, and the
useful-FLOP ratio.  This is the §Roofline source of record.
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows():
    out = []
    files = sorted(glob.glob(os.path.join(RESULTS, "*", "*", "*.json")))
    if not files:
        return [("roofline/no_results", 0.0,
                 "run: python -m repro.launch.dryrun")]
    for f in files:
        d = json.load(open(f))
        mesh = d.get("mesh_name", "?")
        tag = f"{mesh}/{d.get('arch')}/{d.get('shape')}"
        if "skipped" in d:
            out.append((f"roofline/{tag}", 0.0, "SKIP:" + d["skipped"][:40]))
            continue
        if "error" in d:
            out.append((f"roofline/{tag}", 0.0, "ERROR"))
            continue
        r = d["roofline"]
        plan = d.get("plan", "?")
        out.append((
            f"roofline/{tag}/{plan}",
            r["step_time_lower_bound_s"] * 1e6,
            f"dom={r['dominant']} comp={r['compute_s']:.4f}s "
            f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
            f"frac={r['roofline_fraction']:.3f} "
            f"useful={r.get('useful_flop_ratio', 0):.2f}"))
    return out
