"""Roofline table reader: per (arch x shape x plan x mesh) from the dry-run.

Reads ``results/dryrun`` JSONs (produced by ``repro.launch.dryrun``) and
emits the three roofline terms, the dominant bottleneck, and the
useful-FLOP ratio.  This is the §Roofline source of record.

``fused_kernel_rows`` adds the aggregation-datapath memory term with no
dryrun dependency: per codec KernelSet, the modeled HBM-roofline time
of one 8M-element bucket under the fused vs unfused pipelines (v5e-ish
819 GB/s HBM), plus the launch-count delta — the datapath side of the
same bottleneck story the dryrun tables tell for the model.
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

#: v5e-class HBM bandwidth used for the modeled kernel roofline
HBM_BYTES_PER_S = 819e9


def fused_kernel_rows(n=8 << 20, num_workers=32):
    """HBM-roofline of the fused vs unfused bucket datapath, per codec."""
    from repro.fabric import available_codecs, get_codec
    out = []
    for name in available_codecs():
        codec = get_codec(name)
        hook = getattr(codec, "pallas_kernels", None)
        ks = hook() if hook is not None else None
        if ks is None:
            continue
        ef = bool(codec.threads_ef)
        bf = ks.hbm_bytes(n, num_workers=num_workers, fused=True,
                          distributed=True, ef=ef)
        bu = ks.hbm_bytes(n, num_workers=num_workers, fused=False,
                          distributed=True, ef=ef)
        lf = ks.launches(fused=True, distributed=True, ef=ef)
        lu = ks.launches(fused=False, distributed=True, ef=ef)
        out.append((f"roofline/fused_kernels/{name}",
                    bf / HBM_BYTES_PER_S * 1e6,
                    f"unfused_us={bu / HBM_BYTES_PER_S * 1e6:.1f} "
                    f"hbm_ratio={bf / bu:.3f} launches={lf}f/{lu}u "
                    f"(n=8M W={num_workers})"))
    return out


def rows():
    out = fused_kernel_rows()
    files = sorted(glob.glob(os.path.join(RESULTS, "*", "*", "*.json")))
    if not files:
        return out + [("roofline/no_results", 0.0,
                       "run: python -m repro.launch.dryrun")]
    for f in files:
        d = json.load(open(f))
        mesh = d.get("mesh_name", "?")
        tag = f"{mesh}/{d.get('arch')}/{d.get('shape')}"
        if "skipped" in d:
            out.append((f"roofline/{tag}", 0.0, "SKIP:" + d["skipped"][:40]))
            continue
        if "error" in d:
            out.append((f"roofline/{tag}", 0.0, "ERROR"))
            continue
        r = d["roofline"]
        plan = d.get("plan", "?")
        out.append((
            f"roofline/{tag}/{plan}",
            r["step_time_lower_bound_s"] * 1e6,
            f"dom={r['dominant']} comp={r['compute_s']:.4f}s "
            f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
            f"frac={r['roofline_fraction']:.3f} "
            f"useful={r.get('useful_flop_ratio', 0):.2f}"))
    return out
