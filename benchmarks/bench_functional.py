"""Paper Section 6: functional correctness with mode-specific oracles.

The packed-sign validation: write sign packets for eight virtual workers,
read back under identity / G-Binary / G-Ternary, compare each against its
transformation-aware oracle (identity: byte-exact; low-bit: the Section 2
reduction).  Reported value is the end-to-end pipeline latency on the
functional path; `derived` records the exact-match verdicts.
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels as K
from repro.core import AdmissionPlan, AggregationMode, GroupPolicy
from repro.fabric import Fabric
from repro.kernels import ref


def _fabric_session_row():
    """End-to-end session check: Fabric.aggregate under a mixed plan.

    Host-local session (one worker): the G-Binary backbone reduces to
    sign(g), the FP32 head to g itself — mode-specific oracles through
    the full registry-dispatch path.
    """
    rng = np.random.RandomState(11)
    grads = {"backbone": {"w": jnp.asarray(rng.randn(256, 128), jnp.float32)},
             "head": {"w": jnp.asarray(rng.randn(128, 16), jnp.float32)}}
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         error_feedback=False)
    fabric = Fabric()                       # mesh-less: 1 virtual worker
    t0 = time.perf_counter()
    agg, _ = fabric.aggregate(grads, plan)
    jax.block_until_ready(agg)
    t_us = (time.perf_counter() - t0) * 1e6
    ok = (np.array_equal(np.asarray(agg["backbone"]["w"]),
                         np.sign(np.asarray(grads["backbone"]["w"])))
          and np.allclose(np.asarray(agg["head"]["w"]),
                          np.asarray(grads["head"]["w"])))
    return ("functional/fabric_session_mixed_plan", t_us, f"oracle_exact={ok}")


def _fused_bucketing_rows():
    """Bucketed vs per-leaf aggregation on the quickstart model.

    Plans the bucket layout over the real quickstart param tree
    (qwen3_0p6b smoke) under the paper's recovered operating point, and
    reports the collective-launch reduction — O(leaves) per-leaf vs
    O(buckets) fused — plus measured host-local dispatch latency and a
    bit-for-bit cross-check of the two paths.
    """
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("qwen3_0p6b", smoke=True)
    params = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    fabric = Fabric()                        # host-local session
    layout = fabric.layout_for(params, plan)
    n_leaves, n_launches = layout.num_leaves, layout.num_launches

    # concrete grads with the same structure: time both paths end to end
    rng = np.random.RandomState(3)
    grads = jax.tree.map(
        lambda s: jnp.asarray(rng.randn(*s.shape), jnp.float32), params)

    def timed(fused):
        agg, _ = fabric.aggregate(grads, plan, fused=fused)  # warm caches
        jax.block_until_ready(agg)
        t0 = time.perf_counter()
        agg, _ = fabric.aggregate(grads, plan, fused=fused)
        jax.block_until_ready(agg)
        return agg, (time.perf_counter() - t0) * 1e6

    per_leaf, t_leaf = timed(False)
    fused, t_fused = timed(True)
    exact = all(jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        per_leaf, fused)))
    return [
        ("functional/fused_launch_count", 0.0,
         f"leaves={n_leaves} launches={n_launches} "
         f"buckets={len(layout.buckets)}"),
        ("functional/per_leaf_aggregate", t_leaf, f"launches={n_leaves}"),
        ("functional/fused_aggregate", t_fused,
         f"launches={n_launches} bitwise_equal={exact}"),
    ]


def rows():
    rng = np.random.RandomState(7)
    w, n = 8, 64 * 128 * 32                      # 64 word rows
    grads = rng.randn(w, n).astype(np.float32)
    planes = [ref.to_plane(jnp.asarray(g)) for g in grads]

    # identity: byte-for-byte read-back of the packed payload
    words = [K.pack_signs(p) for p in planes]
    ident_ok = all(np.array_equal(np.asarray(x), np.asarray(ref.sign_pack(p)))
                   for x, p in zip(words, planes))

    t0 = time.perf_counter()
    stack = jnp.stack(words)
    counts = K.popcount_stack(stack)
    sw_b, mw_b = K.majority_decode(counts, num_workers=w)
    u_bin = ref.from_plane(K.unpack_ternary(sw_b, mw_b), n)
    jax.block_until_ready(u_bin)
    t_bin = (time.perf_counter() - t0) * 1e6

    bin_ok = np.array_equal(np.asarray(u_bin),
                            np.asarray(ref.gbinary_aggregate_dense(
                                jnp.asarray(grads))))

    gate = K.ternary_gate_words(planes[0].shape[0])
    sw_t, mw_t = K.majority_decode(counts, num_workers=w, gate_words=gate)
    u_ter = ref.from_plane(K.unpack_ternary(sw_t, mw_t), n)
    ter_ok = np.array_equal(np.asarray(u_ter),
                            np.asarray(ref.gternary_aggregate_dense(
                                jnp.asarray(grads))))

    return [
        ("functional/identity_readback", 0.0, f"byte_exact={ident_ok}"),
        ("functional/gbinary_pipeline", t_bin, f"oracle_exact={bin_ok}"),
        ("functional/gternary_pipeline", t_bin, f"oracle_exact={ter_ok}"),
        _fabric_session_row(),
        *_fused_bucketing_rows(),
    ]
