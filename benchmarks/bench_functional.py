"""Paper Section 6: functional correctness with mode-specific oracles.

The packed-sign validation: write sign packets for eight virtual workers,
read back under identity / G-Binary / G-Ternary, compare each against its
transformation-aware oracle (identity: byte-exact; low-bit: the Section 2
reduction).  Reported value is the end-to-end pipeline latency on the
functional path; `derived` records the exact-match verdicts.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels as K
from repro.core import AdmissionPlan, AggregationMode, GroupPolicy
from repro.fabric import Fabric
from repro.kernels import ref


def _fabric_session_row():
    """End-to-end session check: Fabric.aggregate under a mixed plan.

    Host-local session (one worker): the G-Binary backbone reduces to
    sign(g), the FP32 head to g itself — mode-specific oracles through
    the full registry-dispatch path.
    """
    rng = np.random.RandomState(11)
    grads = {"backbone": {"w": jnp.asarray(rng.randn(256, 128), jnp.float32)},
             "head": {"w": jnp.asarray(rng.randn(128, 16), jnp.float32)}}
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         error_feedback=False)
    fabric = Fabric()                       # mesh-less: 1 virtual worker
    t0 = time.perf_counter()
    agg, _ = fabric.aggregate(grads, plan)
    jax.block_until_ready(agg)
    t_us = (time.perf_counter() - t0) * 1e6
    ok = (np.array_equal(np.asarray(agg["backbone"]["w"]),
                         np.sign(np.asarray(grads["backbone"]["w"])))
          and np.allclose(np.asarray(agg["head"]["w"]),
                          np.asarray(grads["head"]["w"])))
    return ("functional/fabric_session_mixed_plan", t_us, f"oracle_exact={ok}")


def rows():
    rng = np.random.RandomState(7)
    w, n = 8, 64 * 128 * 32                      # 64 word rows
    grads = rng.randn(w, n).astype(np.float32)
    planes = [ref.to_plane(jnp.asarray(g)) for g in grads]

    # identity: byte-for-byte read-back of the packed payload
    words = [K.pack_signs(p) for p in planes]
    ident_ok = all(np.array_equal(np.asarray(x), np.asarray(ref.sign_pack(p)))
                   for x, p in zip(words, planes))

    t0 = time.perf_counter()
    stack = jnp.stack(words)
    counts = K.popcount_stack(stack)
    sw_b, mw_b = K.majority_decode(counts, num_workers=w)
    u_bin = ref.from_plane(K.unpack_ternary(sw_b, mw_b), n)
    jax.block_until_ready(u_bin)
    t_bin = (time.perf_counter() - t0) * 1e6

    bin_ok = np.array_equal(np.asarray(u_bin),
                            np.asarray(ref.gbinary_aggregate_dense(
                                jnp.asarray(grads))))

    gate = K.ternary_gate_words(planes[0].shape[0])
    sw_t, mw_t = K.majority_decode(counts, num_workers=w, gate_words=gate)
    u_ter = ref.from_plane(K.unpack_ternary(sw_t, mw_t), n)
    ter_ok = np.array_equal(np.asarray(u_ter),
                            np.asarray(ref.gternary_aggregate_dense(
                                jnp.asarray(grads))))

    return [
        ("functional/identity_readback", 0.0, f"byte_exact={ident_ok}"),
        ("functional/gbinary_pipeline", t_bin, f"oracle_exact={bin_ok}"),
        ("functional/gternary_pipeline", t_bin, f"oracle_exact={ter_ok}"),
        _fabric_session_row(),
    ]
