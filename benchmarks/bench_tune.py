"""Autotuner bench: the tuned plan vs the preset baselines, per topology.

Runs ``fabric.autotune`` over the default search space (every
``plan_presets`` entry + generated low-bit axes, classifier head pinned
to FP32) on the GPT-2 XL-shaped abstract census over 32 workers, once
per topology (``ici_ring``, ``multihop``), and reports the winner
against the ``fp32`` and ``gbin_backbone`` baselines: modeled step
time, per-device wire bytes, exposed datapath %.

Written machine-readably to ``BENCH_tune.json``; the nightly CI gate
asserts the file exists and that the tuned plan's sim-scored step time
is never slower than the best preset it searched over
(``best_preset_step_time_s``) — the structural invariant the search
strategies guarantee by always sim-scoring seed candidates.
"""
import json
import os

BENCH_TUNE_JSON = os.environ.get("BENCH_TUNE_JSON", "BENCH_tune.json")

W = 32
TOPOLOGIES = ("ici_ring", "multihop")
BASELINES = ("fp32", "gbin_backbone")

_CACHE = {}


def _run() -> dict:
    if _CACHE:
        return _CACHE
    from benchmarks.bench_comm_model import _gpt2_xl_leaves

    from repro.fabric import Fabric
    from repro.tune import default_space

    params = _gpt2_xl_leaves()
    fabric = Fabric(num_workers=W)
    space = default_space()
    seed_names = {n for n, _ in space.plans}
    out = {}
    for topo in TOPOLOGIES:
        tuned = fabric.autotune(params, space, topology=topo)
        # sim-scored presets from the tuner's own run: the gate baseline
        presets = {}
        for r in tuned.runners_up:
            base = r.name.split("/")[0]
            if base in seed_names and r.score is not None:
                t = float(r.score.step_time_s)
                if base not in presets or t < presets[base]["step_time_s"]:
                    presets[base] = {
                        "step_time_s": t,
                        "wire_bytes": float(r.score.wire_bytes),
                        "exposed_pct": float(r.score.exposed_pct)}
        tuned_base = tuned.name.split("/")[0]
        if tuned_base in seed_names or any(
                tuned.plan.signature() == p.signature()
                and tuned.bucket_bytes == fabric.bucket_bytes
                for n, p in space.plans):
            # the winner itself may be a preset; count it as one
            presets.setdefault(tuned_base, {
                "step_time_s": float(tuned.score.step_time_s),
                "wire_bytes": float(tuned.score.wire_bytes),
                "exposed_pct": float(tuned.score.exposed_pct)})
        best_preset = min(presets.values(),
                          key=lambda p: p["step_time_s"],
                          default={"step_time_s": float("inf")})
        out[topo] = {
            "tuned": tuned.summary(),
            "candidates": dict(tuned.provenance["candidates"]),
            "baselines": {b: presets[b] for b in BASELINES
                          if b in presets},
            "best_preset_step_time_s": best_preset["step_time_s"],
            "speedup_vs_fp32": (
                presets["fp32"]["step_time_s"] / tuned.score.step_time_s
                if "fp32" in presets and tuned.score.step_time_s > 0
                else None),
        }
    _CACHE.update(out)
    return _CACHE


def rows():
    results = _run()
    out = []
    for topo, r in results.items():
        t = r["tuned"]
        out.append((f"tune/{topo}/tuned", t["step_time_s"] * 1e6,
                    t["plan_signature"]))
        out.append((f"tune/{topo}/best_preset",
                    r["best_preset_step_time_s"] * 1e6,
                    f"tuned_no_slower={t['step_time_s'] <= r['best_preset_step_time_s'] + 1e-12}"))
        for b, s in r["baselines"].items():
            out.append((f"tune/{topo}/{b}", s["step_time_s"] * 1e6,
                        f"wire={s['wire_bytes']:.0f}B"))
        if r["speedup_vs_fp32"] is not None:
            out.append((f"tune/{topo}/speedup_vs_fp32",
                        r["speedup_vs_fp32"],
                        f"exposed={t['exposed_pct']:.2f}%"))
    with open(BENCH_TUNE_JSON, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
