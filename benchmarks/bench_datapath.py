"""Paper Section 5 (Table 4, Fig 2-3): datapath timing exposure, TPU-adapted.

Three evidence sources:
  * measured wall-time of the controller-datapath kernels on the functional
    (interpret) path — the byte-exact reference implementation — staged
    chain vs the codec-owned fused kernels (repro.kernels.fused);
  * modeled per-bucket kernel-launch counts and HBM bytes of the fused vs
    unfused pipelines from each codec's KernelSet accounting (merged into
    BENCH_codecs.json for the nightly fused-vs-unfused gate);
  * the analytic exposure model with v5e constants:
    T_exposed = max(0, T_agg - T_overlap), swept over link bandwidth,
    datapath depth, admitted fraction, and telemetry staleness (Fig 3
    panels a-d).
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels as K
from repro.core.exposure import ExposureModel, TpuDatapathModel, envelope_sweep
from repro.core.traffic import wire_bytes_per_device
from repro.core.modes import AggregationMode, Schedule

#: same file bench_comm_model writes — both writers read-modify-write so
#: module order within a run (and partial runs) cannot drop keys
BENCH_CODECS_JSON = os.environ.get("BENCH_CODECS_JSON", "BENCH_codecs.json")


def merge_bench_json(path, updates):
    """Read-modify-write merge of per-codec dicts into a bench JSON."""
    bench = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                bench = json.load(f)
        except (OSError, ValueError):
            bench = {}
    for name, d in updates.items():
        bench.setdefault(name, {}).update(d)
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
    return bench


def _time(fn, *args, reps=3):
    fn(*args)                      # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def rows():
    out = []
    rng = np.random.RandomState(0)
    w, m = 8, 2048                      # 8 workers, 2048x128 plane (256 KiB)
    plane = jnp.asarray(rng.randn(m, 128), jnp.float32)
    stack = jnp.stack([K.pack_signs(jnp.asarray(rng.randn(m, 128),
                                                jnp.float32))
                       for _ in range(w)])
    counts = K.popcount_stack(stack)
    gate = K.ternary_gate_words(m)
    sw, mw = K.majority_decode(counts, num_workers=w, gate_words=gate)

    out.append(("datapath/pack_signs_256KiB", _time(K.pack_signs, plane),
                f"elements={m*128}"))
    out.append(("datapath/popcount_w8", _time(K.popcount_stack, stack),
                "W=8"))
    out.append(("datapath/majority_decode",
                _time(lambda c: K.majority_decode(c, num_workers=w,
                                                  gate_words=gate), counts),
                "ternary-gated"))
    out.append(("datapath/unpack_ternary", _time(K.unpack_ternary, sw, mw),
                ""))
    out.append(("datapath/apply_sign_update",
                _time(lambda p: K.apply_sign_update(p, sw, mw, 0.01), plane),
                "fused"))

    # Table 4 analogue: modeled exposure at the production operating point
    n = 8 << 20                      # 8M-element bucket
    model = ExposureModel()
    for sched, tag in ((Schedule.VOTE_PSUM, "vote_psum"),
                       (Schedule.PACKED_A2A, "packed_a2a")):
        wb = wire_bytes_per_device(n, AggregationMode.G_BINARY, sched, 32)
        r = model.exposed(n, 32, wb)
        out.append((f"exposure/{tag}", r["t_agg_s"] * 1e6,
                    f"exposed_pct={r['exposed_pct']:.2f} hidden={r['hidden']}"))

    # per-codec exposure + flit-pipeline timing: every registered codec
    # priced through its own wire model and sim lane descriptor
    from repro.fabric import available_codecs, get_codec
    from repro.sim import FlitPipeline
    pipe = FlitPipeline()
    for name in available_codecs():
        codec = get_codec(name)
        wire = codec.default_schedule if codec.reduction == "mean" \
            else Schedule.PACKED_A2A
        r = model.exposed_launch(n, 32, name, wire)
        t_pipe = pipe.t_agg(n, 32, name)
        out.append((f"exposure/codec/{name}", r["t_agg_s"] * 1e6,
                    f"wire={wire if isinstance(wire, str) else wire.value} "
                    f"exposed_pct={r['exposed_pct']:.2f} "
                    f"hidden={r['hidden']} "
                    f"flit_pipeline_us={t_pipe * 1e6:.1f} "
                    f"lane={pipe.lane(name).name}"))

    out.extend(fused_rows())

    # Fig 3 envelope sweep
    sweep = envelope_sweep()
    worst_a = max(sweep["a"], key=lambda r: r["exposed_pct"])
    out.append(("exposure/envelope_worst_a", worst_a["t_exposed_s"] * 1e6,
                f"link={worst_a['link_GBps']}GBps depth={worst_a['depth_mult']}x "
                f"exposed={worst_a['exposed_pct']:.2f}pct"))
    hidden_frac = np.mean([r["hidden"] for r in sweep["a"]])
    out.append(("exposure/envelope_hidden_fraction", 0.0,
                f"{hidden_frac:.2f} of (bw x depth) grid fully hidden"))
    d10 = [r for r in sweep["d"] if r["stale_steps"] == 10][0]
    out.append(("exposure/telemetry_staleness_10steps", 0.0,
                f"amortized_cost={d10['amortized_step_cost_pct']:.3f}pct"))
    out.extend(sim_rows())
    return out


def fused_rows():
    """Fused vs unfused datapath: measured wall time + modeled accounting.

    Wall time compares the staged interpret-mode chain (pack -> popcount
    -> majority -> unpack) against the single fused ``vote_pipeline``
    kernel on the same payload, and the staged int4 reference against
    the one-launch two-phase quant kernel.  The modeled section prices
    every registered codec that brings a :class:`KernelSet` — launch
    count and HBM bytes per 8M-element bucket at W=32, fused vs unfused
    — and merges the numbers into ``BENCH_codecs.json`` under each
    codec's ``fused_datapath`` key (the nightly gate asserts
    fused launches < unfused and fused HBM <= unfused there).
    """
    from repro.fabric import available_codecs, get_codec
    from repro.kernels import fused, ref

    out = []
    rng = np.random.RandomState(1)
    w, m = 8, 2048
    stack = jnp.asarray(rng.randn(w, m, 128), jnp.float32)
    gate = fused.local_gate_words(m // ref.PACK, ternary=True)

    def staged(s):
        words = jnp.stack([K.pack_signs(s[i], interpret=True)
                           for i in range(w)])
        counts = K.popcount_stack(words, interpret=True)
        sw, mw = K.majority_decode(counts, num_workers=w, gate_words=gate,
                                   interpret=True)
        return K.unpack_ternary(sw, mw, interpret=True)

    t_staged = _time(staged, stack)
    t_fused = _time(lambda s: fused.vote_pipeline(
        s, gate, num_workers=w, interpret=True), stack)
    out.append(("datapath/fused/vote_staged_4op", t_staged, "W=8 interpret"))
    out.append(("datapath/fused/vote_pipeline_1op", t_fused,
                f"W=8 interpret vs_staged={t_staged / t_fused:.2f}x "
                "(interpret-mode wall; the modeled rows are the perf claim)"))

    plane = jnp.asarray(rng.randn(m, 128), jnp.float32)
    t_ref = _time(jax.jit(ref.int4_quant_plane), plane)
    t_k = _time(lambda p: fused.int4_quant_plane(p, interpret=True), plane)
    out.append(("datapath/fused/int4_staged", t_ref, "jit ref"))
    out.append(("datapath/fused/int4_kernel_1op", t_k, "interpret"))

    # modeled per-bucket accounting, per codec kernel set
    n, W = 8 << 20, 32
    updates = {}
    for name in available_codecs():
        codec = get_codec(name)
        hook = getattr(codec, "pallas_kernels", None)
        ks = hook() if hook is not None else None
        if ks is None:
            continue
        ef = bool(codec.threads_ef)
        row = {"kernel_signature": ks.signature()}
        for path, is_fused in (("fused", True), ("unfused", False)):
            row[f"launches_{path}"] = ks.launches(fused=is_fused,
                                                  distributed=True, ef=ef)
            row[f"hbm_bytes_{path}"] = ks.hbm_bytes(
                n, num_workers=W, fused=is_fused, distributed=True, ef=ef)
        updates[name] = {"fused_datapath": row}
        out.append((f"datapath/fused/modeled/{name}",
                    float(row["launches_fused"]),
                    f"launches {row['launches_fused']}f vs "
                    f"{row['launches_unfused']}u, HBM/bucket "
                    f"{row['hbm_bytes_fused'] / 2**20:.1f}MiBf vs "
                    f"{row['hbm_bytes_unfused'] / 2**20:.1f}MiBu "
                    f"(n=8M W={W})"))
    merge_bench_json(BENCH_CODECS_JSON, updates)
    out.append(("datapath/fused/bench_json", 0.0,
                f"merged fused_datapath for {len(updates)} codecs into "
                f"{BENCH_CODECS_JSON}"))
    return out


def sim_rows():
    """Cycle-level simulator cross-check of the analytic exposure model.

    (The paper's operating-point scenarios live in ``bench_sim`` — not
    duplicated here, so every ``sim/*`` metric name is emitted once per
    full run.)
    """
    from repro.core.traffic import IciModel
    from repro.sim import LaunchSpec, simulate_launches

    # degenerate single-launch agreement: sim vs closed-form exposure
    n, w, wb = 8 << 20, 32, 1024.0        # cheap collective -> exposed
    model = ExposureModel()
    ref = model.exposed(n, w, wb)
    spec = LaunchSpec("agree", AggregationMode.G_BINARY, "vote_psum", n, wb)
    rep = simulate_launches(
        [spec], w, topology="ici_ring", datapath=model.datapath,
        ici=IciModel(link_bytes_per_s=model.link_bw, hop_latency_s=0.0,
                     launch_overhead_s=0.0))
    delta = abs(rep.launches[0].exposed_s - ref["t_exposed_s"])
    rel = delta / ref["t_exposed_s"] if ref["t_exposed_s"] else 0.0
    return [("sim/analytic_agreement", rep.launches[0].exposed_s * 1e6,
             f"rel_delta={rel:.2e} (tolerance 1e-2)")]
