"""Atomic, retained, reshard-on-load checkpointing.

Fault-tolerance contract (what the node-failure / elastic tests exercise):

  * **Atomicity** — a checkpoint is written to ``step_<k>.tmp`` and renamed
    to ``step_<k>`` only when complete; a crash mid-save never corrupts the
    restore path (the previous step remains the latest valid one).
  * **Retention** — keep the last ``keep`` checkpoints; older ones deleted
    only after a newer one is durable.
  * **Reshard-on-load** — leaves are stored device-layout-free (host
    ndarrays + a tree manifest); restore takes *target* shardings, so a
    job can restart on a different mesh shape (elastic scaling) or a
    different DP degree and GSPMD re-lays the state out.
  * **Async save** — serialization runs on a background thread so the
    training loop overlaps checkpoint I/O with compute; ``wait()`` fences.
  * **Controller threading** — pass ``controller=`` to
    :meth:`CheckpointManager.maybe_save` / :meth:`CheckpointManager.restore`
    and the admission controller's ``state_dict()`` rides in the manifest's
    ``extra`` (JSON) and is loaded back on restore, so CUSUM statistics,
    Supervisor cooldown, and the admitted plan survive failure recovery.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, arrs = [], []
    for kp, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in kp)
        names.append(name)
        arrs.append(leaf)
    return names, arrs, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Write one atomic checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, arrs, _ = _flatten_with_names(tree)
    host = [np.asarray(a) for a in arrs]          # device -> host, any sharding
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": h for i, h in enumerate(host)})
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [str(h.dtype) for h in host],
        "shapes": [list(h.shape) for h in host],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                          # atomic publish

    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def _latest_dir(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore_latest(directory: str, target_tree: Any,
                   target_shardings: Any | None = None):
    """Restore the newest checkpoint into ``target_tree``'s structure.

    ``target_shardings``: optional pytree of jax.sharding.Sharding — arrays
    are placed directly into the (possibly different) target layout, which
    is what makes mesh-shape changes across restarts work.
    Returns (step, tree, extra) or None if no checkpoint exists.
    """
    path = _latest_dir(directory)
    if path is None:
        return None
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    arrs = [data[f"a{i}"] for i in range(len(manifest["names"]))]

    _, t_leaves, treedef = _flatten_with_names(target_tree)
    assert len(t_leaves) == len(arrs), (
        f"checkpoint has {len(arrs)} leaves, target has {len(t_leaves)}")
    if target_shardings is not None:
        s_leaves = treedef.flatten_up_to(target_shardings)
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, s_leaves)]
    else:
        arrs = [jax.device_put(a) for a in arrs]
    tree = jax.tree_util.tree_unflatten(treedef, arrs)
    return manifest["step"], tree, manifest.get("extra", {})


class CheckpointManager:
    """Async wrapper with save-interval policy and restart counting."""

    def __init__(self, directory: str, *, interval: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.saves = 0

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None,
                   force: bool = False, controller: Any = None) -> bool:
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        if controller is not None and hasattr(controller, "state_dict"):
            extra = dict(extra or {})
            extra["controller"] = {
                "name": getattr(controller, "name",
                                type(controller).__name__),
                "state": controller.state_dict()}
        self.wait()
        # snapshot to host synchronously (cheap vs serialization) so the
        # trainer can mutate state while the writer thread works
        names, arrs, _ = _flatten_with_names(tree)
        host = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            save_checkpoint(self.directory, step, host, extra=extra,
                            keep=self.keep)

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
        self.saves += 1
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, target_tree: Any, target_shardings: Any | None = None,
                controller: Any = None):
        self.wait()
        restored = restore_latest(self.directory, target_tree,
                                  target_shardings)
        if (restored is not None and controller is not None
                and hasattr(controller, "load_state_dict")):
            blob = (restored[2] or {}).get("controller")
            if blob is not None:
                saved = blob.get("name")
                mine = getattr(controller, "name", type(controller).__name__)
                if saved is not None and saved != mine:
                    # resuming under a different policy is a legitimate
                    # operator choice — keep the fresh controller rather
                    # than feeding it a foreign state dict
                    logging.getLogger("repro.checkpoint").warning(
                        "checkpoint carries %r controller state; active "
                        "controller is %r — controller state not restored",
                        saved, mine)
                else:
                    controller.load_state_dict(blob["state"])
        return restored
