"""Fault-tolerant checkpointing (atomic, retained, reshard-on-load)."""
from .manager import CheckpointManager, restore_latest, save_checkpoint

__all__ = ["CheckpointManager", "restore_latest", "save_checkpoint"]
