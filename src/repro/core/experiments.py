"""Virtual-worker convergence experiments (paper Sections 7 and 8).

The paper's protocol: split each minibatch into W=8 virtual workers, apply
the selected aggregation rule to the per-worker gradients, and feed the
aggregate to an unmodified optimizer.  This module provides that harness on
synthetic cluster-classification tasks whose difficulty knob reproduces the
paper's regimes: the easy task (CIFAR-10 analogue) tolerates full-path
low-bit aggregation, the fine-grained hard task (CIFAR-100 analogue)
rejects it, and layer-aware admission (low-bit backbone + FP32 head)
recovers most of the gap — the paper's central boundary result.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data import ClassificationTask, make_cluster_task
from .buckets import AdmissionPlan, GroupRules
from .diagnostics import group_cosines_from_workers
from .modes import AggregationMode
from .traffic import plan_traffic_ratio


# ---------------------------------------------------------------------------
# small MLP classifier (backbone + head, mirroring the paper's split)
# ---------------------------------------------------------------------------

def init_mlp(key, dim: int, hidden: int, classes: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, a, b: jax.random.normal(k, (a, b)) * (1.0 / np.sqrt(a))
    return {
        "backbone": {"w1": s(k1, dim, hidden), "b1": jnp.zeros(hidden),
                     "w2": s(k2, hidden, hidden), "b2": jnp.zeros(hidden)},
        "head": {"w": s(k3, hidden, classes), "b": jnp.zeros(classes)},
    }


def mlp_logits(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ p["backbone"]["w1"] + p["backbone"]["b1"])
    h = jax.nn.relu(h @ p["backbone"]["w2"] + p["backbone"]["b2"])
    return h @ p["head"]["w"] + p["head"]["b"]


def _ce(p, x, y):
    lg = mlp_logits(p, x)
    return jnp.mean(jax.scipy.special.logsumexp(lg, -1)
                    - jnp.take_along_axis(lg, y[:, None], 1)[:, 0])


# ---------------------------------------------------------------------------
# aggregation rules over stacked worker grads (host-side, W small)
# ---------------------------------------------------------------------------

def agg_fp32(g):
    return jnp.mean(g, axis=0)


def agg_gbinary(g):
    w = g.shape[0]
    return jnp.sign(2 * jnp.sum((g > 0), axis=0).astype(jnp.float32) - w)


def agg_gternary(g):
    u = agg_gbinary(g)
    n = u.size
    gate = ((jnp.arange(n) % 3) != 2).astype(jnp.float32).reshape(u.shape)
    return u * gate


def agg_majority_sign(g):
    """MajoritySignSGD: communication-comparable software baseline."""
    return agg_gbinary(g)


def agg_sign_of_mean(g):
    """SignOfMean: sign after the FP32 mean (optimizer reference)."""
    return jnp.sign(jnp.mean(g, axis=0))


RULES: dict[str, Callable] = {
    "fp32": agg_fp32,
    "gbinary": agg_gbinary,
    "gternary": agg_gternary,
    "majority_sign_sgd": agg_majority_sign,
    "sign_of_mean": agg_sign_of_mean,
}

#: paper-tuned learning rates: FP32-scale for mean updates, small for sign
LR = {"fp32": 0.08, "gbinary": 5e-4, "gternary": 5e-4,
      "majority_sign_sgd": 5e-4, "sign_of_mean": 5e-4}


@dataclasses.dataclass
class RunResult:
    policy: str
    final_acc: float
    traffic_ratio: float
    losses: list
    cosines: Optional[dict] = None


def run_training(task: ClassificationTask, *, policy: str = "fp32",
                 head_policy: Optional[str] = None, steps: int = 400,
                 batch: int = 256, workers: int = 8, hidden: int = 256,
                 seed: int = 0, lr: Optional[float] = None,
                 momentum: float = 0.9, diagnose_at: Optional[int] = None,
                 degrade: Optional[tuple] = None, warmup_fp32: int = 50,
                 plan_callback: Optional[Callable] = None,
                 program=None) -> RunResult:
    """One training run under a (backbone, head) aggregation policy.

    ``policy`` applies to the backbone; ``head_policy`` (default = policy)
    to the classifier head — 'fp32' head + low-bit backbone is the paper's
    layer-aware operating point.  Every run begins with ``warmup_fp32``
    FP32 steps (paper Section 3: "Training begins on the FP32 bypass path")
    before the selected policy is admitted; the warm-up phase is a
    :class:`repro.fabric.control.PolicyProgram` latching ``(backbone,
    head)`` rule-name pairs, and ``program=`` may replace it with any
    user-defined phase schedule (e.g. "head on FP32 after step N" via
    ``PolicyProgram.staged``).  ``plan_callback(step, loss)`` may return
    a (backbone, head) pair to change the policy online (control-plane
    pilots).  ``degrade=(t0, t1)`` injects a gradient-corruption window.
    """
    # control vocabulary lives in the fabric layer; imported lazily so
    # `repro.core` stays importable standalone (no cycle: fabric.control
    # imports core.admission/buckets, never this module)
    from ..fabric.control import Phase, PolicyProgram, Telemetry

    head_policy = head_policy or policy
    params = init_mlp(jax.random.PRNGKey(seed), task.dim, hidden,
                      task.num_classes)
    vel = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def worker_grads(p, xs, ys):
        return jax.vmap(lambda x, y: jax.grad(_ce)(p, x, y))(xs, ys)

    losses, cosines = [], None
    cur = {"plan": (policy, head_policy)}   # live latch payload
    user_program = program is not None
    if program is None:
        program = PolicyProgram([
            Phase("warmup", plan=("fp32", "fp32"),
                  transition=lambda t, p: ("admit" if t.step >= warmup_fp32
                                           else None)),
            Phase("admit", plan=lambda t, p: cur["plan"], latch=False),
        ])
    data = task.batches(batch, seed_offset=seed * 1000)
    rng_eval = np.random.RandomState(seed + 777)
    xe, ye = task.sample(rng_eval, 2048)

    lr_b = lr if lr is not None else LR[policy]
    lr_h = lr if lr is not None else LR[head_policy]

    traffic_acc = 0.0
    for step in range(steps):
        x, y = next(data)
        xs = x.reshape(workers, batch // workers, -1)
        ys = y.reshape(workers, batch // workers)
        g = worker_grads(params, jnp.asarray(xs), jnp.asarray(ys))
        if degrade and degrade[0] <= step < degrade[1]:
            g = jax.tree.map(
                lambda a: a + 5.0 * jax.random.normal(
                    jax.random.PRNGKey(step), a.shape), g)

        loss = float(_ce(params, jnp.asarray(x), jnp.asarray(y)))
        losses.append(loss)

        if plan_callback is not None:
            nxt = plan_callback(step, loss)
            if nxt is not None:
                cur["plan"] = tuple(nxt)
        active = tuple(program.advance(Telemetry(step=step, loss=loss)))
        bb_rule, hd_rule = RULES[active[0]], RULES[active[1]]

        if diagnose_at is not None and step == diagnose_at:
            groups = {"backbone": jax.tree.map(lambda _: "backbone",
                                               params["backbone"]),
                      "head": jax.tree.map(lambda _: "head", params["head"])}
            cosines = {k: {m: float(v) for m, v in d.items()}
                       for k, d in group_cosines_from_workers(
                           g, groups).items()}

        agg = {"backbone": jax.tree.map(bb_rule, g["backbone"]),
               "head": jax.tree.map(hd_rule, g["head"])}
        del bb_rule, hd_rule
        bits = {"fp32": 32.0, "gbinary": 1.0, "gternary": np.log2(3.0),
                "majority_sign_sgd": 1.0, "sign_of_mean": 32.0}
        nb = sum(x.size for x in jax.tree.leaves(params["backbone"]))
        nh = sum(x.size for x in jax.tree.leaves(params["head"]))
        traffic_acc += (nb * bits[active[0]] + nh * bits[active[1]]) \
            / (32.0 * (nb + nh))

        def upd(p, v, a, lr_):
            v = momentum * v + a
            return p - lr_ * v, v
        lr_b_now = LR["fp32"] if active[0] == "fp32" and lr is None else lr_b
        lr_h_now = LR["fp32"] if active[1] == "fp32" and lr is None else lr_h
        for grp, lr_ in (("backbone", lr_b_now), ("head", lr_h_now)):
            new = jax.tree.map(lambda p, v, a: upd(p, v, a, lr_),
                               params[grp], vel[grp], agg[grp])
            params[grp] = jax.tree.map(lambda t: t[0], new,
                                       is_leaf=lambda x: isinstance(x, tuple))
            vel[grp] = jax.tree.map(lambda t: t[1], new,
                                    is_leaf=lambda x: isinstance(x, tuple))

    acc = float(jnp.mean(jnp.argmax(
        mlp_logits(params, jnp.asarray(xe)), -1) == jnp.asarray(ye)))
    # label what actually ran: a user-supplied program owns the latch, so
    # its final plan names the operating point, not the policy arguments
    bb, hd = tuple(program.plan) if user_program else cur["plan"]
    return RunResult(policy=f"{bb}+{hd}head", final_acc=acc,
                     traffic_ratio=traffic_acc / steps, losses=losses,
                     cosines=cosines)


def easy_task(seed: int = 0) -> ClassificationTask:
    """CIFAR-10 analogue: 10 well-separated classes."""
    return make_cluster_task(10, dim=64, hard=False, seed=seed)


def hard_task(seed: int = 0) -> ClassificationTask:
    """CIFAR-100 analogue: 100 fine-grained hierarchical classes."""
    return make_cluster_task(100, dim=64, hard=True, seed=seed)
