"""Gradient bucket manager: parameter groups -> admitted aggregation policies.

The paper's controller operates on *buckets* (Section 5.2 replays 32 MiB
gradient buckets) and its admission decisions are *layer-group* granular
(Section 7.3: backbone vs classifier head).  This module owns that mapping:

  parameter tree --(GroupRules)--> named groups --(AdmissionPlan)--> modes
                 --(resolve_policies)--> per-leaf LeafPolicy pytree

Groups also drive the traffic accounting and the cosine-alignment
diagnostics, so the three views (admission, traffic, diagnostics) always
agree on what "the head" or "the backbone" is.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

from .lowbit import LeafPolicy
from .modes import (AggregationMode, DEFAULT_SCHEDULE, Schedule,
                    schedule_name)


def path_name(key_path) -> str:
    """jax tree key path -> '/'-joined name, e.g. 'layers/3/attn/wq'."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class GroupRules:
    """Ordered (regex, group) rules; first match wins, default 'backbone'.

    The default rules encode the paper's sensitivity findings plus the
    standard production conventions: the classifier / LM head and anything
    scale-like (norms, biases) stay out of the low-bit backbone group, and
    MoE routers are treated as head-like (their gradients are tiny but
    decision-critical — see DESIGN.md §Arch-applicability).
    """
    rules: tuple = (
        (r"(^|/)(head|lm_head|classifier|logits)(/|$)", "head"),
        (r"(^|/)(router|gate_weights?)(/|$)", "head"),
        (r"(norm|bias|scale|ln_|layernorm)", "norms"),
        (r"(embed|wte|wpe|patch_proj|frontend)", "embed"),
    )
    default: str = "backbone"

    def group_of(self, name: str) -> str:
        for pattern, group in self.rules:
            if re.search(pattern, name):
                return group
        return self.default


def assign_groups(params: Any, rules: GroupRules | None = None) -> Any:
    """Params pytree -> pytree of group-name strings (same structure)."""
    rules = rules or GroupRules()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    groups = [rules.group_of(path_name(kp)) for kp, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, groups)


def group_sizes(params: Any, rules: GroupRules | None = None) -> dict[str, int]:
    """Element counts per group (drives traffic accounting)."""
    rules = rules or GroupRules()
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    out: dict[str, int] = {}
    for kp, leaf in leaves:
        g = rules.group_of(path_name(kp))
        out[g] = out.get(g, 0) + int(leaf.size)
    return out


@dataclasses.dataclass(frozen=True)
class GroupPolicy:
    """Mode + schedule + EF flag for one parameter group.

    ``schedule`` may be a built-in :class:`Schedule`, the string name of
    any backend registered via ``repro.fabric.register_schedule``, or
    None for the mode default.
    """
    mode: AggregationMode = AggregationMode.FP32
    schedule: Schedule | str | None = None    # None -> mode default
    error_feedback: bool = False

    def resolved_schedule(self) -> Schedule | str:
        return self.schedule or DEFAULT_SCHEDULE[self.mode]


@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """Controller-visible mode latch: group name -> GroupPolicy.

    Immutable and hashable: the training runtime compiles one train_step per
    plan signature and caches it (the XLA analogue of writing the paper's
    mode latch — plans change at phase granularity, not per step).
    """
    policies: tuple = ()                      # tuple[(group, GroupPolicy)]
    default: GroupPolicy = GroupPolicy()

    @staticmethod
    def from_dict(d: Mapping[str, GroupPolicy],
                  default: GroupPolicy | None = None) -> "AdmissionPlan":
        return AdmissionPlan(policies=tuple(sorted(d.items())),
                             default=default or GroupPolicy())

    def policy_for(self, group: str) -> GroupPolicy:
        for g, pol in self.policies:
            if g == group:
                return pol
        return self.default

    def signature(self) -> str:
        items = [f"{g}:{p.mode.value}:{schedule_name(p.resolved_schedule())}"
                 f":{int(p.error_feedback)}" for g, p in self.policies]
        d = self.default
        items.append(f"*:{d.mode.value}:{schedule_name(d.resolved_schedule())}"
                     f":{int(d.error_feedback)}")
        return "|".join(items)

    # ---- canonical plans from the paper -------------------------------
    @staticmethod
    def fp32_all() -> "AdmissionPlan":
        return AdmissionPlan(default=GroupPolicy(AggregationMode.FP32))

    @staticmethod
    def lowbit_all(mode: AggregationMode = AggregationMode.G_BINARY,
                   schedule: Schedule | str | None = None,
                   error_feedback: bool = False) -> "AdmissionPlan":
        """'Full-path' low-bit: the configuration CIFAR-100 rejects."""
        return AdmissionPlan(default=GroupPolicy(mode, schedule, error_feedback))

    @staticmethod
    def lowbit_backbone(mode: AggregationMode = AggregationMode.G_BINARY,
                        schedule: Schedule | str | None = None,
                        error_feedback: bool = False) -> "AdmissionPlan":
        """The paper's recovered operating point: low-bit backbone, FP32 head
        (and FP32 for norms/embeddings/routers)."""
        return AdmissionPlan.from_dict(
            {"backbone": GroupPolicy(mode, schedule, error_feedback)},
            default=GroupPolicy(AggregationMode.FP32))


def resolve_policies(params: Any, plan: AdmissionPlan,
                     pspecs: Any | None = None,
                     rules: GroupRules | None = None) -> Any:
    """Params (+ optional PartitionSpec tree) -> LeafPolicy pytree.

    ``pspecs`` supplies each leaf's tensor-parallel PartitionSpec so the
    packed_a2a schedule can localize TP-sharded leaves via an inner
    shard_map.
    """
    rules = rules or GroupRules()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    if pspecs is None:
        spec_leaves = [None] * len(leaves)
    else:
        spec_leaves = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)[0]
    assert len(spec_leaves) == len(leaves), (
        f"pspec tree mismatch: {len(spec_leaves)} specs vs {len(leaves)} leaves")
    policies = []
    for (kp, _), spec in zip(leaves, spec_leaves):
        gp = plan.policy_for(rules.group_of(path_name(kp)))
        policies.append(LeafPolicy(
            mode=gp.mode, schedule=gp.resolved_schedule(), model_spec=spec,
            error_feedback=gp.error_feedback))
    return jax.tree_util.tree_unflatten(treedef, policies)
