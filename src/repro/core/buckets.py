"""Gradient bucket manager: groups, admission policies, and bucket layouts.

The paper's controller operates on *buckets* (Section 5.2 replays 32 MiB
gradient buckets) and its admission decisions are *layer-group* granular
(Section 7.3: backbone vs classifier head).  This module owns that mapping:

  parameter tree --(GroupRules)--> named groups --(AdmissionPlan)--> modes
                 --(resolve_policies)--> per-leaf LeafPolicy pytree
                 --(plan_buckets)------> BucketLayout (fused flat buckets)

Groups also drive the traffic accounting and the cosine-alignment
diagnostics, so the three views (admission, traffic, diagnostics) always
agree on what "the head" or "the backbone" is.

The :class:`BucketLayout` planner is the fusion seam: compatible leaves
(same mode / wire schedule / error-feedback flag / gate phase / TP spec /
dtype) are concatenated into fixed-budget flat buckets (default 32 MiB,
matching the paper's bucket size) so the fabric runs **one** collective
per bucket instead of one per leaf.  The layout is a pure function of
(leaf order, shapes, dtypes, policies, bucket_bytes), so it is stable
across steps and safe to cache alongside a compiled train step.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Iterator, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .lowbit import LeafPolicy
from .modes import (AggregationMode, Schedule, canonical_mode, codec_name,
                    schedule_name, wire_schedule)


def _codec(mode):
    """Resolve a codec lazily (keeps ``core`` importable without fabric)."""
    from ..fabric.codecs import get_codec
    return get_codec(mode)


def path_name(key_path) -> str:
    """jax tree key path -> '/'-joined name, e.g. 'layers/3/attn/wq'."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class GroupRules:
    """Ordered (regex, group) rules; first match wins, default 'backbone'.

    The default rules encode the paper's sensitivity findings plus the
    standard production conventions: the classifier / LM head and anything
    scale-like (norms, biases) stay out of the low-bit backbone group, and
    MoE routers are treated as head-like (their gradients are tiny but
    decision-critical — see DESIGN.md §Arch-applicability).
    """
    rules: tuple = (
        (r"(^|/)(head|lm_head|classifier|logits)(/|$)", "head"),
        (r"(^|/)(router|gate_weights?)(/|$)", "head"),
        (r"(norm|bias|scale|ln_|layernorm)", "norms"),
        (r"(embed|wte|wpe|patch_proj|frontend)", "embed"),
    )
    default: str = "backbone"

    def group_of(self, name: str) -> str:
        for pattern, group in self.rules:
            if re.search(pattern, name):
                return group
        return self.default


def assign_groups(params: Any, rules: GroupRules | None = None) -> Any:
    """Params pytree -> pytree of group-name strings (same structure)."""
    rules = rules or GroupRules()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    groups = [rules.group_of(path_name(kp)) for kp, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, groups)


def group_sizes(params: Any, rules: GroupRules | None = None) -> dict[str, int]:
    """Element counts per group (drives traffic accounting)."""
    rules = rules or GroupRules()
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    out: dict[str, int] = {}
    for kp, leaf in leaves:
        g = rules.group_of(path_name(kp))
        out[g] = out.get(g, 0) + int(leaf.size)
    return out


@dataclasses.dataclass(frozen=True)
class GroupPolicy:
    """Codec + schedule + EF flag for one parameter group.

    ``mode`` names the gradient codec: a built-in
    :class:`AggregationMode` member or the string name of any codec
    registered via ``repro.fabric.register_codec``.  ``schedule`` may be
    a built-in :class:`Schedule`, the string name of any backend
    registered via ``repro.fabric.register_schedule``, or None for the
    codec's default transport.
    """
    mode: AggregationMode | str = AggregationMode.FP32
    schedule: Schedule | str | None = None    # None -> codec default
    error_feedback: bool = False

    def resolved_schedule(self) -> Schedule | str:
        return self.schedule or _codec(self.mode).default_schedule


@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """Controller-visible mode latch: group name -> GroupPolicy.

    Immutable and hashable: the training runtime compiles one train_step per
    plan signature and caches it (the XLA analogue of writing the paper's
    mode latch — plans change at phase granularity, not per step).
    """
    policies: tuple = ()                      # tuple[(group, GroupPolicy)]
    default: GroupPolicy = GroupPolicy()

    @staticmethod
    def from_dict(d: Mapping[str, GroupPolicy],
                  default: GroupPolicy | None = None) -> "AdmissionPlan":
        return AdmissionPlan(policies=tuple(sorted(d.items())),
                             default=default or GroupPolicy())

    def policy_for(self, group: str) -> GroupPolicy:
        for g, pol in self.policies:
            if g == group:
                return pol
        return self.default

    def signature(self) -> str:
        items = [f"{g}:{codec_name(p.mode)}"
                 f":{schedule_name(p.resolved_schedule())}"
                 f":{int(p.error_feedback)}" for g, p in self.policies]
        d = self.default
        items.append(f"*:{codec_name(d.mode)}"
                     f":{schedule_name(d.resolved_schedule())}"
                     f":{int(d.error_feedback)}")
        return "|".join(items)

    # ---- canonical plans from the paper -------------------------------
    @staticmethod
    def fp32_all() -> "AdmissionPlan":
        return AdmissionPlan(default=GroupPolicy(AggregationMode.FP32))

    @staticmethod
    def lowbit_all(mode: AggregationMode | str = AggregationMode.G_BINARY,
                   schedule: Schedule | str | None = None,
                   error_feedback: bool = False) -> "AdmissionPlan":
        """'Full-path' low-bit: the configuration CIFAR-100 rejects."""
        return AdmissionPlan(default=GroupPolicy(mode, schedule, error_feedback))

    @staticmethod
    def lowbit_backbone(mode: AggregationMode | str = AggregationMode.G_BINARY,
                        schedule: Schedule | str | None = None,
                        error_feedback: bool = False) -> "AdmissionPlan":
        """The paper's recovered operating point: low-bit backbone, FP32 head
        (and FP32 for norms/embeddings/routers)."""
        return AdmissionPlan.from_dict(
            {"backbone": GroupPolicy(mode, schedule, error_feedback)},
            default=GroupPolicy(AggregationMode.FP32))


def resolve_policies(params: Any, plan: AdmissionPlan,
                     pspecs: Any | None = None,
                     rules: GroupRules | None = None) -> Any:
    """Params (+ optional PartitionSpec tree) -> LeafPolicy pytree.

    ``pspecs`` supplies each leaf's tensor-parallel PartitionSpec so the
    packed_a2a schedule can localize TP-sharded leaves via an inner
    shard_map.
    """
    rules = rules or GroupRules()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    if pspecs is None:
        spec_leaves = [None] * len(leaves)
    else:
        spec_leaves = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)[0]
    assert len(spec_leaves) == len(leaves), (
        f"pspec tree mismatch: {len(spec_leaves)} specs vs {len(leaves)} leaves")
    policies = []
    for (kp, _), spec in zip(leaves, spec_leaves):
        gp = plan.policy_for(rules.group_of(path_name(kp)))
        policies.append(LeafPolicy(
            mode=gp.mode, schedule=gp.resolved_schedule(), model_spec=spec,
            error_feedback=gp.error_feedback))
    return jax.tree_util.tree_unflatten(treedef, policies)


# ---------------------------------------------------------------------------
# bucket layout planner (paper Section 5.2: fixed-size gradient buckets)
# ---------------------------------------------------------------------------

#: Default flat-bucket payload budget; the paper's controller replays
#: 32 MiB gradient buckets (Section 5.2).
DEFAULT_BUCKET_BYTES = 32 * 2 ** 20

_is_policy = lambda x: hasattr(x, "mode") and hasattr(x, "schedule")


def _trivial_spec(spec) -> bool:
    """True when a model PartitionSpec implies a fully local leaf."""
    return spec is None or all(a is None for a in spec)


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Fusion-compatibility key: leaves may share a bucket iff equal.

    ``mode`` is the canonical codec name (built-in codecs keep their
    :class:`AggregationMode` member for stable reprs/hashes; registered
    codecs are plain strings).  ``schedule`` is the *wire* schedule name
    (post :func:`~repro.core.modes.wire_schedule` normalization), so
    e.g. an FP32 leaf nominally planned on ``packed_a2a`` fuses with
    plain ``psum`` leaves — exactly the collective the per-leaf path
    would have launched.  ``model_spec`` is None for fully local leaves;
    TP-sharded leaves keep their spec (and are never fused).  ``hops``
    is the codec's hop-plan signature (None for flat codecs), so buckets
    never mix hierarchical routes — two plans over the same backbone
    codec still launch separately.
    """
    mode: AggregationMode | str
    schedule: str
    error_feedback: bool
    gate_phase: int
    model_spec: Any
    dtype: str
    hops: str | None = None


@dataclasses.dataclass(frozen=True)
class BucketSlot:
    """One leaf's placement inside a bucket's flat payload."""
    leaf: int                   # index into the flattened gradient tree
    name: str                   # '/'-joined tree path (debugging / reports)
    shape: tuple
    size: int                   # element count
    offset: int                 # start offset in the bucket's flat payload


@dataclasses.dataclass(frozen=True)
class BucketGate:
    """Per-bucket ternary zero gate, as (size, phase) leaf segments.

    The 2-of-3 gate is defined over each *leaf's own* flat index (paper
    Section 2), so the bucket gate is the concatenation of per-leaf
    patterns — this is what keeps the fused ternary path bit-identical
    to per-leaf aggregation.  Backends pick the representation:
    :meth:`vector` builds it on device (iota + mod — no multi-MB host
    constant in the compiled step) for elementwise schedules;
    :meth:`mask` materializes the host boolean array the packed-word
    schedules need for gate-word packing (1 bit/element once packed).
    """
    segments: tuple             # ((n_elements, phase), ...) per leaf

    def mask(self) -> np.ndarray:
        return np.concatenate(
            [(((np.arange(n) + p) % 3) != 2) for n, p in self.segments])

    def vector(self, dtype) -> Any:
        parts = [(((jnp.arange(n) + p) % 3) != 2).astype(dtype)
                 for n, p in self.segments]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A group of compatible leaves aggregated by one fused collective."""
    key: BucketKey
    slots: tuple
    size: int                   # total elements in the flat payload

    def gate(self) -> BucketGate | None:
        """The bucket's zero gate (from its codec), None when ungated."""
        return _codec(self.key.mode).bucket_gate(self)


@dataclasses.dataclass(frozen=True)
class UnfusedLeaf:
    """A leaf aggregated per-leaf (TP-sharded or non-fusable backend)."""
    leaf: int
    name: str
    key: BucketKey
    size: int


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Leaf -> (bucket, offset) assignment for one (tree, policies) pair.

    Deterministic in its inputs (leaf order, shapes, dtypes, policies,
    ``bucket_bytes``), hence stable across steps: a layout computed once
    at trace time is valid for every step compiled from the same plan.
    """
    buckets: tuple              # tuple[Bucket]
    unfused: tuple              # tuple[UnfusedLeaf]
    num_leaves: int
    bucket_bytes: int

    @property
    def num_launches(self) -> int:
        """Collectives per aggregation pass: O(buckets), not O(leaves)."""
        return len(self.buckets) + len(self.unfused)

    def launches(self) -> Iterator[tuple]:
        """Yield ``(BucketKey, n_elements)`` per collective launch."""
        for b in self.buckets:
            yield b.key, b.size
        for u in self.unfused:
            yield u.key, u.size


def leaf_bucket_key(policy, dtype) -> BucketKey:
    """Compatibility key for one leaf under its resolved policy."""
    mode = canonical_mode(policy.mode)
    wire = wire_schedule(policy.mode, policy.schedule)
    spec = getattr(policy, "model_spec", None)
    # only gated codecs read the gate phase; normalizing it for every
    # other codec keeps otherwise-compatible leaves in the same bucket
    phase = (int(getattr(policy, "gate_phase", 0))
             if _codec(mode).gated else 0)
    return BucketKey(
        mode=mode, schedule=wire,
        error_feedback=bool(policy.error_feedback),
        gate_phase=phase,
        model_spec=None if _trivial_spec(spec) else spec,
        dtype=str(np.dtype(dtype)),
        hops=getattr(_codec(mode), "hop_signature", None))


def plan_buckets(params_like: Any, policies: Any, *,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 fusable: Callable[[str], bool] | None = None) -> BucketLayout:
    """Group gradient leaves into fixed-budget flat buckets.

    ``params_like`` may hold concrete arrays or abstract
    ShapeDtypeStructs — only shapes/dtypes are read.  ``fusable`` is an
    optional predicate on the wire-schedule name (the Fabric session
    passes one that checks the backend's ``fusable`` flag); leaves whose
    schedule fails it, or that are TP-sharded (non-trivial
    ``model_spec``), stay on the per-leaf path as :class:`UnfusedLeaf`.

    Greedy first-fit in leaf order: a bucket closes when adding the next
    leaf would exceed ``bucket_bytes``; a single leaf larger than the
    budget gets a bucket of its own.  Pass ``bucket_bytes=1`` to obtain
    the degenerate one-leaf-per-bucket layout (the per-leaf baseline for
    launch accounting).
    """
    leaves, _ = jax.tree_util.tree_flatten_with_path(params_like)
    pol_leaves = jax.tree_util.tree_flatten(policies, is_leaf=_is_policy)[0]
    assert len(pol_leaves) == len(leaves), (
        f"policy tree mismatch: {len(pol_leaves)} policies vs "
        f"{len(leaves)} leaves")

    open_buckets: dict[BucketKey, list] = {}     # key -> [slots, elems]
    done: list[Bucket] = []
    unfused: list[UnfusedLeaf] = []

    def close(key):
        slots, elems = open_buckets.pop(key)
        done.append(Bucket(key=key, slots=tuple(slots), size=elems))

    for i, ((kp, leaf), pol) in enumerate(zip(leaves, pol_leaves)):
        name = path_name(kp)
        shape = tuple(leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        key = leaf_bucket_key(pol, leaf.dtype)
        ok = key.model_spec is None and (fusable is None
                                         or fusable(key.schedule))
        if not ok:
            unfused.append(UnfusedLeaf(leaf=i, name=name, key=key, size=size))
            continue
        budget = max(1, bucket_bytes // np.dtype(leaf.dtype).itemsize)
        if key in open_buckets and open_buckets[key][1] + size > budget:
            close(key)
        slots, elems = open_buckets.setdefault(key, [[], 0])
        slots.append(BucketSlot(leaf=i, name=name, shape=shape, size=size,
                                offset=open_buckets[key][1]))
        open_buckets[key][1] += size
    for key in list(open_buckets):
        close(key)
    # deterministic order: by first leaf index, independent of dict history
    done.sort(key=lambda b: b.slots[0].leaf)
    return BucketLayout(buckets=tuple(done), unfused=tuple(unfused),
                        num_leaves=len(leaves), bucket_bytes=bucket_bytes)
