"""Layer-wise low-bit/FP32 cosine-alignment diagnostics (paper Table 5).

During FP32 calibration steps, both aggregates are available almost for
free: the FP32 mean gradient (being used for the actual update) and the
low-bit direction it *would* have produced.  The cosine between them,
accumulated per layer group, is the admission signal: values near 1 mean
the low-bit signal preserves the update direction, values near 0 mean it is
nearly orthogonal (the paper measures 0.17 for the CIFAR-100 classifier
head vs 0.72 for the backbone at epoch 20).
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from .buckets import GroupRules, assign_groups
from .lowbit import _flat_index_gate


def _cos(u: jax.Array, v: jax.Array, eps: float = 1e-12) -> jax.Array:
    num = jnp.sum(u * v)
    den = jnp.sqrt(jnp.sum(u * u)) * jnp.sqrt(jnp.sum(v * v)) + eps
    return num / den


def group_cosines_from_mean(grads_mean: Any, groups: Any,
                            gate_phase: int = 0) -> dict:
    """Per-group cosine between FP32 mean aggregate and its low-bit image.

    ``grads_mean`` is the already-aggregated FP32 mean gradient tree (what
    the calibration step computes anyway); the G-Binary image of the *mean*
    is ``sign(mean)``, which equals the majority direction when workers
    agree and is the controller-visible proxy during FP32 phases.  Jittable;
    returns {group: {'gbinary': cos, 'gternary': cos}} of scalars.
    """
    leaves, _ = jax.tree_util.tree_flatten(grads_mean)
    group_leaves, _ = jax.tree_util.tree_flatten(groups)
    acc: dict[str, dict[str, list]] = {}
    for leaf, group in zip(leaves, group_leaves):
        g = leaf.astype(jnp.float32).reshape(-1)
        ubin = jnp.sign(g)
        uter = ubin * _flat_index_gate(g.shape, gate_phase)
        d = acc.setdefault(group, {"num_b": [], "num_t": [],
                                   "gg": [], "bb": [], "tt": []})
        d["num_b"].append(jnp.sum(ubin * g))
        d["num_t"].append(jnp.sum(uter * g))
        d["gg"].append(jnp.sum(g * g))
        d["bb"].append(jnp.sum(ubin * ubin))
        d["tt"].append(jnp.sum(uter * uter))
    out = {}
    for group, d in acc.items():
        gg = jnp.sqrt(sum(d["gg"]))
        out[group] = {
            "gbinary": sum(d["num_b"]) / (gg * jnp.sqrt(sum(d["bb"])) + 1e-12),
            "gternary": sum(d["num_t"]) / (gg * jnp.sqrt(sum(d["tt"])) + 1e-12),
        }
    return out


def group_cosines_from_workers(worker_grads: Any, groups: Any,
                               gate_phase: int = 0) -> dict:
    """Exact Table-5 diagnostic from stacked per-worker gradients.

    ``worker_grads`` leaves have a leading worker dim (W, ...).  Computes
    the true majority-vote aggregate (not the sign-of-mean proxy) against
    the FP32 mean.  Used by the convergence benchmarks, which split
    minibatches into virtual workers exactly as the paper does.
    """
    leaves, _ = jax.tree_util.tree_flatten(worker_grads)
    group_leaves, _ = jax.tree_util.tree_flatten(groups)
    acc: dict[str, dict[str, list]] = {}
    for leaf, group in zip(leaves, group_leaves):
        w = leaf.shape[0]
        g = jnp.mean(leaf.astype(jnp.float32), axis=0).reshape(-1)
        votes = jnp.sum((leaf > 0).astype(jnp.int32), axis=0).reshape(-1)
        ubin = jnp.sign(2 * votes - w).astype(jnp.float32)
        uter = ubin * _flat_index_gate(g.shape, gate_phase)
        d = acc.setdefault(group, {"num_b": [], "num_t": [],
                                   "gg": [], "bb": [], "tt": []})
        d["num_b"].append(jnp.sum(ubin * g))
        d["num_t"].append(jnp.sum(uter * g))
        d["gg"].append(jnp.sum(g * g))
        d["bb"].append(jnp.sum(ubin * ubin))
        d["tt"].append(jnp.sum(uter * uter))
    out = {}
    for group, d in acc.items():
        gg = jnp.sqrt(sum(d["gg"]))
        out[group] = {
            "gbinary": sum(d["num_b"]) / (gg * jnp.sqrt(sum(d["bb"])) + 1e-12),
            "gternary": sum(d["num_t"]) / (gg * jnp.sqrt(sum(d["tt"])) + 1e-12),
        }
    return out


def cosines_to_host(cosines: Mapping[str, Mapping[str, jax.Array]]) -> dict:
    """Device scalars -> plain floats for the Commander."""
    return {g: {k: float(v) for k, v in d.items()} for g, d in cosines.items()}
