"""Generic name -> object registry shared by every extension seam.

Four registries grew up hand-rolled in lockstep — schedules (PR 1),
controllers (PR 3), sim topologies (PR 4), codecs (PR 5) — and PR 5 had
to apply the same override/unregister alias-sweep fix to two of the four
copies by hand.  This module is that fix made once: one :class:`Registry`
owning key normalization, duplicate checking, alias registration, the
override sweep (replacing a name must also drop any *other* alias still
bound to the replaced object, so a stale alias can never silently
resolve the old entry), and unregistration.

Each seam keeps its public decorator/getter functions and its exact
error-message contract (tests match those strings); the per-registry
texture is injected through the constructor:

  * ``kind``      — noun used in error messages ("codec", "schedule
                    backend", "controller", "topology", ...).
  * ``key_fn``    — name normalization (``codec_name`` accepts the
                    legacy ``AggregationMode`` enum, etc.).
  * ``prepare``   — turn the decorated object into the stored value
                    (instantiate classes, validate protocols, or keep
                    the factory as-is for stateful entries).
  * ``describe``  — how an existing entry is named in the duplicate
                    error (type name for instances, ``__name__`` for
                    factories).
  * ``register_hint`` / ``format_available`` — the unknown-name error's
    trailing hint and how the available-names list renders.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

__all__ = ["Registry"]


def _default_prepare(obj: Any, keys: Sequence[str]) -> Any:
    return obj


def _default_describe(value: Any) -> str:
    return type(value).__name__


class Registry:
    """One extension seam: normalized string keys -> registered values."""

    def __init__(self, kind: str, *,
                 key_fn: Callable[[Any], str] = str,
                 prepare: Callable[[Any, Sequence[str]], Any] | None = None,
                 describe: Callable[[Any], str] | None = None,
                 register_hint: str | None = None,
                 format_available: Callable[[tuple], str] = repr):
        self.kind = kind
        self.key_fn = key_fn
        self.prepare = prepare or _default_prepare
        self.describe = describe or _default_describe
        #: e.g. ``"@register_codec({key!r})"`` — appended to unknown-name
        #: errors as "Register one with <hint>."; None omits the hint.
        self.register_hint = register_hint
        self.format_available = format_available
        self._items: dict[str, Any] = {}

    # -- registration ----------------------------------------------------

    def register(self, name: Any, *aliases: Any, override: bool = False):
        """Decorator registering an object under ``name`` (+ ``aliases``).

        Re-registering an existing key raises unless ``override=True``,
        which replaces the named keys *and* sweeps any other alias still
        bound to the replaced values.  Returns the decorated object
        unchanged (classes stay usable as classes).
        """
        keys = [self.key_fn(k) for k in (name, *aliases)]

        def deco(obj):
            value = self.prepare(obj, keys)
            if not override:
                # validate every key before inserting any, so a clash on
                # an alias cannot leave the registry half-registered
                for key in keys:
                    if key in self._items:
                        raise ValueError(
                            f"{self.kind} {key!r} already registered "
                            f"({self.describe(self._items[key])}); pass "
                            f"override=True to replace it")
            else:
                replaced = {id(self._items[k]): self._items[k]
                            for k in keys if k in self._items}
                for old in replaced.values():
                    if old is not value:
                        for k in [k for k, v in self._items.items()
                                  if v is old]:
                            del self._items[k]
            for key in keys:
                self._items[key] = value
            return obj

        return deco

    def unregister(self, name: Any) -> None:
        """Remove an entry and every alias bound to the same value
        (primarily for tests tearing down toy registrations)."""
        value = self._items.pop(self.key_fn(name), None)
        if value is not None:
            for key in [k for k, v in self._items.items() if v is value]:
                del self._items[key]

    # -- resolution ------------------------------------------------------

    def get(self, name: Any) -> Any:
        """Resolve a registered name to its stored value."""
        key = self.key_fn(name)
        try:
            return self._items[key]
        except KeyError:
            msg = (f"unknown {self.kind} {key!r}; available: "
                   f"{self.format_available(self.available())}")
            if self.register_hint is not None:
                msg += (". Register one with "
                        f"{self.register_hint.format(key=key)}.")
            raise KeyError(msg) from None

    def available(self) -> tuple[str, ...]:
        return tuple(sorted(self._items))

    def __contains__(self, name: Any) -> bool:
        return self.key_fn(name) in self._items

    def __len__(self) -> int:
        return len(self._items)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Registry({self.kind!r}, {len(self._items)} entries)"
