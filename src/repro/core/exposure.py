"""Datapath timing-exposure model (paper Section 5, TPU-adapted).

The paper's central timing question: is the low-bit aggregation datapath
*exposed* in the communication path, or hidden behind the memory/link
service interval?

    T_exposed = max(0, T_agg - T_overlap)                     (Section 3)

On TPU the "CXL bandwidth gate" becomes the ICI service time of the
gradient collective, and the "five-cycle 512-bit datapath" becomes the VPU
time of the pack/PopCount/majority kernels.  The same conclusion structure
is preserved: under bandwidth pressure (large buckets, thin links) the
datapath hides entirely; it is exposed only when the collective is cheap
relative to compute — and even then it is bounded by the kernels' VPU
throughput, reported here per byte.

This module is analytic (the container has no TPU); the kernel *work*
terms come from the kernels' op counts, and the benchmarks additionally
measure interpret-mode wall time for the functional path.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class TpuDatapathModel:
    """VPU-side cost model for the controller kernels.

    The VPU executes one (8, 128) int32 lanes op per cycle.  Per packed
    word (32 sign bits) the datapath costs roughly:
      pack:      ~3 vector ops / 32 values  (cmp, shift, add-reduce amortized)
      popcount:  ~3 ops per worker word
      majority:  ~6 ops (margin, two compares, two shifts, gate)
      unpack:    ~4 ops
    """
    clock_hz: float = 940e6            # v5e core clock
    vpu_lanes: int = 8 * 128
    ops_per_value_pack: float = 3 / 32
    ops_per_value_popcount_per_worker: float = 3 / 32
    ops_per_value_majority: float = 6 / 32
    ops_per_value_unpack: float = 4 / 32

    def t_agg(self, n_elements: int, num_workers: int) -> float:
        """Seconds of VPU time for the full aggregation datapath."""
        ops_per_value = (self.ops_per_value_pack
                         + self.ops_per_value_popcount_per_worker * num_workers
                         + self.ops_per_value_majority
                         + self.ops_per_value_unpack)
        total_ops = n_elements * ops_per_value
        return total_ops / (self.vpu_lanes * self.clock_hz)


@dataclasses.dataclass(frozen=True)
class ExposureModel:
    """T_exposed = max(0, T_agg - overlap_fraction * T_service)."""
    datapath: TpuDatapathModel = dataclasses.field(default_factory=TpuDatapathModel)
    link_bw: float = 50e9              # bytes/s per ICI link
    hbm_bw: float = 819e9              # bytes/s
    overlap_fraction: float = 1.0      # how much of service time can hide compute

    def t_service(self, wire_bytes_per_device: float) -> float:
        return wire_bytes_per_device / self.link_bw

    def exposed(self, n_elements: int, num_workers: int,
                wire_bytes_per_device: float,
                extra_service_s: float = 0.0) -> dict:
        """Exposure of one aggregation launch.

        ``extra_service_s`` adds fixed service-path latency (e.g. ring
        hops, CXL memory access) on top of the bandwidth term — it
        extends the window the datapath can hide behind, subject to the
        same ``overlap_fraction``.
        """
        t_agg = self.datapath.t_agg(n_elements, num_workers)
        t_srv = self.t_service(wire_bytes_per_device) + extra_service_s
        t_exp = max(0.0, t_agg - self.overlap_fraction * t_srv)
        base = t_srv if t_srv > 0 else t_agg
        return {
            "t_agg_s": t_agg,
            "t_service_s": t_srv,
            "t_exposed_s": t_exp,
            "exposed_pct": 100.0 * t_exp / base if base else 0.0,
            "hidden": t_exp == 0.0,
        }

    def exposed_launch(self, n_elements: int, num_workers: int, mode,
                       schedule, extra_service_s: float = 0.0) -> dict:
        """Exposure of one launch, wire bytes priced via the registries.

        ``mode`` is a codec name and ``schedule`` a registered backend;
        the wire-byte model resolves through
        :func:`repro.core.traffic.wire_bytes_per_device` (the schedule's
        transport factor times the codec's payload bytes), so any
        registered codec/schedule pair gets an exposure figure without
        hand-computing its bytes.
        """
        from .traffic import wire_bytes_per_device
        wb = wire_bytes_per_device(n_elements, mode, schedule, num_workers)
        return self.exposed(n_elements, num_workers, wb,
                            extra_service_s=extra_service_s)


def envelope_sweep(n_elements: int = 8 << 20, num_workers: int = 32,
                   wire_bytes_per_device: float | None = None):
    """Paper Fig 3 operating-envelope sweep, TPU-adapted.

    Panel (a): link bandwidth x datapath depth multiplier.
    Panel (b): hop latency (analogue of fixed CXL memory-access latency).
    Panel (c): admitted fraction (analogue of LLC-filtered controller load).
    Panel (d): telemetry (mode-latch) staleness in steps.
    Returns {panel: list[dict]} rows for the benchmark harness.
    """
    if wire_bytes_per_device is None:
        wire_bytes_per_device = 3 * n_elements / 8   # packed_a2a schedule
    rows: dict[str, list] = {"a": [], "b": [], "c": [], "d": []}

    for bw in (12.5e9, 25e9, 50e9, 100e9, 200e9):
        for depth_mult in (1.0, 2.0, 4.0):
            dp = TpuDatapathModel(
                ops_per_value_pack=3 / 32 * depth_mult,
                ops_per_value_popcount_per_worker=3 / 32 * depth_mult,
                ops_per_value_majority=6 / 32 * depth_mult,
                ops_per_value_unpack=4 / 32 * depth_mult)
            m = ExposureModel(datapath=dp, link_bw=bw)
            r = m.exposed(n_elements, num_workers, wire_bytes_per_device)
            rows["a"].append({"link_GBps": bw / 1e9, "depth_mult": depth_mult, **r})

    for hop_us in (0.5, 1.0, 2.0, 5.0):
        # hop latency is extra service-path time; route it through the
        # model so overlap_fraction and the zero-service guard apply
        # (the old hand-patched dict recomputed t_exposed_s ignoring
        # overlap_fraction and divided by an unguarded t_service_s)
        m = ExposureModel()
        r = m.exposed(n_elements, num_workers, wire_bytes_per_device,
                      extra_service_s=2 * (num_workers - 1) * hop_us * 1e-6)
        rows["b"].append({"hop_us": hop_us, **r})

    for admitted_frac in (0.25, 0.5, 0.75, 1.0):
        m = ExposureModel()
        n_adm = int(n_elements * admitted_frac)
        r = m.exposed(n_adm, num_workers, wire_bytes_per_device * admitted_frac
                      + (1 - admitted_frac) * 8 * n_elements)
        rows["c"].append({"admitted_frac": admitted_frac, **r})

    for stale_steps in (0, 1, 10, 100):
        # a stale mode latch only delays the traffic change; cost is one
        # FP32-priced step per stale step, amortized over an epoch-scale run
        step_cost = 8 * n_elements / 50e9
        amortized_pct = 100.0 * stale_steps * step_cost / (1000 * step_cost)
        rows["d"].append({"stale_steps": stale_steps,
                          "amortized_step_cost_pct": amortized_pct})
    return rows
