"""Admission control plane: Predictor / Commander / Supervisor.

Paper Section 3 ("Control interface") organizes policy into three roles,
which we keep verbatim — only the signal sources change from gem5/NS-3
telemetry to training-runtime telemetry:

  * **Predictor** — estimates collective pressure from forecasts: gradient
    volume, per-bucket bytes, All-Reduce windows on the ICI model.  It never
    observes gradients, weights, or loss.
  * **Commander** — proposes a mode per layer group from diagnostics (the
    deterministic ladder of Section 8: pick the lowest-traffic mode whose
    cosine-alignment diagnostic passes, keep sensitive groups on FP32).
  * **Supervisor** — training-health guard: a one-sided CUSUM on the loss
    trend (Page, 1954) triggers recovery to FP32, enforces a cooldown, and
    allows re-admission afterwards.

The controller itself (the compiled train step) only ever receives mode
metadata — an :class:`AdmissionPlan` — mirroring the paper's "the control
plane writes only mode metadata; it does not inspect gradient payloads".

This module holds the *math* of the three roles.  The control loop that
sequences them (phase machine, telemetry schema, registry) lives in
:mod:`repro.fabric.control` — its ``"paper"`` controller is the
successor of the pre-registry ``ControlPlane`` class.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from .buckets import AdmissionPlan, GroupPolicy
from .modes import AggregationMode, Schedule
from .traffic import IciModel, plan_traffic_ratio, wire_bytes_per_device


# ---------------------------------------------------------------------------
# Predictor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Predictor:
    """Communication-pressure forecasts (paper: trace-derived; here: model-derived).

    Stored forecasts mirror the paper's list: forward/backward duration,
    All-Reduce timing, gradient volume, shard bytes per device, peak
    bandwidth demand.
    """
    num_workers: int
    ici: IciModel = dataclasses.field(default_factory=IciModel)

    def forecast(self, group_sizes: Mapping[str, int],
                 plan: AdmissionPlan) -> dict:
        grad_volume = sum(group_sizes.values()) * 4  # FP32 bytes produced
        per_group = {}
        total_time = 0.0
        total_bytes = 0.0
        for g, n in group_sizes.items():
            pol = plan.policy_for(g)
            b = wire_bytes_per_device(n, pol.mode, pol.resolved_schedule(),
                                      self.num_workers)
            t = self.ici.collective_time(b, self.num_workers)
            per_group[g] = {"wire_bytes": b, "time_s": t}
            total_time += t
            total_bytes += b
        return {
            "gradient_volume_bytes": grad_volume,
            "allreduce_time_s": total_time,
            "wire_bytes_per_device": total_bytes,
            "traffic_ratio": plan_traffic_ratio(group_sizes, plan),
            "per_group": per_group,
        }


# ---------------------------------------------------------------------------
# Commander (deterministic admission ladder)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Commander:
    """Maps per-group cosine diagnostics to the lowest-traffic passing mode.

    Ladder (paper Section 8): G-Binary if its alignment passes, else
    G-Ternary, else FP32.  Groups listed in ``always_fp32`` (norms by
    default — scale-critical, tiny traffic) are never admitted.

    ``binary_mode`` / ``ternary_mode`` are the codecs the two ladder
    rungs admit; the cosine diagnostics are always keyed ``"gbinary"`` /
    ``"gternary"`` (the admitted codec's *sign statistics* are what the
    diagnostic measures, whatever transport realizes them), so pointing
    a rung at a hierarchical plan — e.g.
    ``Commander(binary_mode="hier_fp32_gbinary")`` — admits the per-hop
    route under the same thresholds.
    """
    tau_binary: float = 0.35
    tau_ternary: float = 0.30
    always_fp32: tuple = ("norms",)
    schedule: Schedule | None = None
    error_feedback: bool = False
    binary_mode: AggregationMode | str = AggregationMode.G_BINARY
    ternary_mode: AggregationMode | str = AggregationMode.G_TERNARY

    def propose(self, cosines: Mapping[str, Mapping[str, float]]) -> AdmissionPlan:
        """cosines: group -> {'gbinary': cos, 'gternary': cos}."""
        policies = {}
        for g, c in cosines.items():
            if g in self.always_fp32:
                policies[g] = GroupPolicy(AggregationMode.FP32)
            elif c.get("gbinary", 0.0) >= self.tau_binary:
                policies[g] = GroupPolicy(self.binary_mode,
                                          self.schedule, self.error_feedback)
            elif c.get("gternary", 0.0) >= self.tau_ternary:
                policies[g] = GroupPolicy(self.ternary_mode,
                                          self.schedule, self.error_feedback)
            else:
                policies[g] = GroupPolicy(AggregationMode.FP32)
        return AdmissionPlan.from_dict(
            policies, default=GroupPolicy(AggregationMode.FP32))


# ---------------------------------------------------------------------------
# Supervisor (CUSUM training-health guard)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CusumGuard:
    """One-sided CUSUM on the loss trend (Page 1954).

    s_t = max(0, s_{t-1} + (loss_t - mu_t - kappa)); trigger when s_t > h.
    mu_t is an EWMA of the loss maintained while healthy, so the statistic
    accumulates only *sustained* loss growth, not single-step noise.
    """
    kappa: float = 0.01
    h: float = 0.25
    ewma: float = 0.05
    mu: float | None = None
    s: float = 0.0

    def update(self, loss: float) -> bool:
        loss = float(loss)
        if not math.isfinite(loss):
            return True
        if self.mu is None:
            self.mu = loss
            return False
        self.s = max(0.0, self.s + (loss - self.mu - self.kappa))
        triggered = self.s > self.h
        if not triggered:
            self.mu = (1 - self.ewma) * self.mu + self.ewma * loss
        return triggered

    def reset(self) -> None:
        self.mu, self.s = None, 0.0

    def state_dict(self) -> dict:
        return {"mu": self.mu, "s": self.s}

    def load_state_dict(self, state: dict) -> None:
        self.mu = None if state["mu"] is None else float(state["mu"])
        self.s = float(state["s"])


@dataclasses.dataclass
class Supervisor:
    """Keeps or recovers to FP32 when training-health telemetry is unsafe."""
    guard: CusumGuard = dataclasses.field(default_factory=CusumGuard)
    cooldown_steps: int = 50
    _cooldown_left: int = 0

    def observe(self, loss: float) -> bool:
        """Returns True when a recovery to FP32 must happen now."""
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self.guard.update(loss)  # keep mu tracking during cooldown
            return False
        if self.guard.update(loss):
            self._cooldown_left = self.cooldown_steps
            self.guard.reset()
            return True
        return False

    @property
    def in_cooldown(self) -> bool:
        return self._cooldown_left > 0

    def state_dict(self) -> dict:
        return {"cooldown_left": self._cooldown_left,
                "guard": self.guard.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        self._cooldown_left = int(state["cooldown_left"])
        self.guard.load_state_dict(state["guard"])


# ---------------------------------------------------------------------------
# Control events (mode-latch audit trail)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ControlEvent:
    step: int
    kind: str            # warmup_end | admitted | recovery | readmitted
    plan_signature: str
