"""Distributed low-bit gradient aggregation collectives (the paper's core).

Every function here runs *inside* a ``jax.shard_map`` whose manual axes are
the data-parallel mesh axes (``('pod', 'data')`` on the production mesh):
per-device gradients are visible before reduction, which is the JAX
analogue of the paper's premise that the controller sees per-worker
payloads rather than an already-reduced tensor.

Semantics (paper Section 2, identical across schedules):

    b_{k,i} = 1{ sgn(g_{k,i}) > 0 }
    c_i     = PopCount_k(b_{k,i})           (vote count over W workers)
    a_i     = 2 c_i - W                      (vote margin)
    u_i     = sgn(a_i)                       (G-Binary)
    u_i     = m_i * sgn(a_i)                 (G-Ternary, 2-of-3 zero gate)

Two schedules implement the same semantics with different bytes-on-wire:

  * ``vote_psum``   — int8 sign votes, one ``psum`` over the DP axes.
                      ~2N bytes/device modeled (vs ~8N for FP32 ring
                      all-reduce); the XLA realization widens the psum
                      operand to int32 so the margin stays exact at any W.
  * ``packed_a2a``  — the controller schedule.  Workers pack sign bits
                      (``sign_pack`` kernel, N/8 bytes), ``all_to_all``
                      routes each packed shard to the device that "owns"
                      that element range (the write into the CXL-resident
                      buffer), the owner runs the PopCount/majority Pallas
                      datapath, and the packed ternary result is
                      all-gathered back (the read response).
                      ~(N/8 + N/4) bytes/device: ~21x less than FP32.

FP32 aggregation stays available per bucket (``fp32_allreduce``), exactly
as the paper's bypass path.  ``sign_of_mean`` and ``majority_sign_sgd``
are the paper's Section 9 baselines.

Beyond the paper: optional per-worker error feedback (EF-signSGD style)
on the vote input, which tightens the hard-workload boundary (see
EXPERIMENTS.md) at the cost of one residual buffer per admitted bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import kernels as K
from ..kernels import fused as KF
from .modes import AggregationMode, Schedule

Axes = Sequence[str] | str


# ---------------------------------------------------------------------------
# FP32 bypass path
# ---------------------------------------------------------------------------

def fp32_allreduce(g: jax.Array, dp_axes: Axes) -> jax.Array:
    """Full-precision mean aggregate (the calibration / recovery path).

    The collective runs on an FP32 payload regardless of the gradient's
    storage dtype — this *is* the paper's FP32 bypass semantics, and it is
    what the wire-byte accounting (4 bytes/element) assumes.
    """
    return jax.lax.pmean(g.astype(jnp.float32), dp_axes)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _flat_index_gate(shape, phase: int, dtype=jnp.float32) -> jax.Array:
    """Fixed 2-of-3 zero gate over flattened elements (paper Section 2)."""
    n = 1
    for s in shape:
        n *= s
    idx = jnp.arange(n).reshape(shape)
    return (((idx + phase) % 3) != 2).astype(dtype)


def _ef_inject(g: jax.Array, ef: jax.Array | None):
    """Error-feedback vote input: votes are taken on g + e (beyond paper)."""
    if ef is None:
        return g, None
    return g + ef.astype(g.dtype), ef


def _ef_update(g_eff: jax.Array, ef: jax.Array | None):
    """Residual update e' = x - beta * sgn(x), beta = mean|x| (EF-signSGD)."""
    if ef is None:
        return None
    beta = jnp.mean(jnp.abs(g_eff))
    sent = beta * jnp.sign(g_eff)
    return (g_eff - sent).astype(ef.dtype)


# ---------------------------------------------------------------------------
# vote_psum schedule (dense int8 votes)
# ---------------------------------------------------------------------------

def lowbit_vote_psum(g: jax.Array, dp_axes: Axes, num_workers: int, *,
                     ternary: bool = False, gate_phase: int = 0,
                     ef: jax.Array | None = None,
                     gate: jax.Array | None = None):
    """Sign votes as int8, one psum over DP, majority (+ optional gate).

    Works on arbitrarily sharded leaves (pure elementwise + psum), so it is
    the default schedule for tensor-parallel-sharded parameters.

    ``gate`` optionally overrides the flat-index 2-of-3 gate with an
    explicit {0, 1} keep vector — the fused bucket path passes the
    concatenation of per-leaf gates here.

    Returns ``(u, new_ef)`` with ``u`` in {-1, 0, +1} (dtype of ``g``).
    """
    g_eff, ef = _ef_inject(g, ef)
    votes = jnp.where(g_eff > 0, jnp.int8(1), jnp.int8(-1))
    # The *accumulation* must be wider than the 1-byte vote: the margin
    # a_i = 2c - W spans [-W, W], which wraps int8 for W >= 128.  Note
    # the XLA realization therefore ships the widened int32 operand; the
    # schedule's wire-byte model keeps counting the paper's logical
    # 1-byte vote payload (what a controller-side popcount would move) —
    # see VotePsumBackend.wire_bytes_per_device.
    margin = jax.lax.psum(votes.astype(jnp.int32), dp_axes)
    u = jnp.sign(margin.astype(jnp.float32))
    if ternary:
        u = u * (_flat_index_gate(g.shape, gate_phase) if gate is None
                 else gate.astype(u.dtype))
    return u.astype(g.dtype), _ef_update(g_eff, ef)


# ---------------------------------------------------------------------------
# packed_a2a schedule (the controller datapath on ICI)
# ---------------------------------------------------------------------------

def _packed_a2a_local(g: jax.Array, dp_axes: Axes, num_workers: int, *,
                      ternary: bool, gate_phase: int,
                      ef: jax.Array | None, interpret: bool | None,
                      gate_mask=None, kernels: KF.KernelSet | None = None):
    """Packed aggregation over DP for a *fully local* array.

    ``gate_mask`` (host-side boolean (N,) array) overrides the uniform
    flat-index 2-of-3 gate with an arbitrary keep pattern; the fused
    bucket path uses it to carry the concatenation of per-leaf gates.
    ``kernels`` (a vote-capable :class:`~repro.kernels.fused.KernelSet`)
    reroutes the whole chain to the codec's fused kernels — bit-identical
    by the KernelSet contract, fewer launches and no intermediate HBM
    materialization.
    """
    if kernels is not None and kernels.votes:
        return kernels.packed_vote(g, dp_axes, num_workers, ternary=ternary,
                                   gate_phase=gate_phase, ef=ef,
                                   interpret=interpret, gate_mask=gate_mask)
    w = num_workers
    n = g.size
    g_eff, ef = _ef_inject(g, ef)
    plane = K.to_plane(g_eff.reshape(-1))
    words = K.pack_signs(plane, interpret=interpret)      # (R, 128) u32
    r = words.shape[0]
    pad_r = (-r) % w
    if pad_r:
        words = jnp.pad(words, ((0, pad_r), (0, 0)))
    rw = (r + pad_r) // w
    # "write-side materialization": route worker payloads to the owning
    # aggregator for each element range.
    routed = jax.lax.all_to_all(words.reshape(w, rw, K.LANE), dp_axes,
                                split_axis=0, concat_axis=0, tiled=False)
    # "controller datapath": PopCount across workers + majority/ternary gate
    # (the gate helper is shared with the fused driver, so both pipelines
    # consume byte-identical zero gates by construction).
    counts = K.popcount_stack(routed, interpret=interpret)
    gate = KF.shard_gate_words(dp_axes, rw, ternary=ternary,
                               gate_phase=gate_phase, gate_mask=gate_mask,
                               total_rows=r + pad_r)
    sw, mw = K.majority_decode(counts, num_workers=w, gate_words=gate,
                               interpret=interpret)
    # "read response": packed ternary aggregate gathered back to all workers.
    sw_all = jax.lax.all_gather(sw, dp_axes, axis=0, tiled=True)[:r]
    mw_all = jax.lax.all_gather(mw, dp_axes, axis=0, tiled=True)[:r]
    u_plane = K.unpack_ternary(sw_all, mw_all, dtype=jnp.float32,
                               interpret=interpret)
    u = K.from_plane(u_plane, n).reshape(g.shape).astype(g.dtype)
    return u, _ef_update(g_eff, ef)


def lowbit_packed_a2a(g: jax.Array, dp_axes: Axes, num_workers: int, *,
                      model_spec: P | None = None, ternary: bool = False,
                      gate_phase: int = 0, ef: jax.Array | None = None,
                      interpret: bool | None = None, gate_mask=None,
                      kernels: KF.KernelSet | None = None):
    """Controller-schedule aggregation.

    If the leaf is sharded over auto (tensor-parallel) mesh axes,
    ``model_spec`` must give its PartitionSpec; an inner ``shard_map`` makes
    the shard fully local so the Pallas datapath can run on it.
    ``gate_mask`` (fully local payloads only) overrides the flat-index
    ternary gate — see :func:`_packed_a2a_local`.  ``kernels`` routes the
    chain to the codec's fused kernel set when present.
    """
    kwargs = dict(ternary=ternary, gate_phase=gate_phase, interpret=interpret,
                  kernels=kernels)

    if model_spec is None or all(a is None for a in model_spec):
        return _packed_a2a_local(g, dp_axes, num_workers, ef=ef,
                                 gate_mask=gate_mask, **kwargs)
    assert gate_mask is None, "gate_mask requires a fully local payload"

    manual = frozenset(a for axes in model_spec if axes is not None
                       for a in ((axes,) if isinstance(axes, str) else axes))

    if ef is None:
        def inner(gl):
            u, _ = _packed_a2a_local(gl, dp_axes, num_workers, ef=None, **kwargs)
            return u
        u = jax.shard_map(inner, in_specs=model_spec, out_specs=model_spec,
                          axis_names=manual, check_vma=False)(g)
        return u, None

    def inner_ef(gl, efl):
        return _packed_a2a_local(gl, dp_axes, num_workers, ef=efl, **kwargs)
    u, new_ef = jax.shard_map(
        inner_ef, in_specs=(model_spec, model_spec),
        out_specs=(model_spec, model_spec),
        axis_names=manual, check_vma=False)(g, ef)
    return u, new_ef


# ---------------------------------------------------------------------------
# Section 9 baselines
# ---------------------------------------------------------------------------

def majority_sign_sgd(g: jax.Array, dp_axes: Axes, num_workers: int):
    """MajoritySignSGD: communication-comparable software sign baseline.

    Identical update rule to G-Binary (each worker contributes a sign; the
    majority decides); kept separate because the paper positions it as the
    software reference against the controller-resident primitive.
    """
    u, _ = lowbit_vote_psum(g, dp_axes, num_workers)
    return u


def sign_of_mean(g: jax.Array, dp_axes: Axes) -> jax.Array:
    """SignOfMean: sign taken *after* the FP32 mean (optimizer reference).

    Not communication-comparable — the full-precision reduction has already
    happened (paper Section 2, "Sign-gradient baselines").
    """
    return jnp.sign(jax.lax.pmean(g, dp_axes)).astype(g.dtype)


# ---------------------------------------------------------------------------
# per-leaf dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafPolicy:
    """Resolved aggregation policy for one gradient leaf.

    ``mode`` names the gradient codec (a built-in
    :class:`AggregationMode` member or the string name of any codec
    registered via ``repro.fabric.register_codec``); ``schedule`` may be
    a built-in :class:`Schedule` member or the string name of any
    backend registered via ``repro.fabric.register_schedule``.
    """
    mode: AggregationMode | str
    schedule: Schedule | str
    model_spec: Any = None          # PartitionSpec over auto (TP) axes
    gate_phase: int = 0
    error_feedback: bool = False


def aggregate_leaf(g: jax.Array, policy: LeafPolicy, dp_axes: Axes,
                   num_workers: int, ef: jax.Array | None = None,
                   interpret: bool | None = None):
    """Deprecated free-function shim — use ``repro.fabric``.

    Dispatches through the schedule-backend registry (no hardcoded
    mode/schedule branching lives here anymore).  Returns
    ``(aggregate, new_ef)``; for FP32 the aggregate is the mean gradient,
    for low-bit modes it is the ternary direction in {-1, 0, +1}.
    """
    from ..fabric import AggregationContext
    from ..fabric.session import aggregate_leaf as _dispatch
    ctx = AggregationContext(dp_axes=dp_axes, num_workers=num_workers,
                             interpret=interpret)
    return _dispatch(ctx, g, policy, ef=ef)
