"""Gradient-traffic accounting and communication-time models.

Two distinct views, kept separate exactly as in the paper:

  * **Payload accounting** (paper Section 4 / Table 6): bits of the
    communicated gradient *representation* per element, normalized to the
    same-runner FP32 payload.  This is what "traffic vs FP32 = 0.0357"
    means; it is independent of the collective algorithm.

  * **Wire-byte / time models** (paper Fig 7 and our roofline collective
    term): bytes that actually cross links per device under a concrete
    schedule, and the resulting modeled communication time on the TPU ICI
    constants.  These are not wall-clock training speedups.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from .buckets import AdmissionPlan, BucketLayout
from .modes import (AggregationMode, Schedule, bits_per_element,
                    wire_schedule)


# ---------------------------------------------------------------------------
# payload accounting (paper's ratios)
# ---------------------------------------------------------------------------

def payload_bytes(n_elements: int, mode: AggregationMode | str) -> float:
    """Communicated payload bytes for one aggregation of n elements.

    ``mode`` is a codec name (built-in enum member or any registered
    codec); the bits/element figure lives on the codec.
    """
    return n_elements * bits_per_element(mode) / 8.0


def plan_traffic_ratio(sizes: Mapping[str, int], plan: AdmissionPlan) -> float:
    """Traffic vs FP32 for an admission plan over the given group sizes.

    Reproduces the paper's Table 6 accounting: e.g. for ResNet-18/CIFAR-100
    (backbone ~99.54% of params) a G-Binary backbone + FP32 head plan yields
    ~0.0357, and full-path G-Binary yields 0.0313 (= 1/32).  Bits per
    element resolve through the codec registry, so plans naming a
    registered codec (e.g. ``int4``) are accounted automatically.
    """
    total = sum(sizes.values())
    if total == 0:
        return 1.0
    lowbit = sum(n * bits_per_element(plan.policy_for(g).mode)
                 for g, n in sizes.items())
    return lowbit / (32.0 * total)


# ---------------------------------------------------------------------------
# wire-byte models per schedule (per-device bytes crossing links)
# ---------------------------------------------------------------------------

def wire_bytes_per_device(n_elements: int, mode: AggregationMode | str,
                          schedule: Schedule | str, num_workers: int,
                          dtype_bytes: int = 4) -> float:
    """Ring-model bytes per device for one aggregation of n elements.

    The model lives on the schedule backend (its
    ``wire_bytes_per_device`` method) so byte accounting and dispatch
    can never disagree; mean transports price the *codec's* payload
    bytes (``get_codec(mode).payload_bytes``), so a registered codec is
    accounted without touching any backend.  ``dtype_bytes`` is a
    legacy knob kept for custom backends — every built-in prices the
    codec's wire payload (the FP32 bypass always ships fp32 regardless
    of storage dtype) and ignores it.  The built-ins:

    psum             : 2 (W-1)/W * codec bytes  (reduce-scatter + all-gather;
                                                 4N for fp32, 0.5N for int4)
    vote_psum (int8) : 2 (W-1)/W * 1N
    packed_a2a       : (W-1)/W * (N/8)          all_to_all of packed signs
                       + (W-1)/W * (N/4)        all-gather of sign+mask words
    """
    if num_workers <= 1:
        return 0.0
    from ..fabric import get_schedule
    backend = get_schedule(wire_schedule(mode, schedule))
    fn = getattr(backend, "wire_bytes_per_device", None)
    if fn is None:
        raise ValueError(f"schedule {schedule!r} has no wire-byte model; "
                         f"give its backend a wire_bytes_per_device method")
    return fn(n_elements, mode, num_workers, dtype_bytes=dtype_bytes)


def hop_wire_bytes_per_device(n_elements: int, mode: AggregationMode | str,
                              schedule: Schedule | str, num_workers: int,
                              dtype_bytes: int = 4) -> tuple:
    """Per-hop wire bytes per device: one entry per route leg.

    Flat schedules are a single leg (the :func:`wire_bytes_per_device`
    figure); hierarchical codecs (a registered
    :class:`~repro.fabric.hierarchy.HopPlan`) report one leg per hop,
    each priced by that hop backend's own ring model at the hop's
    worker-group size.  ``sum(hop_wire_bytes_per_device(...)) ==
    wire_bytes_per_device(...)`` always holds — the flat figure *is* the
    route total.
    """
    from ..fabric import get_schedule
    backend = get_schedule(wire_schedule(mode, schedule))
    fn = getattr(backend, "hop_wire_bytes_per_device", None)
    if fn is not None:
        return tuple(float(b) for b in
                     fn(n_elements, mode, num_workers,
                        dtype_bytes=dtype_bytes))
    return (wire_bytes_per_device(n_elements, mode, schedule, num_workers,
                                  dtype_bytes=dtype_bytes),)


# ---------------------------------------------------------------------------
# modeled communication time (paper Fig 7, TPU-adapted)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IciModel:
    """TPU v5e-like interconnect constants (see EXPERIMENTS.md §Roofline).

    ``link_bytes_per_s`` is bytes/s per ICI link direction.
    """
    #: bytes/s per ICI link direction
    link_bytes_per_s: float = 50e9
    #: effective links usable by the collective
    links_per_chip: float = 1.0
    #: per-step latency of a ring stage
    hop_latency_s: float = 1e-6
    #: fixed dispatch cost per collective launch (host dispatch + XLA
    #: ramp-up)
    launch_overhead_s: float = 20e-6

    def collective_time(self, per_device_bytes: float, num_workers: int,
                        num_launches: int = 1) -> float:
        """Bandwidth term + per-launch latency (ring hops + dispatch).

        ``num_launches`` is the number of separate collectives the bytes
        are split across: each launch pays the full ring-stage latency
        and the fixed dispatch overhead, which is exactly the term bucket
        fusion amortizes (one launch per 32 MiB bucket instead of one
        per gradient leaf).
        """
        bw = self.link_bytes_per_s * self.links_per_chip
        steps = max(2 * (num_workers - 1), 1)
        per_launch = steps * self.hop_latency_s + self.launch_overhead_s
        return per_device_bytes / bw + num_launches * per_launch


def modeled_comm_time(n_elements: int, mode: AggregationMode,
                      schedule: Schedule, num_workers: int,
                      ici: IciModel | None = None,
                      num_launches: int = 1) -> float:
    """One-aggregation communication time under the ring/ICI model."""
    ici = ici or IciModel()
    b = wire_bytes_per_device(n_elements, mode, schedule, num_workers)
    return ici.collective_time(b, num_workers, num_launches=num_launches)


def modeled_layout_comm_time(layout: BucketLayout, num_workers: int,
                             ici: IciModel | None = None) -> float:
    """Modeled comm time of one aggregation pass under a bucket layout.

    Sums, over every collective launch the layout implies (one per fused
    bucket plus one per unfused leaf), the wire-byte bandwidth term of
    that launch's schedule and the per-launch latency.  Comparing the
    32 MiB layout against the degenerate per-leaf layout
    (``plan_buckets(..., bucket_bytes=1)``) shows why fusion wins: the
    bytes are identical, the launch terms collapse from O(leaves) to
    O(buckets).
    """
    ici = ici or IciModel()
    total = 0.0
    for key, n in layout.launches():
        # per-hop accounting: the launch's bytes are the sum of its route
        # legs (a single leg for flat schedules); every leg of one launch
        # shares the launch's dispatch + ring-stage latency term
        legs = hop_wire_bytes_per_device(n, key.mode, key.schedule,
                                         num_workers)
        total += ici.collective_time(sum(legs), num_workers)
    return total


@dataclasses.dataclass(frozen=True)
class MultiHopModel:
    """Analytic counterpart of the sim's ``multihop`` topology.

    Constants mirror :class:`repro.sim.topology.MultiHop` term for term
    (the sim's ``multihop`` lane is validated against this model within
    1% on degenerate single-launch cases, exactly as ``ici_ring`` is
    validated against :class:`IciModel`): every route leg crosses its
    own link at ``link_bytes_per_s``, each leg adds one
    ``hop_latency_s``, and each launch pays one ``launch_overhead_s``.
    """
    #: bytes/s per inter-hop link (oversubscribed vs the 50e9 ICI ring)
    link_bytes_per_s: float = 25e9
    #: per-leg store-and-forward latency
    hop_latency_s: float = 2e-6
    #: fixed dispatch cost per launch
    launch_overhead_s: float = 5e-6

    def route_time(self, hop_bytes: Sequence[float],
                   num_launches: int = 1) -> float:
        """Serialized service of every leg + per-launch latency."""
        legs = [float(b) for b in hop_bytes]
        per_launch = (len(legs) * self.hop_latency_s
                      + self.launch_overhead_s)
        return (sum(legs) / self.link_bytes_per_s
                + num_launches * per_launch)


def modeled_layout_multihop_time(layout: BucketLayout, num_workers: int,
                                 model: MultiHopModel | None = None) -> float:
    """Modeled multihop comm time of one aggregation pass under a layout.

    The hop-aware analogue of :func:`modeled_layout_comm_time`: each
    launch's route legs come from :func:`hop_wire_bytes_per_device` (so
    a hierarchical codec's intra-node and inter-node legs are priced
    separately) and are fed to :meth:`MultiHopModel.route_time`.
    """
    model = model or MultiHopModel()
    return sum(
        model.route_time(
            hop_wire_bytes_per_device(n, key.mode, key.schedule,
                                      num_workers))
        for key, n in layout.launches())


#: Payload sizes used by the paper's Fig 7 positioning experiment.
GPT2_XL_PARAMS = 1_557_611_200       # GPT-2 XL ~1.56B parameters
BERT_LARGE_PARAMS = 340_000_000
GPT3_PARAMS = 175_000_000_000
