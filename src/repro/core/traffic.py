"""Gradient-traffic accounting and communication-time models.

Two distinct views, kept separate exactly as in the paper:

  * **Payload accounting** (paper Section 4 / Table 6): bits of the
    communicated gradient *representation* per element, normalized to the
    same-runner FP32 payload.  This is what "traffic vs FP32 = 0.0357"
    means; it is independent of the collective algorithm.

  * **Wire-byte / time models** (paper Fig 7 and our roofline collective
    term): bytes that actually cross links per device under a concrete
    schedule, and the resulting modeled communication time on the TPU ICI
    constants.  These are not wall-clock training speedups.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping

from .buckets import AdmissionPlan, BucketLayout
from .modes import (AggregationMode, Schedule, bits_per_element,
                    wire_schedule)


# ---------------------------------------------------------------------------
# payload accounting (paper's ratios)
# ---------------------------------------------------------------------------

def payload_bytes(n_elements: int, mode: AggregationMode | str) -> float:
    """Communicated payload bytes for one aggregation of n elements.

    ``mode`` is a codec name (built-in enum member or any registered
    codec); the bits/element figure lives on the codec.
    """
    return n_elements * bits_per_element(mode) / 8.0


def plan_traffic_ratio(sizes: Mapping[str, int], plan: AdmissionPlan) -> float:
    """Traffic vs FP32 for an admission plan over the given group sizes.

    Reproduces the paper's Table 6 accounting: e.g. for ResNet-18/CIFAR-100
    (backbone ~99.54% of params) a G-Binary backbone + FP32 head plan yields
    ~0.0357, and full-path G-Binary yields 0.0313 (= 1/32).  Bits per
    element resolve through the codec registry, so plans naming a
    registered codec (e.g. ``int4``) are accounted automatically.
    """
    total = sum(sizes.values())
    if total == 0:
        return 1.0
    lowbit = sum(n * bits_per_element(plan.policy_for(g).mode)
                 for g, n in sizes.items())
    return lowbit / (32.0 * total)


# ---------------------------------------------------------------------------
# wire-byte models per schedule (per-device bytes crossing links)
# ---------------------------------------------------------------------------

def wire_bytes_per_device(n_elements: int, mode: AggregationMode | str,
                          schedule: Schedule | str, num_workers: int,
                          dtype_bytes: int = 4) -> float:
    """Ring-model bytes per device for one aggregation of n elements.

    The model lives on the schedule backend (its
    ``wire_bytes_per_device`` method) so byte accounting and dispatch
    can never disagree; mean transports price the *codec's* payload
    bytes (``get_codec(mode).payload_bytes``), so a registered codec is
    accounted without touching any backend.  ``dtype_bytes`` is a
    legacy knob kept for custom backends — every built-in prices the
    codec's wire payload (the FP32 bypass always ships fp32 regardless
    of storage dtype) and ignores it.  The built-ins:

    psum             : 2 (W-1)/W * codec bytes  (reduce-scatter + all-gather;
                                                 4N for fp32, 0.5N for int4)
    vote_psum (int8) : 2 (W-1)/W * 1N
    packed_a2a       : (W-1)/W * (N/8)          all_to_all of packed signs
                       + (W-1)/W * (N/4)        all-gather of sign+mask words
    """
    if num_workers <= 1:
        return 0.0
    from ..fabric import get_schedule
    backend = get_schedule(wire_schedule(mode, schedule))
    fn = getattr(backend, "wire_bytes_per_device", None)
    if fn is None:
        raise ValueError(f"schedule {schedule!r} has no wire-byte model; "
                         f"give its backend a wire_bytes_per_device method")
    return fn(n_elements, mode, num_workers, dtype_bytes=dtype_bytes)


# ---------------------------------------------------------------------------
# modeled communication time (paper Fig 7, TPU-adapted)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, init=False)
class IciModel:
    """TPU v5e-like interconnect constants (see EXPERIMENTS.md §Roofline).

    ``link_bytes_per_s`` is bytes/s per ICI link direction.  The old
    field name ``link_gbps`` was misleading (the value was always
    bytes/s, never Gbit/s); it survives as a deprecated constructor
    kwarg and read-only property carrying the same bytes/s value.
    """
    link_bytes_per_s: float          # bytes/s per ICI link direction
    links_per_chip: float            # effective links usable by the collective
    hop_latency_s: float             # per-step latency of a ring stage
    launch_overhead_s: float         # fixed dispatch cost per collective
                                     # launch (host dispatch + XLA ramp-up)

    def __init__(self, link_bytes_per_s: float | None = None,
                 links_per_chip: float = 1.0,
                 hop_latency_s: float = 1e-6,
                 launch_overhead_s: float = 20e-6, *,
                 link_gbps: float | None = None) -> None:
        if link_gbps is not None:
            warnings.warn(
                "IciModel(link_gbps=...) is deprecated: the field always "
                "held bytes/s, not Gbit/s — pass link_bytes_per_s instead",
                DeprecationWarning, stacklevel=2)
            if link_bytes_per_s is not None:
                raise TypeError("pass link_bytes_per_s or the deprecated "
                                "link_gbps, not both")
            link_bytes_per_s = link_gbps
        if link_bytes_per_s is None:
            link_bytes_per_s = 50e9
        object.__setattr__(self, "link_bytes_per_s", float(link_bytes_per_s))
        object.__setattr__(self, "links_per_chip", float(links_per_chip))
        object.__setattr__(self, "hop_latency_s", float(hop_latency_s))
        object.__setattr__(self, "launch_overhead_s",
                           float(launch_overhead_s))

    @property
    def link_gbps(self) -> float:
        """Deprecated alias for :attr:`link_bytes_per_s` (bytes/s)."""
        warnings.warn(
            "IciModel.link_gbps is deprecated (it holds bytes/s, not "
            "Gbit/s); read link_bytes_per_s instead",
            DeprecationWarning, stacklevel=2)
        return self.link_bytes_per_s

    def collective_time(self, per_device_bytes: float, num_workers: int,
                        num_launches: int = 1) -> float:
        """Bandwidth term + per-launch latency (ring hops + dispatch).

        ``num_launches`` is the number of separate collectives the bytes
        are split across: each launch pays the full ring-stage latency
        and the fixed dispatch overhead, which is exactly the term bucket
        fusion amortizes (one launch per 32 MiB bucket instead of one
        per gradient leaf).
        """
        bw = self.link_bytes_per_s * self.links_per_chip
        steps = max(2 * (num_workers - 1), 1)
        per_launch = steps * self.hop_latency_s + self.launch_overhead_s
        return per_device_bytes / bw + num_launches * per_launch


def modeled_comm_time(n_elements: int, mode: AggregationMode,
                      schedule: Schedule, num_workers: int,
                      ici: IciModel | None = None,
                      num_launches: int = 1) -> float:
    """One-aggregation communication time under the ring/ICI model."""
    ici = ici or IciModel()
    b = wire_bytes_per_device(n_elements, mode, schedule, num_workers)
    return ici.collective_time(b, num_workers, num_launches=num_launches)


def modeled_layout_comm_time(layout: BucketLayout, num_workers: int,
                             ici: IciModel | None = None) -> float:
    """Modeled comm time of one aggregation pass under a bucket layout.

    Sums, over every collective launch the layout implies (one per fused
    bucket plus one per unfused leaf), the wire-byte bandwidth term of
    that launch's schedule and the per-launch latency.  Comparing the
    32 MiB layout against the degenerate per-leaf layout
    (``plan_buckets(..., bucket_bytes=1)``) shows why fusion wins: the
    bytes are identical, the launch terms collapse from O(leaves) to
    O(buckets).
    """
    ici = ici or IciModel()
    total = 0.0
    for key, n in layout.launches():
        b = wire_bytes_per_device(n, key.mode, key.schedule, num_workers)
        total += ici.collective_time(b, num_workers)
    return total


#: Payload sizes used by the paper's Fig 7 positioning experiment.
GPT2_XL_PARAMS = 1_557_611_200       # GPT-2 XL ~1.56B parameters
BERT_LARGE_PARAMS = 340_000_000
GPT3_PARAMS = 175_000_000_000
