"""NEURON-Fabric core: low-bit gradient aggregation with admission control.

The paper's contribution as a composable JAX module:

  * :mod:`modes`        — aggregation modes + payload accounting (Table 2)
  * :mod:`lowbit`       — G-Binary / G-Ternary / FP32 collectives (Section 2/3)
  * :mod:`buckets`      — param groups, admission plans (Section 7.3)
  * :mod:`aggregate`    — tree-level aggregation under a plan
  * :mod:`admission`    — Predictor / Commander / Supervisor (Section 3/8)
  * :mod:`diagnostics`  — cosine-alignment layer diagnostics (Table 5)
  * :mod:`traffic`      — payload ratios + wire-byte/time models (Table 6, Fig 7)
  * :mod:`exposure`     — datapath timing-exposure model (Section 5, Fig 3)
"""
from .modes import (AggregationMode, Schedule, bits_per_element,
                    canonical_mode, codec_name, schedule_name,
                    traffic_ratio, wire_schedule)
from .lowbit import (LeafPolicy, aggregate_leaf, fp32_allreduce,
                     lowbit_packed_a2a, lowbit_vote_psum, majority_sign_sgd,
                     sign_of_mean)
from .buckets import (AdmissionPlan, Bucket, BucketGate, BucketKey,
                      BucketLayout, BucketSlot, DEFAULT_BUCKET_BYTES,
                      GroupPolicy, GroupRules, UnfusedLeaf, assign_groups,
                      group_sizes, path_name, plan_buckets,
                      resolve_policies)
from .aggregate import aggregate_gradients, init_ef_states, make_policy_tree
from .admission import Commander, CusumGuard, Predictor, Supervisor
from .diagnostics import (cosines_to_host, group_cosines_from_mean,
                          group_cosines_from_workers)
from .traffic import (IciModel, MultiHopModel, hop_wire_bytes_per_device,
                      modeled_comm_time, modeled_layout_comm_time,
                      modeled_layout_multihop_time, payload_bytes,
                      plan_traffic_ratio, wire_bytes_per_device)
from .exposure import ExposureModel, TpuDatapathModel, envelope_sweep

__all__ = [
    "AggregationMode", "Schedule", "bits_per_element", "canonical_mode",
    "codec_name", "schedule_name", "traffic_ratio", "wire_schedule",
    "LeafPolicy", "aggregate_leaf", "fp32_allreduce", "lowbit_packed_a2a",
    "lowbit_vote_psum", "majority_sign_sgd", "sign_of_mean",
    "AdmissionPlan", "Bucket", "BucketGate", "BucketKey", "BucketLayout",
    "BucketSlot", "DEFAULT_BUCKET_BYTES", "GroupPolicy", "GroupRules",
    "UnfusedLeaf", "assign_groups", "group_sizes", "path_name",
    "plan_buckets", "resolve_policies",
    "aggregate_gradients", "init_ef_states", "make_policy_tree",
    "Commander", "CusumGuard", "Predictor", "Supervisor",
    "cosines_to_host", "group_cosines_from_mean", "group_cosines_from_workers",
    "IciModel", "MultiHopModel", "hop_wire_bytes_per_device",
    "modeled_comm_time", "modeled_layout_comm_time",
    "modeled_layout_multihop_time", "payload_bytes", "plan_traffic_ratio",
    "wire_bytes_per_device",
    "ExposureModel", "TpuDatapathModel", "envelope_sweep",
]
