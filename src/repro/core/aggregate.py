"""Tree-level gradient aggregation under an admission plan.

This is the seam the training runtime calls: a gradient pytree goes in,
and each leaf is aggregated under its resolved :class:`LeafPolicy`
(FP32 / G-Binary / G-Ternary x schedule), exactly as the paper's
controller applies the latched mode per admitted bucket.

Error-feedback residual state (beyond paper, optional) is carried as a
pytree matching the params: EF-enabled leaves hold a ``(1, *shape)`` local
residual (globally ``(W, *shape)`` sharded over the DP axes); disabled
leaves hold a scalar sentinel so the tree structure stays static across
plans (one jit cache entry per plan signature, not per step).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .buckets import AdmissionPlan, GroupRules, resolve_policies
from .lowbit import LeafPolicy, aggregate_leaf

Axes = Sequence[str] | str

_is_policy = lambda x: isinstance(x, LeafPolicy)


def init_ef_states(params: Any, policies: Any, dtype=jnp.float32) -> Any:
    """Residual tree: zeros like (1, *shape) where EF is on, scalar 0 else."""
    def make(p, pol):
        if pol.error_feedback:
            return jnp.zeros((1,) + tuple(p.shape), dtype)
        return jnp.zeros((), dtype)
    return jax.tree.map(make, params, policies, is_leaf=None)


def ef_specs(pspecs: Any, policies: Any, dp_axes) -> Any:
    """PartitionSpecs for the EF tree (leading dim sharded over DP)."""
    from jax.sharding import PartitionSpec as P

    def spec(ps, pol):
        if not pol.error_feedback:
            return P()
        inner = tuple(ps) if ps is not None else ()
        return P(tuple(dp_axes) if not isinstance(dp_axes, str) else dp_axes,
                 *inner)
    return jax.tree.map(spec, pspecs, policies,
                        is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)) or isinstance(x, P))


def aggregate_gradients(grads: Any, policies: Any, dp_axes: Axes,
                        num_workers: int, ef_states: Any | None = None,
                        interpret: bool | None = None):
    """Aggregate a gradient tree leaf-by-leaf under resolved policies.

    Runs inside a shard_map whose manual axes are ``dp_axes``.  Returns
    ``(aggregates, new_ef_states)``; ``new_ef_states`` mirrors the input
    sentinel structure.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    p_leaves = treedef.flatten_up_to(policies)
    if ef_states is None:
        e_leaves = [None] * len(g_leaves)
    else:
        e_leaves = treedef.flatten_up_to(ef_states)

    agg, new_ef = [], []
    for g, pol, e in zip(g_leaves, p_leaves, e_leaves):
        use_ef = pol.error_feedback and e is not None and e.ndim > 0
        ef_in = e[0] if use_ef else None
        u, ef_out = aggregate_leaf(g, pol, dp_axes, num_workers,
                                   ef=ef_in, interpret=interpret)
        agg.append(u)
        if e is None:
            new_ef.append(None)
        elif use_ef:
            new_ef.append(ef_out[None])
        else:
            new_ef.append(e)
    aggregates = jax.tree_util.tree_unflatten(treedef, agg)
    if ef_states is None:
        return aggregates, None
    return aggregates, jax.tree_util.tree_unflatten(treedef, new_ef)


def make_policy_tree(params: Any, plan: AdmissionPlan,
                     pspecs: Any | None = None,
                     rules: GroupRules | None = None) -> Any:
    """Convenience re-export: params + plan (+ specs) -> LeafPolicy tree."""
    return resolve_policies(params, plan, pspecs=pspecs, rules=rules)
