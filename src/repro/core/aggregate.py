"""Tree-level gradient aggregation under an admission plan (legacy seam).

The canonical implementation lives in :mod:`repro.fabric` — a
:class:`~repro.fabric.Fabric` session owns dispatch (via the
schedule-backend registry), the aggregation context, and EF-state
handling.  This module keeps the original free-function surface as thin
deprecation shims plus :func:`init_ef_states`, the worker-local EF
initializer the session builds on.

Error-feedback residual state (beyond paper, optional) is carried as a
pytree matching the params: EF-enabled leaves hold a ``(1, *shape)`` local
residual (globally ``(W, *shape)`` sharded over the DP axes); disabled
leaves hold a scalar sentinel so the tree structure stays static across
plans (one jit cache entry per plan signature, not per step).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .buckets import AdmissionPlan, GroupRules, resolve_policies

Axes = Sequence[str] | str


def init_ef_states(params: Any, policies: Any, dtype=jnp.float32) -> Any:
    """Residual tree: zeros like (1, *shape) where EF is on, scalar 0 else."""
    def make(p, pol):
        if pol.error_feedback:
            return jnp.zeros((1,) + tuple(p.shape), dtype)
        return jnp.zeros((), dtype)
    return jax.tree.map(make, params, policies, is_leaf=None)


def aggregate_gradients(grads: Any, policies: Any, dp_axes: Axes,
                        num_workers: int, ef_states: Any | None = None,
                        interpret: bool | None = None):
    """Deprecated free-function shim — use ``Fabric.aggregate``.

    Aggregates a gradient tree leaf-by-leaf under resolved policies,
    inside a shard_map whose manual axes are ``dp_axes``.  Returns
    ``(aggregates, new_ef_states)``.
    """
    from ..fabric import AggregationContext, aggregate_tree
    ctx = AggregationContext(dp_axes=dp_axes, num_workers=num_workers,
                             interpret=interpret)
    return aggregate_tree(ctx, grads, policies, ef_states=ef_states)


def make_policy_tree(params: Any, plan: AdmissionPlan,
                     pspecs: Any | None = None,
                     rules: GroupRules | None = None) -> Any:
    """Convenience re-export: params + plan (+ specs) -> LeafPolicy tree."""
    return resolve_policies(params, plan, pspecs=pspecs, rules=rules)
