"""Aggregation modes and payload-bit accounting (paper Table 2).

Modes name what the "controller" returns for an admitted gradient bucket:

  * IDENTITY   — original bytes (functional read-back checks only).
  * FP32       — full-precision mean aggregate (warm-up / calibration /
                 recovery path).
  * G_BINARY   — majority sign aggregate, u = sgn(2c - W).
  * G_TERNARY  — ternary sign/zero aggregate, u = m * sgn(2c - W) with the
                 fixed 2-of-3 zero gate.

Payload accounting follows the paper's convention: ratios count the bits of
the communicated gradient representation per element, normalized to FP32
(32 bits).  G-Ternary is counted at log2(3) bits/element, which reproduces
the paper's 0.0494 full-path ratio (Table 6).
"""
from __future__ import annotations

import enum
import math


class AggregationMode(str, enum.Enum):
    IDENTITY = "identity"
    FP32 = "fp32"
    G_BINARY = "gbinary"
    G_TERNARY = "gternary"

    @property
    def is_lowbit(self) -> bool:
        return self in (AggregationMode.G_BINARY, AggregationMode.G_TERNARY)


#: Communicated payload bits per gradient element, per mode.
BITS_PER_ELEMENT = {
    AggregationMode.IDENTITY: 32.0,
    AggregationMode.FP32: 32.0,
    AggregationMode.G_BINARY: 1.0,
    AggregationMode.G_TERNARY: math.log2(3.0),
}


def bits_per_element(mode: AggregationMode) -> float:
    return BITS_PER_ELEMENT[AggregationMode(mode)]


def traffic_ratio(mode: AggregationMode) -> float:
    """Payload ratio vs the same-runner FP32 baseline (paper Section 4)."""
    return bits_per_element(mode) / 32.0


class Schedule(str, enum.Enum):
    """Concrete collective schedule implementing a mode on the mesh.

    The *mode* fixes the returned aggregate's semantics; the *schedule* fixes
    the bytes that actually cross ICI links (reported separately in the
    roofline, mirroring the paper's payload-vs-service-path split).
    """
    #: FP32: XLA psum (ring reduce-scatter + all-gather under the hood).
    PSUM = "psum"
    #: low-bit, paper-faithful dense votes: int8 sign votes -> psum -> majority.
    VOTE_PSUM = "vote_psum"
    #: low-bit, controller schedule: pack -> all_to_all -> PopCount kernel ->
    #: majority -> all-gather packed result (the CXL write/aggregate/read
    #: response path mapped onto ICI collectives).
    PACKED_A2A = "packed_a2a"


DEFAULT_SCHEDULE = {
    AggregationMode.IDENTITY: Schedule.PSUM,
    AggregationMode.FP32: Schedule.PSUM,
    AggregationMode.G_BINARY: Schedule.VOTE_PSUM,
    AggregationMode.G_TERNARY: Schedule.VOTE_PSUM,
}


def schedule_name(schedule) -> str:
    """Canonical registry key for a schedule given as enum or plain string.

    Plans may name schedules outside the built-in :class:`Schedule` enum —
    any backend registered with ``repro.fabric.register_schedule`` is
    addressable by its string name.
    """
    return schedule.value if isinstance(schedule, enum.Enum) else str(schedule)


#: built-in schedules that only carry low-bit payloads; FP32/IDENTITY
#: buckets nominally on one of these ride the psum bypass instead.
_LOWBIT_ONLY_SCHEDULES = frozenset(
    {Schedule.VOTE_PSUM.value, Schedule.PACKED_A2A.value})


def wire_schedule(mode, schedule):
    """Wire-level schedule actually used for a (mode, schedule) pair.

    Two mode/schedule mismatches are normalized, both preserving the
    pre-registry dispatch semantics:

      * FP32/IDENTITY aggregates carried on a built-in low-bit schedule
        (vote_psum / packed_a2a) travel on the psum path — the paper's
        bypass semantics (and what the 4-bytes/element wire accounting
        assumes);
      * low-bit aggregates nominally on ``psum`` travel on the dense
        vote_psum path (a 1-bit mode has no FP32-mean realization).

    Every other schedule — including registered custom backends such as
    the ``sign_of_mean`` baseline — dispatches as named for every mode.
    """
    lowbit = AggregationMode(mode).is_lowbit
    name = schedule_name(schedule)
    if not lowbit and name in _LOWBIT_ONLY_SCHEDULES:
        return Schedule.PSUM
    if lowbit and name == Schedule.PSUM.value:
        return Schedule.VOTE_PSUM
    return schedule
