"""Aggregation modes, codec naming, and payload-bit accounting (Table 2).

The representation axis is a *registry* — :mod:`repro.fabric.codecs` —
and a "mode" is simply a codec name.  :class:`AggregationMode` survives
as a behavior-identical deprecation shim naming the four built-in
codecs (its values *are* their registry names), so existing plans,
checkpoints, and controller decisions are unchanged:

  * ``identity`` — original bytes (functional read-back checks only).
  * ``fp32``     — full-precision mean aggregate (warm-up / calibration /
                   recovery path).
  * ``gbinary``  — majority sign aggregate, u = sgn(2c - W).
  * ``gternary`` — ternary sign/zero aggregate, u = m * sgn(2c - W) with
                   the fixed 2-of-3 zero gate.

Payload accounting follows the paper's convention: ratios count the bits
of the communicated gradient representation per element, normalized to
FP32 (32 bits).  G-Ternary is counted at log2(3) bits/element, which
reproduces the paper's 0.0494 full-path ratio (Table 6).  The numbers
live on the codecs; :func:`bits_per_element` and :func:`traffic_ratio`
resolve through the registry, so a registered codec (e.g. ``int4``)
participates in every accounting surface automatically.
"""
from __future__ import annotations

import enum
import warnings


class AggregationMode(str, enum.Enum):
    """Deprecation shim naming the four built-in codecs.

    New representations register with
    :func:`repro.fabric.codecs.register_codec` and are addressed by
    string name everywhere a mode is accepted; this enum is kept so
    existing plans/checkpoints (and the Fig-6 pilot decisions) resolve
    unchanged.
    """
    IDENTITY = "identity"
    FP32 = "fp32"
    G_BINARY = "gbinary"
    G_TERNARY = "gternary"

    @property
    def is_lowbit(self) -> bool:
        warnings.warn(
            "AggregationMode.is_lowbit is deprecated: ask the codec "
            "registry instead (get_codec(mode).reduction == 'vote')",
            DeprecationWarning, stacklevel=2)
        return self in (AggregationMode.G_BINARY, AggregationMode.G_TERNARY)


def codec_name(mode) -> str:
    """Canonical codec-registry key for a mode given as enum or string.

    The representation analogue of :func:`schedule_name` — plans may
    name codecs outside the built-in :class:`AggregationMode` shim; any
    codec registered via ``repro.fabric.register_codec`` is addressable
    by its string name.
    """
    return mode.value if isinstance(mode, enum.Enum) else str(mode)


def canonical_mode(mode):
    """Normalize a codec name: built-ins to their enum member, else str.

    Keeps :class:`AggregationMode` members flowing through policies,
    bucket keys, and checkpoints exactly as before the codec registry
    (repr/hash stable), while letting registered codec names pass
    through as plain strings.
    """
    try:
        return AggregationMode(mode)
    except ValueError:
        return str(mode)


def bits_per_element(mode) -> float:
    """Communicated payload bits per gradient element, per codec."""
    from ..fabric.codecs import get_codec
    return get_codec(mode).bits_per_element


def traffic_ratio(mode) -> float:
    """Payload ratio vs the same-runner FP32 baseline (paper Section 4)."""
    return bits_per_element(mode) / 32.0


class Schedule(str, enum.Enum):
    """Concrete collective schedule implementing a codec on the mesh.

    The *codec* fixes the returned aggregate's semantics; the *schedule*
    fixes the bytes that actually cross ICI links (reported separately
    in the roofline, mirroring the paper's payload-vs-service-path
    split).
    """
    #: FP32: XLA psum (ring reduce-scatter + all-gather under the hood).
    PSUM = "psum"
    #: low-bit, paper-faithful dense votes: int8 sign votes -> psum -> majority.
    VOTE_PSUM = "vote_psum"
    #: low-bit, controller schedule: pack -> all_to_all -> PopCount kernel ->
    #: majority -> all-gather packed result (the CXL write/aggregate/read
    #: response path mapped onto ICI collectives).
    PACKED_A2A = "packed_a2a"


def schedule_name(schedule) -> str:
    """Canonical registry key for a schedule given as enum or plain string.

    Plans may name schedules outside the built-in :class:`Schedule` enum —
    any backend registered with ``repro.fabric.register_schedule`` is
    addressable by its string name.
    """
    return schedule.value if isinstance(schedule, enum.Enum) else str(schedule)


#: built-in schedules that only carry sign-vote payloads; mean-reduction
#: codecs nominally on one of these ride the psum bypass instead.
_VOTE_ONLY_SCHEDULES = frozenset(
    {Schedule.VOTE_PSUM.value, Schedule.PACKED_A2A.value})


def wire_schedule(mode, schedule) -> str:
    """Wire-level schedule name actually used for a (codec, schedule) pair.

    Always returns the canonical *string* name (the registry key; the
    old version leaked a ``Schedule.PSUM`` enum on one normalization
    branch and the caller's original enum-or-string otherwise).  Two
    codec/schedule mismatches are normalized, both preserving the
    pre-registry dispatch semantics:

      * mean-reduction codecs (FP32/IDENTITY/quantizers) carried on a
        built-in vote schedule (vote_psum / packed_a2a) travel on the
        psum path — the paper's bypass semantics (and what the
        codec-bytes/element wire accounting assumes);
      * vote-reduction codecs nominally on ``psum`` travel on the dense
        vote_psum path (a sign-vote codec has no FP32-mean realization);
      * hierarchical codecs (``reduction == "hierarchical"``, i.e. a
        registered :class:`~repro.fabric.hierarchy.HopPlan`) carried on
        any built-in flat schedule travel on the ``hierarchical``
        backend — the flat names have no single-hop meaning for a
        multi-hop route, whose per-hop transports are fixed by the plan;
      * local-accumulation codecs (``reduction == "local"``, the
        zero-wire ``local`` codec from :mod:`repro.elastic.strategies`)
        carried on any built-in collective travel on ``local_accum`` —
        a 0-bit payload on a real collective would ship FP32 bytes the
        traffic model prices at zero.

    Every other schedule — including registered custom backends such as
    the ``sign_of_mean`` baseline — dispatches as named for every codec.
    """
    from ..fabric.codecs import get_codec
    reduction = get_codec(mode).reduction
    name = schedule_name(schedule)
    if reduction == "hierarchical":
        if name in _VOTE_ONLY_SCHEDULES or name == Schedule.PSUM.value:
            return "hierarchical"
        return name
    if reduction == "local":
        if name in _VOTE_ONLY_SCHEDULES or name == Schedule.PSUM.value:
            return "local_accum"
        return name
    votes = reduction == "vote"
    if not votes and name in _VOTE_ONLY_SCHEDULES:
        return Schedule.PSUM.value
    if votes and name == Schedule.PSUM.value:
        return Schedule.VOTE_PSUM.value
    return name


# ---------------------------------------------------------------------------
# deprecated module-level tables (pre-codec-registry API)
# ---------------------------------------------------------------------------

def _legacy_bits_per_element() -> dict:
    from ..fabric.codecs import get_codec
    return {m: get_codec(m).bits_per_element for m in AggregationMode}


def _legacy_default_schedule() -> dict:
    from ..fabric.codecs import get_codec
    return {m: Schedule(get_codec(m).default_schedule)
            for m in AggregationMode}


def __getattr__(name: str):
    if name == "BITS_PER_ELEMENT":
        warnings.warn(
            "core.modes.BITS_PER_ELEMENT is deprecated: bits/element live "
            "on the codecs — use bits_per_element(mode) or "
            "repro.fabric.get_codec(mode).bits_per_element",
            DeprecationWarning, stacklevel=2)
        return _legacy_bits_per_element()
    if name == "DEFAULT_SCHEDULE":
        warnings.warn(
            "core.modes.DEFAULT_SCHEDULE is deprecated: the default "
            "transport lives on the codecs — use "
            "repro.fabric.get_codec(mode).default_schedule",
            DeprecationWarning, stacklevel=2)
        return _legacy_default_schedule()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
