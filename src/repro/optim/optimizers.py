"""Optimizers for NEURON-Fabric training.

The paper's contract: the aggregate returned by the controller (FP32 mean
or low-bit {-1, 0, +1} direction) is handed to the *unmodified* optimizer —
"NEURON-Fabric does not change model computation, model weights, or
backpropagation".  So these are ordinary AdamW / SGD-momentum; the only
NEURON-Fabric-aware piece is :func:`optimizer_state_pspecs`, which shards
optimizer moments over the data-parallel axes (ZeRO-1) — a distributed-
optimization feature orthogonal to the aggregation mode.

Everything is pure: ``init`` builds state, ``apply`` maps
(params, grads, state) -> (params, state).  Distribution happens outside
via shardings (GSPMD materializes the gather/scatter implied by ZeRO-1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class OptState(NamedTuple):
    step: jax.Array
    mu: Any                 # first moment / momentum
    nu: Any                 # second moment (None-tree for SGD)


def lr_schedule(step, *, peak_lr: float, warmup_steps: int = 100,
                total_steps: int = 10000, min_ratio: float = 0.1):
    """Linear warmup + cosine decay to ``min_ratio * peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.0
    grad_clip: float = 0.0        # 0 = off; applies to FP32 aggregates only

    def init(self, params: Any) -> OptState:
        raise NotImplementedError

    def apply(self, params: Any, grads: Any, state: OptState
              ) -> tuple[Any, OptState]:
        raise NotImplementedError

    @property
    def has_nu(self) -> bool:
        """Whether this optimizer's state carries a second moment (nu).

        Derived by introspecting the *actual* init state on a scalar
        probe — not the class name — so subclasses and new adaptive
        optimizers are classified correctly (the train-step builder uses
        this to shard ``nu`` like ``mu`` under ZeRO-1).  Override when
        probing ``init`` is undesirable.
        """
        return state_has_nu(self)

    def _lr(self, step):
        return lr_schedule(step, peak_lr=self.peak_lr,
                           warmup_steps=self.warmup_steps,
                           total_steps=self.total_steps)


def state_has_nu(optimizer) -> bool:
    """Probe an optimizer's init state for a second-moment (nu) buffer.

    The single implementation behind :attr:`Optimizer.has_nu` and the
    session's duck-typed fallback — works for any object exposing
    ``init(params)``.
    """
    state = optimizer.init(jnp.zeros((1,), jnp.float32))
    return getattr(state, "nu", None) is not None


@dataclasses.dataclass(frozen=True)
class AdamW(Optimizer):
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params: Any) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def apply(self, params, grads, state):
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step=step, mu=new_m, nu=new_v)


@dataclasses.dataclass(frozen=True)
class SgdMomentum(Optimizer):
    momentum: float = 0.9
    nesterov: bool = False

    def init(self, params: Any) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=None)

    def apply(self, params, grads, state):
        step = state.step + 1
        lr = self._lr(step)

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            if self.weight_decay:
                g = g + self.weight_decay * p.astype(jnp.float32)
            m = self.momentum * m + g
            d = g + self.momentum * m if self.nesterov else m
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m

        flat = jax.tree.map(upd, params, grads, state.mu)
        new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step=step, mu=new_m, nu=None)


def optimizer_state_pspecs(param_pspecs: Any, params_abstract: Any,
                           dp_axes=("pod", "data"), dp_size: int = 1,
                           zero1: bool = True) -> Any:
    """ZeRO-1 PartitionSpecs for optimizer moments.

    Each moment leaf additionally shards its *first un-sharded, divisible*
    dimension over the DP axes.  Leaves too small (or with no divisible
    dim) stay replicated — the memory win lives in the big matrices anyway.
    """
    dp = tuple(dp_axes)

    def spec(ps, p):
        if not zero1 or p.ndim == 0:
            return ps if ps is not None else P()
        entries = list(ps) if ps is not None else []
        entries += [None] * (p.ndim - len(entries))
        for i, (e, dim) in enumerate(zip(entries, p.shape)):
            if e is None and dim % max(dp_size, 1) == 0 and dim >= dp_size:
                entries[i] = dp
                return P(*entries)
        return P(*entries)

    is_spec = lambda x: isinstance(x, P) or x is None
    mu = jax.tree.map(spec, param_pspecs, params_abstract, is_leaf=is_spec)
    return mu
