"""Optimizers (AdamW, SGD-momentum) with ZeRO-1 state-sharding specs."""
from .optimizers import (AdamW, OptState, Optimizer, SgdMomentum,
                         lr_schedule, optimizer_state_pspecs)

__all__ = ["AdamW", "OptState", "Optimizer", "SgdMomentum", "lr_schedule",
           "optimizer_state_pspecs"]
