"""State-space / recurrent blocks: xLSTM (mLSTM + sLSTM) and Mamba-style heads.

These cover the two assigned non-attention architectures:

  * ``xlstm-125m`` — alternating mLSTM (matrix memory, exponential gating)
    and sLSTM (scalar memory, recurrent gating) blocks per arXiv:2405.04517.
  * ``hymba-1.5b`` — Mamba-style selective-SSM heads running *in parallel*
    with attention heads inside each layer (arXiv:2411.13676); the SSM part
    lives here, the fusion lives in models/transformer.py.

All recurrences use ``lax.scan`` over time with O(1)-in-sequence state, so
the ``long_500k`` decode cell is a single cheap state update — exactly why
these families stay in the long-context matrix (DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# two-level checkpointed scan (O(sqrt(T)) backward memory)
# ---------------------------------------------------------------------------

def chunked_scan(step, state0, xs, seq_len: int, chunk: int = 0):
    """lax.scan over time with sqrt(T) gradient-checkpoint chunking.

    A flat scan's backward pass saves every per-step carry (for mLSTM that
    is the (B,H,hd,hd) matrix memory at all T steps — hundreds of GB at 4k
    tokens).  Chunking saves only the chunk-boundary carries and recomputes
    inside each checkpointed chunk: memory ~ (T/chunk + chunk) * state.
    """
    if chunk <= 0:
        chunk = max(int(math.sqrt(seq_len)), 1)
    if seq_len <= chunk or seq_len % chunk != 0:
        return jax.lax.scan(step, state0, xs)

    nc = seq_len // chunk
    xs_c = jax.tree.map(
        lambda x: x.reshape((nc, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def outer(state, xc):
        return jax.lax.scan(step, state, xc)

    state, ys = jax.lax.scan(outer, state0, xs_c)
    ys = jax.tree.map(lambda y: y.reshape((seq_len,) + y.shape[2:]), ys)
    return state, ys


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) block
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> dict:
    """xLSTM mLSTM block: up-projection by pf, H heads over the inner dim."""
    d = cfg.d_model
    pf = cfg.ssm.proj_factor
    di = int(d * pf)
    h = cfg.num_heads
    hd = di // h
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    stdi = 1.0 / math.sqrt(di)
    dt = _dt(cfg)
    return {
        "w_up": (jax.random.normal(ks[0], (d, di)) * std).astype(dt),
        "w_q": (jax.random.normal(ks[1], (di, di)) * stdi).astype(dt),
        "w_k": (jax.random.normal(ks[2], (di, di)) * stdi).astype(dt),
        "w_v": (jax.random.normal(ks[3], (di, di)) * stdi).astype(dt),
        "w_ogate": (jax.random.normal(ks[4], (d, di)) * std).astype(dt),
        "w_if": (jax.random.normal(ks[5], (di, 2 * h)) * stdi).astype(jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[6], (di, d)) * stdi).astype(dt),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    di = int(cfg.d_model * cfg.ssm.proj_factor)
    h = cfg.num_heads
    hd = di // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def _mlstm_cell(state: dict, qkvif, hd: int):
    """One stabilized mLSTM step (arXiv:2405.04517 eqs. 19-27)."""
    q, k, v, it, ft = qkvif          # (B,H,hd) x3, (B,H), (B,H)
    c_prev, n_prev, m_prev = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(ft + m_prev, it)
    i_p = jnp.exp(it - m_t)
    f_p = jnp.exp(ft + m_prev - m_t)
    c_t = (f_p[..., None, None] * c_prev
           + i_p[..., None, None] * (v[..., :, None] * k[..., None, :]))
    n_t = f_p[..., None] * n_prev + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n_t * q, axis=-1)), 1.0)
    h_t = jnp.einsum("bhvk,bhk->bhv", c_t, q) / denom[..., None]
    return {"C": c_t, "n": n_t, "m": m_t}, h_t


def _mlstm_preact(p: dict, x: jax.Array, cfg: ModelConfig):
    b, s, d = x.shape
    di = p["w_up"].shape[1]
    h = cfg.num_heads
    hd = di // h
    xu = x @ p["w_up"]
    q = (xu @ p["w_q"]).reshape(b, s, h, hd) / math.sqrt(hd)
    k = (xu @ p["w_k"]).reshape(b, s, h, hd) / math.sqrt(hd)
    v = (xu @ p["w_v"]).reshape(b, s, h, hd)
    gif = (xu.astype(jnp.float32) @ p["w_if"]) + p["if_bias"]
    it, ft = gif[..., :h], gif[..., h:]
    o = jax.nn.sigmoid(x @ p["w_ogate"])
    return q, k, v, it, ft, o


def mlstm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence mLSTM block (training / prefill)."""
    b, s, d = x.shape
    di = p["w_up"].shape[1]
    h = cfg.num_heads
    hd = di // h
    q, k, v, it, ft, o = _mlstm_preact(p, x, cfg)

    def step(state, inp):
        return _mlstm_cell(state, inp, hd)

    xs = (q.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          it.swapaxes(0, 1), ft.swapaxes(0, 1))
    state0 = mlstm_init_state(cfg, b)
    _, hs = chunked_scan(step, state0, xs, s)
    hs = hs.swapaxes(0, 1).reshape(b, s, di).astype(x.dtype)   # (B,S,di)
    return (o * hs) @ p["w_down"]


def mlstm_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    """One-token mLSTM step; x: (B,1,d)."""
    b = x.shape[0]
    di = p["w_up"].shape[1]
    h = cfg.num_heads
    hd = di // h
    q, k, v, it, ft, o = _mlstm_preact(p, x, cfg)
    inp = (q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
           v[:, 0].astype(jnp.float32), it[:, 0], ft[:, 0])
    new_state, h_t = _mlstm_cell(state, inp, hd)
    h_t = h_t.reshape(b, 1, di).astype(x.dtype)
    return (o * h_t) @ p["w_down"], new_state


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with recurrent gating) block
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    dt = _dt(cfg)
    std = 1.0 / math.sqrt(d)
    return {
        # input weights for z, i, f, o stacked: (d, 4d)
        "w_in": (jax.random.normal(ks[0], (d, 4 * d)) * std).astype(dt),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd)) / math.sqrt(hd)).astype(jnp.float32),
        "bias": jnp.concatenate([jnp.zeros((3 * d,)), jnp.ones((d,))]).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[2], (d, d)) * std).astype(dt),
    }


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p: dict, cfg: ModelConfig, state: dict, x_in: jax.Array):
    """x_in: (B, 4d) preactivation from input; adds recurrent term."""
    b = x_in.shape[0]
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    h_prev = state["h"].reshape(b, h, hd)
    rec = jnp.einsum("bhk,hkf->bhf", h_prev, p["r"]).reshape(b, 4 * d)
    pre = x_in + rec + p["bias"]
    z, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_t)
    m_t = jnp.maximum(f_t + state["m"], i_t)       # exponential-gate stabilizer
    i_p = jnp.exp(i_t - m_t)
    f_p = jnp.exp(f_t + state["m"] - m_t)
    c_t = f_p * state["c"] + i_p * z
    n_t = f_p * state["n"] + i_p
    h_t = o * (c_t / jnp.maximum(n_t, 1e-6))
    return {"c": c_t, "n": n_t, "h": h_t, "m": m_t}, h_t


def slstm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    x_in = (x @ p["w_in"]).astype(jnp.float32)     # (B,S,4d)

    def step(state, xi):
        return _slstm_cell(p, cfg, state, xi)

    _, hs = chunked_scan(step, slstm_init_state(cfg, b),
                         x_in.swapaxes(0, 1), s)
    hs = hs.swapaxes(0, 1).astype(x.dtype)
    return hs @ p["w_down"]


def slstm_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    x_in = (x[:, 0] @ p["w_in"]).astype(jnp.float32)
    new_state, h_t = _slstm_cell(p, cfg, state, x_in)
    return (h_t[:, None].astype(x.dtype)) @ p["w_down"], new_state


# ---------------------------------------------------------------------------
# Mamba-style selective-SSM head (Hymba parallel heads)
# ---------------------------------------------------------------------------

def init_mamba_head(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n = cfg.ssm.state_size
    ks = jax.random.split(key, 5)
    dt = _dt(cfg)
    std = 1.0 / math.sqrt(d)
    return {
        "w_in": (jax.random.normal(ks[0], (d, d)) * std).astype(dt),
        "w_dt": (jax.random.normal(ks[1], (d, d)) * std * 0.1).astype(jnp.float32),
        "dt_bias": jnp.full((d,), -2.0, jnp.float32),    # softplus(-2) ~ 0.12
        "w_bc": (jax.random.normal(ks[2], (d, 2 * n)) * std).astype(jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (d, 1))),
        "skip_scale": jnp.ones((d,), jnp.float32),
        "w_out": (jax.random.normal(ks[3], (d, d)) * std).astype(dt),
    }


def mamba_init_state(cfg: ModelConfig, batch: int) -> jax.Array:
    return jnp.zeros((batch, cfg.d_model, cfg.ssm.state_size), jnp.float32)


def _mamba_scan_inputs(p: dict, x: jax.Array, cfg: ModelConfig):
    n = cfg.ssm.state_size
    u = (x @ p["w_in"]).astype(jnp.float32)                       # (B,S,d)
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["w_dt"] + p["dt_bias"])
    bc = x.astype(jnp.float32) @ p["w_bc"]
    b_in, c_out = bc[..., :n], bc[..., n:]
    a = -jnp.exp(p["a_log"])                                       # (d, n)
    da = jnp.exp(dt[..., None] * a)                                # (B,S,d,n)
    db = dt[..., None] * b_in[..., None, :]                        # (B,S,d,n)
    return u, da, db, c_out


def mamba_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    u, da, db, c_out = _mamba_scan_inputs(p, x, cfg)

    def step(h, inp):
        u_t, da_t, db_t, c_t = inp
        h = da_t * h + db_t * u_t[..., None]                       # (B,d,n)
        y = jnp.sum(h * c_t[:, None, :], axis=-1)                  # (B,d)
        return h, y

    xs = (u.swapaxes(0, 1), da.swapaxes(0, 1), db.swapaxes(0, 1),
          c_out.swapaxes(0, 1))
    _, ys = chunked_scan(step, mamba_init_state(cfg, b), xs, s)
    ys = ys.swapaxes(0, 1)                                          # (B,S,d)
    y = ys + p["skip_scale"] * u
    return (y.astype(x.dtype)) @ p["w_out"]


def mamba_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: jax.Array):
    u, da, db, c_out = _mamba_scan_inputs(p, x, cfg)
    h = da[:, 0] * state + db[:, 0] * u[:, 0, :, None]
    y = jnp.sum(h * c_out[:, 0][:, None, :], axis=-1) + p["skip_scale"] * u[:, 0]
    return (y[:, None].astype(x.dtype)) @ p["w_out"], h
