"""Building-block layers: norms, RoPE, GQA attention, MLP, MoE.

Pure-functional style: every block is ``init_*(key, cfg) -> params`` plus an
apply function.  Tensor-parallel sharding is expressed with *constraints on
the 'model' mesh axis only* (the DP axes are manual inside the training
shard_map and must never appear here); the :func:`shard` helper silently
no-ops when there is no mesh (CPU unit tests) or the named axis is absent
or manual.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig, MoEConfig


# ---------------------------------------------------------------------------
# sharding helper
# ---------------------------------------------------------------------------

def shard(x: jax.Array, *entries):
    """with_sharding_constraint that tolerates missing/manual mesh axes."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.axis_names:
        return x
    try:
        types = dict(zip(mesh.axis_names, mesh.axis_types))
    except Exception:
        types = {a: None for a in mesh.axis_names}

    def ok(axis) -> bool:
        if axis is None:
            return True
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        for a in axes:
            if a not in mesh.axis_names:
                return False
            if str(types.get(a)) == "AxisType.Manual" or repr(types.get(a)) == "Manual":
                return False
        return True

    cleaned = tuple(a if ok(a) else None for a in entries)
    if all(a is None for a in cleaned):
        return x
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    dt = _dtype(cfg)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, k * hd)) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, k * hd)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * std).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((k * hd,), dt)
        p["bv"] = jnp.zeros((k * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def attention_pspecs(cfg: ModelConfig) -> dict:
    """TP PartitionSpecs: Q/O sharded over heads, KV replicated (GQA-safe)."""
    p = {"wq": P(None, "model"), "wk": P(), "wv": P(),
         "wo": P("model", None)}
    if cfg.qkv_bias:
        p.update({"bq": P("model"), "bk": P(), "bv": P()})
    if cfg.qk_norm:
        p.update({"q_norm": {"scale": P()}, "k_norm": {"scale": P()}})
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions):
    b, s, d = x.shape
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    kk = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    q = shard(q.reshape(b, s, h, hd), None, None, "model", None)
    kk = kk.reshape(b, s, k, hd)
    v = v.reshape(b, s, k, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        kk = rmsnorm(p["k_norm"], kk, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)
    return q, kk, v


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """q: (B,S,H,hd), k: (B,T,K,hd) -> scores (B,K,G,S,T); H = K*G."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    return scores / math.sqrt(hd)


def _gqa_out(scores: jax.Array, v: jax.Array, cfg: ModelConfig) -> jax.Array:
    """scores (B,K,G,S,T) x v (B,T,K,hd) -> (B,S,H*hd)."""
    b, kv, g, s, t = scores.shape
    out = jnp.einsum("bkgst,btkh->bskgh", scores.astype(v.dtype), v)
    return out.reshape(b, s, kv * g * v.shape[-1])


#: above this sequence length, attention runs double-blocked (flash-style)
FLASH_SEQ_THRESHOLD = 2048
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_K = 1024


def _mask_block(iq: jax.Array, jk: jax.Array, *, causal: bool,
                window, is_global) -> jax.Array:
    """(bq, bk) bool mask from absolute query/key positions."""
    i = iq[:, None]
    j = jk[None, :]
    m = (j <= i) if causal else jnp.ones((iq.shape[0], jk.shape[0]), bool)
    if window is not None:
        local = m & (i - j < window)
        m = jnp.where(jnp.asarray(is_global), m, local)
    return m


def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window, is_global, scale: float,
                     block_q: int = FLASH_BLOCK_Q,
                     block_k: int = FLASH_BLOCK_K) -> jax.Array:
    """Double-blocked online-softmax attention (memory O(S * block)).

    q: (B,S,KV,G,hd); k, v: (B,T,KV,hd).  Returns (B,S,KV,G,hd).
    Blockwise numerically-stable softmax: per query block, scan key blocks
    carrying (running max, denominator, weighted accumulator).  This keeps
    the 32k/500k-token cells compilable without a quadratic score buffer —
    the flash-attention recurrence expressed in pure lax (XLA fuses it per
    block; a Pallas attention kernel is an orthogonal optimization to the
    paper's contribution and intentionally out of scope, see DESIGN.md).
    """
    b, s, kv, g, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    pad_q = (-s) % bq
    pad_k = (-t) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (s + pad_q) // bq, (t + pad_k) // bk
    qb = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, bk, kv, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, kv, hd).transpose(1, 0, 3, 2, 4)
    # qb: (nq, B, KV, G, bq, hd); kb/vb: (nk, B, KV, bk, hd)

    def q_block(carry, qi):
        qblk, iq0 = qi                      # (B,KV,G,bq,hd), scalar

        def k_block(state, ki):
            kblk, vblk, jk0 = ki
            m_run, l_run, acc = state
            sc = jnp.einsum("bkgqh,bkth->bkgqt", qblk, kblk,
                            preferred_element_type=jnp.float32) * scale
            iq = iq0 + jnp.arange(bq)
            jk = jk0 + jnp.arange(bk)
            mask = _mask_block(iq, jk, causal=causal, window=window,
                               is_global=is_global)
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, bq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_block, (m0, l0, a0),
            (kb, vb, jnp.arange(nk) * bk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return carry, out

    _, outs = jax.lax.scan(q_block, None, (qb, jnp.arange(nq) * bq))
    # outs: (nq, B, KV, G, bq, hd) -> (B, S, KV, G, hd)
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * bq, kv, g, hd)
    return outs[:, :s].astype(q.dtype)


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 is_global=True, positions=None, causal: bool = True
                 ) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``is_global`` may be a traced bool (scan-over-layers with a per-layer
    local/global pattern): both masks are cheap, only one set of einsums.
    Long sequences take the blockwise flash path automatically.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    kv, hd = cfg.num_kv_heads, cfg.hd
    g = cfg.num_heads // kv

    if s > FLASH_SEQ_THRESHOLD:
        q5 = q.reshape(b, s, kv, g, hd)
        out = _flash_attention(q5, k, v, causal=causal,
                               window=cfg.sliding_window,
                               is_global=is_global,
                               scale=1.0 / math.sqrt(hd))
        out = out.reshape(b, s, kv * g * hd)
        out = shard(out, None, None, "model")
        return out @ p["wo"]

    scores = _gqa_scores(q, k, cfg)                     # (B,K,G,S,T)
    i = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    mask = (j <= i) if causal else jnp.ones((s, s), bool)
    if cfg.sliding_window is not None:
        local = mask & (i - j < cfg.sliding_window)
        glob = jnp.asarray(is_global)
        mask = jnp.where(glob, mask, local)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, cfg)
    out = shard(out, None, None, "model")
    return out @ p["wo"]


def attn_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache_k, cache_v,
                position, *, is_global=True):
    """One-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, K, hd); position: scalar int32,
    or (B,) int32 for continuous batching — per-row write positions and
    per-row causal masks, so requests at different depths share one step.
    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    t = cache_k.shape[1]
    jidx = jnp.arange(t)
    if jnp.ndim(position) == 0:
        # scalar path: all rows at the same depth (training-style decode)
        pos = jnp.full((b, 1), position, jnp.int32)
        q, k_new, v_new = _qkv(p, x, cfg, pos)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, position, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, position, 0, 0))
        valid = jidx <= position
        if cfg.sliding_window is not None:
            local = valid & (position - jidx < cfg.sliding_window)
            valid = jnp.where(jnp.asarray(is_global), valid, local)
        mask = valid[None, None, None, None]            # (1,1,1,1,T)
    else:
        # vector path: row i writes/reads at its own position[i]
        pos = jnp.asarray(position, jnp.int32).reshape(b, 1)
        q, k_new, v_new = _qkv(p, x, cfg, pos)
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, pos[:, 0]].set(
            k_new[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos[:, 0]].set(
            v_new[:, 0].astype(cache_v.dtype))
        valid = jidx[None, :] <= pos                    # (B,T)
        if cfg.sliding_window is not None:
            local = valid & (pos - jidx[None, :] < cfg.sliding_window)
            valid = jnp.where(jnp.asarray(is_global), valid, local)
        mask = valid[:, None, None, None, :]            # (B,1,1,1,T)
    scores = _gqa_scores(q, cache_k, cfg)               # (B,K,G,1,T)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, cache_v, cfg)
    out = shard(out, None, None, "model")
    return out @ p["wo"], cache_k, cache_v


def init_cross_attention(key, cfg: ModelConfig) -> dict:
    return init_attention(key, cfg)


def cross_attn_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                       enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper).

    x: (B,S,d); enc_k/enc_v: (B,T_enc,K,hd) already projected+normalized.
    """
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.hd
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = shard(q.reshape(b, s, h, hd), None, None, "model", None)
    scores = _gqa_scores(q, enc_k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, enc_v, cfg)
    out = shard(out, None, None, "model")
    return out @ p["wo"]


def cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    """Project encoder output to cross-attention K/V once per sequence."""
    b, t, _ = enc_out.shape
    k, hd = cfg.num_kv_heads, cfg.hd
    kk = (enc_out @ p["wk"]).reshape(b, t, k, hd)
    v = (enc_out @ p["wv"]).reshape(b, t, k, hd)
    if cfg.qkv_bias:
        kk = kk + p["bk"].reshape(k, hd)
        v = v + p["bv"].reshape(k, hd)
    return kk, v


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    std = 1.0 / math.sqrt(d)
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {
            "w_gate": (jax.random.normal(ks[0], (d, f)) * std).astype(dt),
            "w_up": (jax.random.normal(ks[1], (d, f)) * std).astype(dt),
            "w_down": (jax.random.normal(ks[2], (f, d)) / math.sqrt(f)).astype(dt),
        }
    return {
        "w_up": (jax.random.normal(ks[0], (d, f)) * std).astype(dt),
        "w_down": (jax.random.normal(ks[1], (f, d)) / math.sqrt(f)).astype(dt),
    }


def mlp_pspecs(cfg: ModelConfig) -> dict:
    if cfg.mlp_variant == "swiglu":
        return {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                "w_down": P("model", None)}
    return {"w_up": P(None, "model"), "w_down": P("model", None)}


def mlp_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = shard(h, None, None, "model")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped capacity dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, de, e = cfg.d_model, m.d_expert, m.num_experts
    dt = _dtype(cfg)
    std = 1.0 / math.sqrt(d)
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, de)) * std).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, de)) * std).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, de, d)) / math.sqrt(de)).astype(dt),
    }
    if m.num_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.d_expert * m.num_shared)
    return p


def moe_pspecs(cfg: ModelConfig) -> dict:
    p = {"router": P(),
         "w_gate": P("model", None, None),
         "w_up": P("model", None, None),
         "w_down": P("model", None, None)}
    if cfg.moe.num_shared:
        p["shared"] = mlp_pspecs(cfg)
    return p


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k capacity-based MoE over grouped tokens; experts sharded (EP)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    gs = min(m.group_size, t)
    pad = (-t) % gs
    xt = x.reshape(t, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = (t + pad) // gs
    xg = xt.reshape(g, gs, d)
    e, k = m.num_experts, m.top_k
    cap = max(4, int(gs * k / e * m.capacity_factor))
    cap = min(cap, gs)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                     # (g, gs, k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)
    topv = topv.astype(_dtype(cfg))

    # capacity assignment: sequential priority over the k choices
    combine = jnp.zeros((g, gs, e, cap), _dtype(cfg))
    counts = jnp.zeros((g, e), jnp.int32)
    for i in range(k):
        onehot = jax.nn.one_hot(topi[..., i], e, dtype=jnp.int32)   # (g,gs,e)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        counts = counts + jnp.sum(onehot, axis=1)
        keep = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                                dtype=_dtype(cfg))                  # (g,gs,e,cap)
        combine = combine + pos_oh * (topv[..., i, None, None]
                                      * onehot[..., None].astype(_dtype(cfg)))
    dispatch = (combine > 0).astype(_dtype(cfg))

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    expert_in = shard(expert_in, None, "model", None, None)
    if "w_gate" in p:
        h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"]))
             * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"]))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"]))
    h = shard(h, None, "model", None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    y = y.reshape(t + pad, d)[:t].reshape(b, s, d)
    if "shared" in p:
        y = y + mlp_forward(p["shared"], x, cfg)
    return y
