"""Model configuration for the composable transformer family.

One config dataclass covers all ten assigned architectures: dense decoders
(qwen3 / qwen2.5 / gemma3 / starcoder2), MoE decoders (llama4-scout /
deepseek-moe), SSM and hybrid stacks (xlstm / hymba), the encoder-decoder
(whisper) and the VLM backbone (phi-3-vision).  Per-layer heterogeneity
(local/global attention, dense-first MoE, alternating sLSTM/mLSTM) is
expressed as *pattern fields* so homogeneous bodies can be scanned.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    num_shared: int = 0           # shared (always-on) experts
    first_dense: int = 0          # leading dense-FFN layers (DeepSeekMoE)
    capacity_factor: float = 1.25
    group_size: int = 1024        # tokens per dispatch group


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    state_size: int = 16
    variant: str = "mamba_head"   # mamba_head | mlstm | slstm
    slstm_every: int = 0          # xLSTM: every Nth block is sLSTM (0 = none)
    proj_factor: float = 2.0      # xLSTM block up-projection factor


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # local-attention window
    global_every: Optional[int] = None     # every Nth layer is global (gemma3 6)
    mlp_variant: str = "swiglu"            # swiglu | gelu

    # mixtures
    moe: Optional[MoEConfig] = None
    ssm: Optional[SsmConfig] = None
    hybrid_parallel: bool = False          # hymba: attn + ssm heads in parallel

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500                # fixed frame count after conv stub

    # vlm (phi-3-vision): stub projector over precomputed patch features
    vision_patches: int = 0
    vision_feat_dim: int = 1024

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True                     # activation checkpoint per layer
    remat_policy: str = "full"             # full | dots (save dot/AR outputs)

    # smoke-test reduction hint (None = this IS a reduced config)
    full_size: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the 500k-token long-context cell (see DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True   # all ten assigned archs have an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers + head)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += d * v                              # head
        per_layer = self._per_layer_params()
        n += self.num_layers * per_layer
        if self.encoder_layers:
            n += self.encoder_layers * per_layer
        return n

    def _per_layer_params(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.moe:
            m = self.moe
            mults = 3 if self.mlp_variant == "swiglu" else 2
            ffn = m.num_experts * mults * d * m.d_expert \
                + m.num_shared * mults * d * m.d_expert + d * m.num_experts
        elif self.d_ff:
            mults = 3 if self.mlp_variant == "swiglu" else 2
            ffn = mults * d * self.d_ff
        else:
            ffn = 0
        ssm = 0
        if self.ssm is not None:
            ssm = int(4 * d * d * self.ssm.proj_factor / 2)
        return attn + ffn + ssm + 2 * d

    def active_param_count(self) -> int:
        """Active params per token (= total for dense; top-k for MoE)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        m = self.moe
        mults = 3 if self.mlp_variant == "swiglu" else 2
        dense_ffn_active = (m.top_k + m.num_shared) * mults * d * m.d_expert
        full_ffn = m.num_experts * mults * d * m.d_expert \
            + m.num_shared * mults * d * m.d_expert
        return self.param_count() - self.num_layers * (full_ffn - dense_ffn_active
                                                       - d * m.num_experts)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) evaluation cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
