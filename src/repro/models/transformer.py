"""Composable LM assembly: decoder-only, MoE, hybrid, SSM, enc-dec, VLM.

One code path covers all ten assigned architectures.  Homogeneous layer
bodies are *scanned* (``lax.scan`` over stacked parameters — one layer
compiled regardless of depth, essential for the 62-layer dry-runs); per-
layer heterogeneity is expressed as scan-time flag arrays (local/global
attention) or as a small unrolled prefix (DeepSeekMoE's dense first layer).
The 12-block xLSTM stack alternates two parameter shapes and is unrolled.

API surface used by the runtime and launcher:

    init_params(key, cfg)          -> params pytree
    param_pspecs(cfg)              -> PartitionSpec pytree (TP over 'model')
    forward(params, cfg, batch)    -> logits            (train / prefill)
    loss_fn(params, cfg, batch)    -> scalar CE loss
    init_cache(cfg, batch, seq)    -> decode cache pytree
    decode_step(params, cfg, token, cache, position) -> (logits, cache)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import ssm as S
from .config import ModelConfig


# ---------------------------------------------------------------------------
# per-layer flags
# ---------------------------------------------------------------------------

def global_attention_flags(cfg: ModelConfig):
    """(L,) host bool array: global vs sliding-window attention per layer.

    Host-side numpy so unrolled prefix layers can branch statically; the
    scanned body consumes it as a traced per-layer xs input.
    """
    import numpy as np
    n = cfg.num_layers
    if cfg.sliding_window is None:
        return np.ones((n,), bool)
    if cfg.global_every:                       # gemma3: every Nth is global
        return np.asarray([(i % cfg.global_every) == cfg.global_every - 1
                           for i in range(n)])
    if cfg.family == "hybrid":                 # hymba: first / middle / last
        keep = {0, n // 2, n - 1}
        return np.asarray([i in keep for i in range(n)])
    return np.zeros((n,), bool)               # pure sliding-window


def _is_slstm_block(cfg: ModelConfig, i: int) -> bool:
    e = cfg.ssm.slstm_every if cfg.ssm else 0
    return bool(e) and (i % e == e - 1)


# ---------------------------------------------------------------------------
# single decoder layer (attention family)
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, dense_ffn: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "norm1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "norm2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.hybrid_parallel:
        p["ssm_head"] = S.init_mamba_head(ks[2], cfg)
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = L.init_moe(ks[1], cfg)
    elif cfg.d_ff or dense_ffn:
        d_ff = cfg.d_ff
        if dense_ffn and cfg.moe is not None:
            d_ff = cfg.moe.d_expert * (cfg.moe.top_k + cfg.moe.num_shared)
        p["mlp"] = L.init_mlp(ks[1], cfg, d_ff=d_ff)
    return p


def _layer_pspecs(cfg: ModelConfig, dense_ffn: bool = False) -> dict:
    p = {"norm1": {"scale": P()}, "attn": L.attention_pspecs(cfg),
         "norm2": {"scale": P()}}
    if cfg.hybrid_parallel:
        p["ssm_head"] = {
            "w_in": P(None, "model"), "w_dt": P(), "dt_bias": P(),
            "w_bc": P(), "a_log": P("model", None), "skip_scale": P("model"),
            "w_out": P("model", None),
        }
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = L.moe_pspecs(cfg)
    elif cfg.d_ff or dense_ffn:
        p["mlp"] = L.mlp_pspecs(cfg)
    return p


def _layer_forward(p: dict, x: jax.Array, cfg: ModelConfig, is_global,
                   positions) -> jax.Array:
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    a = L.attn_forward(p["attn"], h, cfg, is_global=is_global,
                       positions=positions)
    if cfg.hybrid_parallel:
        m = S.mamba_forward(p["ssm_head"], h, cfg)
        a = 0.5 * (a + m)
    x = x + a
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe_forward(p["moe"], h, cfg)
    elif "mlp" in p:
        x = x + L.mlp_forward(p["mlp"], h, cfg)
    return x


def _layer_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict,
                  position, is_global):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    a, ck, cv = L.attn_decode(p["attn"], h, cfg, cache["k"], cache["v"],
                              position, is_global=is_global)
    new_cache = {"k": ck, "v": cv}
    if cfg.hybrid_parallel:
        m, st = S.mamba_decode(p["ssm_head"], h, cfg,
                               cache["ssm"])
        a = 0.5 * (a + m)
        new_cache["ssm"] = st
    x = x + a
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe_forward(p["moe"], h, cfg)
    elif "mlp" in p:
        x = x + L.mlp_forward(p["mlp"], h, cfg)
    return x, new_cache


# ---------------------------------------------------------------------------
# parameter init / pspecs for the whole model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {
        "embed": {"tok": (jax.random.normal(ks[0], (v, d)) * 0.02).astype(dt)},
        "final_norm": L.init_rmsnorm(d),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": (jax.random.normal(ks[1], (d, v))
                                * (1.0 / math.sqrt(d))).astype(dt)}
    if cfg.vision_patches:
        params["embed"]["patch_proj"] = {
            "w": (jax.random.normal(ks[2], (cfg.vision_feat_dim, d))
                  * (1.0 / math.sqrt(cfg.vision_feat_dim))).astype(dt)}

    if cfg.family == "ssm":      # xLSTM: unrolled heterogeneous blocks
        blocks = []
        bkeys = jax.random.split(ks[3], cfg.num_layers)
        for i in range(cfg.num_layers):
            if _is_slstm_block(cfg, i):
                blocks.append({"norm1": L.init_rmsnorm(d),
                               "slstm": S.init_slstm(bkeys[i], cfg)})
            else:
                blocks.append({"norm1": L.init_rmsnorm(d),
                               "mlstm": S.init_mlstm(bkeys[i], cfg)})
        params["blocks"] = blocks
        return params

    if cfg.family == "encdec":   # whisper: encoder + decoder stacks
        params["embed"]["frame_proj"] = {
            "w": (jax.random.normal(ks[2], (d, d)) * (1 / math.sqrt(d))).astype(dt)}
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_encoder_layer(k, cfg))(enc_keys)
        dec_keys = jax.random.split(ks[5], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_decoder_xlayer(k, cfg))(dec_keys)
        return params

    # decoder-only families (dense / moe / hybrid / vlm)
    n_prefix = cfg.moe.first_dense if cfg.moe else 0
    if n_prefix:
        pkeys = jax.random.split(ks[6], n_prefix)
        params["prefix"] = [_init_layer(pk, cfg, dense_ffn=True)
                            for pk in pkeys]
    body = cfg.num_layers - n_prefix
    lkeys = jax.random.split(ks[7], body)
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(lkeys)
    return params


def param_pspecs(cfg: ModelConfig) -> dict:
    """PartitionSpec tree matching init_params (TP over 'model' axis)."""
    specs: dict[str, Any] = {
        "embed": {"tok": P("model", None)},
        "final_norm": {"scale": P()},
    }
    if not cfg.tie_embeddings:
        specs["head"] = {"w": P(None, "model")}
    if cfg.vision_patches:
        specs["embed"]["patch_proj"] = {"w": P(None, "model")}

    if cfg.family == "ssm":
        blocks = []
        for i in range(cfg.num_layers):
            if _is_slstm_block(cfg, i):
                blocks.append({"norm1": {"scale": P()}, "slstm": {
                    "w_in": P(None, "model"), "r": P("model", None, None),
                    "bias": P("model"), "w_down": P("model", None)}})
            else:
                blocks.append({"norm1": {"scale": P()}, "mlstm": {
                    "w_up": P(None, "model"), "w_q": P("model", None),
                    "w_k": P("model", None), "w_v": P("model", None),
                    "w_ogate": P(None, "model"), "w_if": P("model", None),
                    "if_bias": P(), "w_down": P("model", None)}})
        specs["blocks"] = blocks
        return specs

    def stack(tree):
        return jax.tree.map(
            lambda s: P(None, *s) if isinstance(s, P) else s, tree,
            is_leaf=lambda x: isinstance(x, P))

    if cfg.family == "encdec":
        specs["embed"]["frame_proj"] = {"w": P(None, "model")}
        specs["encoder"] = stack(_encoder_layer_pspecs(cfg))
        specs["layers"] = stack(_decoder_xlayer_pspecs(cfg))
        return specs

    n_prefix = cfg.moe.first_dense if cfg.moe else 0
    if n_prefix:
        specs["prefix"] = [_layer_pspecs(cfg, dense_ffn=True)
                           for _ in range(n_prefix)]
    specs["layers"] = stack(_layer_pspecs(cfg))
    return specs


# ---------------------------------------------------------------------------
# encoder-decoder layers (whisper)
# ---------------------------------------------------------------------------

def _init_encoder_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {"norm1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg)}


def _encoder_layer_pspecs(cfg: ModelConfig) -> dict:
    return {"norm1": {"scale": P()}, "attn": L.attention_pspecs(cfg),
            "norm2": {"scale": P()}, "mlp": L.mlp_pspecs(cfg)}


def _encoder_layer_forward(p: dict, x: jax.Array, cfg: ModelConfig):
    """Bidirectional self-attention encoder layer."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + L.attn_forward(p["attn"], h, cfg, causal=False)
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    return x + L.mlp_forward(p["mlp"], h, cfg)


def _init_decoder_xlayer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {"norm1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg),
            "norm_x": L.init_rmsnorm(cfg.d_model),
            "cross": L.init_cross_attention(ks[1], cfg),
            "norm2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[2], cfg)}


def _decoder_xlayer_pspecs(cfg: ModelConfig) -> dict:
    return {"norm1": {"scale": P()}, "attn": L.attention_pspecs(cfg),
            "norm_x": {"scale": P()}, "cross": L.attention_pspecs(cfg),
            "norm2": {"scale": P()}, "mlp": L.mlp_pspecs(cfg)}


def _decoder_xlayer_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                            enc_k, enc_v, positions):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + L.attn_forward(p["attn"], h, cfg, positions=positions)
    h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
    x = x + L.cross_attn_forward(p["cross"], h, cfg, enc_k, enc_v)
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    return x + L.mlp_forward(p["mlp"], h, cfg)


def _decoder_xlayer_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                           cache: dict, position):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    a, ck, cv = L.attn_decode(p["attn"], h, cfg, cache["k"], cache["v"],
                              position)
    x = x + a
    h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
    x = x + L.cross_attn_forward(p["cross"], h, cfg, cache["cross_k"],
                                 cache["cross_v"])
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + L.mlp_forward(p["mlp"], h, cfg)
    return x, {"k": ck, "v": cv, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"]}


# ---------------------------------------------------------------------------
# embeddings and head
# ---------------------------------------------------------------------------

def _embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  patch_feats: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if cfg.vision_patches and patch_feats is not None:
        proj = patch_feats.astype(x.dtype) @ params["embed"]["patch_proj"]["w"]
        x = jnp.concatenate([proj, x], axis=1)      # prepend image patches
    return L.shard(x, None, None, None)


def _logits(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["head"]["w"]
    logits = x @ w
    return L.shard(logits, None, None, "model")


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def _ckpt(fn, cfg: ModelConfig):
    """Per-layer remat with configurable policy.

    'full' recomputes everything in backward (min memory, but re-runs the
    layer's TP collectives); 'dots' saves dot outputs — the tensors the
    SPMD partitioner all-reduces — trading activation memory for a ~1/3
    cut of the per-layer collective traffic (no recomputed ARs).
    """
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def forward(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """batch: {'tokens': (B,S)[, 'patch_feats': (B,P,F)][, 'frames': (B,T,d)]}.

    Returns logits (B, S_total, V).
    """
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens, batch.get("patch_feats"))
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    if cfg.family == "ssm":
        for i, blk in enumerate(params["blocks"]):
            h = L.rmsnorm(blk["norm1"], x, cfg.norm_eps)
            if "slstm" in blk:
                x = x + S.slstm_forward(blk["slstm"], h, cfg)
            else:
                x = x + S.mlstm_forward(blk["mlstm"], h, cfg)
        return _logits(params, cfg, x)

    if cfg.family == "encdec":
        frames = batch["frames"]
        enc = frames.astype(x.dtype) @ params["embed"]["frame_proj"]["w"]

        def enc_body(h, lp):
            return _encoder_layer_forward(lp, h, cfg), None
        enc_body = _ckpt(enc_body, cfg)
        enc, _ = jax.lax.scan(enc_body, enc, params["encoder"])

        def dec_body(h, lp):
            ek, ev = L.cross_kv(lp["cross"], enc, cfg)
            return _decoder_xlayer_forward(lp, h, cfg, ek, ev, positions), None
        dec_body = _ckpt(dec_body, cfg)
        x, _ = jax.lax.scan(dec_body, x, params["layers"])
        return _logits(params, cfg, x)

    flags = global_attention_flags(cfg)
    n_prefix = cfg.moe.first_dense if cfg.moe else 0
    for i, lp in enumerate(params.get("prefix", [])):
        x = _layer_forward(lp, x, cfg, bool(flags[i]), positions)

    def body(h, xs):
        lp, is_global = xs
        return _layer_forward(lp, h, cfg, is_global, positions), None
    body = _ckpt(body, cfg)
    x, _ = jax.lax.scan(body, x, (params["layers"],
                                  jnp.asarray(flags[n_prefix:])))
    return _logits(params, cfg, x)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Mean next-token cross entropy in fp32 (vocab-sharded safe)."""
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:       # VLM: drop patch positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    ce = logz - gold
    if mask is not None:
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_out: Optional[jax.Array] = None,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree sized for ``max_seq`` past tokens."""
    kv = cfg.num_kv_heads
    hd = cfg.hd
    n = cfg.num_layers

    if cfg.family == "ssm":
        blocks = []
        for i in range(n):
            if _is_slstm_block(cfg, i):
                blocks.append({"slstm": S.slstm_init_state(cfg, batch)})
            else:
                blocks.append({"mlstm": S.mlstm_init_state(cfg, batch)})
        return {"blocks": blocks}

    if cfg.family == "encdec":
        cache = {
            "k": jnp.zeros((n, batch, max_seq, kv, hd), dtype),
            "v": jnp.zeros((n, batch, max_seq, kv, hd), dtype),
        }
        t_enc = enc_out.shape[1] if enc_out is not None else cfg.encoder_seq
        cache["cross_k"] = jnp.zeros((n, batch, t_enc, kv, hd), dtype)
        cache["cross_v"] = jnp.zeros((n, batch, t_enc, kv, hd), dtype)
        return cache

    # window-bounded cache for pure sliding-window layers keeps long_500k
    # decode sub-quadratic AND sub-linear in memory for local layers; the
    # (few) global layers keep the full horizon.
    cache = {
        "k": jnp.zeros((n, batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((n, batch, max_seq, kv, hd), dtype),
    }
    if cfg.hybrid_parallel:
        cache["ssm"] = jnp.zeros((n, batch, cfg.d_model,
                                  cfg.ssm.state_size), jnp.float32)
    return cache


def cache_pspecs(cfg: ModelConfig, *, shard_seq: bool = False,
                 dp_axes=("pod", "data")) -> dict:
    """PartitionSpecs for the decode cache.

    Default: batch over DP, KV heads over model.  ``shard_seq=True`` is the
    long-context (batch=1) layout: the *sequence* dim of the KV cache is
    sharded over the DP axes instead (flash-decode style SP), which GSPMD
    resolves into partial-softmax + combine collectives.
    """
    dp = tuple(dp_axes)
    if cfg.family == "ssm":
        bspec = P() if shard_seq else P(dp)     # batch-dim sharding
        blocks = []
        for i in range(cfg.num_layers):
            key = "slstm" if _is_slstm_block(cfg, i) else "mlstm"
            blocks.append({key: jax.tree.map(
                lambda _: bspec, {"c": 0, "n": 0, "h": 0, "m": 0}
                if key == "slstm" else {"C": 0, "n": 0, "m": 0})})
        return {"blocks": blocks}
    if shard_seq:
        # long-context batch=1: sequence sharded over every mesh axis
        # (flash-decode / sequence parallelism; GSPMD emits the
        # partial-softmax combine collectives)
        kv_spec = P(None, None, dp + ("model",), None, None)
    else:
        # batched decode: batch over DP, cache sequence over 'model'
        kv_spec = P(None, dp, "model", None, None)
    cache = {"k": kv_spec, "v": kv_spec}
    if cfg.family == "encdec":
        cache["cross_k"] = kv_spec
        cache["cross_v"] = kv_spec
        return cache
    if cfg.hybrid_parallel:
        cache["ssm"] = P(None, dp if not shard_seq else None, "model", None)
    return cache


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict, position) -> tuple[jax.Array, dict]:
    """One-token decode.  token: (B, 1) int32; position: scalar int32 or
    (B,) int32 for continuous batching (per-row depths, see attn_decode)."""
    x = jnp.take(params["embed"]["tok"], token, axis=0)

    if cfg.family == "ssm":
        new_blocks = []
        for i, (blk, cb) in enumerate(zip(params["blocks"], cache["blocks"])):
            h = L.rmsnorm(blk["norm1"], x, cfg.norm_eps)
            if "slstm" in blk:
                y, st = S.slstm_decode(blk["slstm"], h, cfg, cb["slstm"])
                new_blocks.append({"slstm": st})
            else:
                y, st = S.mlstm_decode(blk["mlstm"], h, cfg,
                                       cb["mlstm"])
                new_blocks.append({"mlstm": st})
            x = x + y
        return _logits(params, cfg, x)[:, 0], {"blocks": new_blocks}

    if cfg.family == "encdec":
        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            h, nc = _decoder_xlayer_decode(
                lp, h, cfg, {"k": ck, "v": cv, "cross_k": xk, "cross_v": xv},
                position)
            return h, (nc["k"], nc["v"])
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, k=nk, v=nv)
        return _logits(params, cfg, x)[:, 0], new_cache

    flags = global_attention_flags(cfg)
    n_prefix = cfg.moe.first_dense if cfg.moe else 0
    new_cache = dict(cache)
    # unrolled prefix layers use the leading slices of the stacked cache
    for i, lp in enumerate(params.get("prefix", [])):
        sub = {"k": cache["k"][i], "v": cache["v"][i]}
        if cfg.hybrid_parallel:
            sub["ssm"] = cache["ssm"][i]
        x, nc = _layer_decode(lp, x, cfg, sub, position, bool(flags[i]))
        new_cache["k"] = new_cache["k"].at[i].set(nc["k"])
        new_cache["v"] = new_cache["v"].at[i].set(nc["v"])

    if cfg.hybrid_parallel:
        def body(h, xs):
            lp, is_global, ck, cv, cs = xs
            h, nc = _layer_decode(lp, h, cfg, {"k": ck, "v": cv, "ssm": cs},
                                  position, is_global)
            return h, (nc["k"], nc["v"], nc["ssm"])
        x, (nk, nv, ns) = jax.lax.scan(
            body, x, (params["layers"], jnp.asarray(flags[n_prefix:]),
                      cache["k"][n_prefix:], cache["v"][n_prefix:],
                      cache["ssm"][n_prefix:]))
        new_cache["k"] = jnp.concatenate([new_cache["k"][:n_prefix], nk]) \
            if n_prefix else nk
        new_cache["v"] = jnp.concatenate([new_cache["v"][:n_prefix], nv]) \
            if n_prefix else nv
        new_cache["ssm"] = jnp.concatenate([cache["ssm"][:n_prefix], ns]) \
            if n_prefix else ns
    else:
        def body(h, xs):
            lp, is_global, ck, cv = xs
            h, nc = _layer_decode(lp, h, cfg, {"k": ck, "v": cv},
                                  position, is_global)
            return h, (nc["k"], nc["v"])
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], jnp.asarray(flags[n_prefix:]),
                      cache["k"][n_prefix:], cache["v"][n_prefix:]))
        if n_prefix:
            nk = jnp.concatenate([new_cache["k"][:n_prefix], nk])
            nv = jnp.concatenate([new_cache["v"][:n_prefix], nv])
        new_cache["k"], new_cache["v"] = nk, nv

    return _logits(params, cfg, x)[:, 0], new_cache


def count_params(params: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
