"""Composable model definitions for the assigned architecture families."""
from .config import (ModelConfig, MoEConfig, ShapeCell, SHAPES,
                     SHAPES_BY_NAME, SsmConfig)
from .transformer import (cache_pspecs, count_params, decode_step, forward,
                          global_attention_flags, init_cache, init_params,
                          loss_fn, param_pspecs)

__all__ = [
    "ModelConfig", "MoEConfig", "ShapeCell", "SHAPES", "SHAPES_BY_NAME",
    "SsmConfig", "cache_pspecs", "count_params", "decode_step", "forward",
    "global_attention_flags", "init_cache", "init_params", "loss_fn",
    "param_pspecs",
]
