"""Sharding utilities: divisibility-safe PartitionSpecs.

``jit`` in/out shardings require every sharded dimension to divide evenly
by the product of its mesh axes (unlike activation *constraints*, which
GSPMD pads).  Architectures with odd head counts (hymba's 25 heads, xlstm's
4) or small leaves would otherwise fail to lower, so every explicit spec
tree is sanitized against the concrete shapes: non-divisible entries fall
back to replication for that dimension (the memory cost lives in the big,
always-divisible matrices anyway).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_is_spec = lambda x: isinstance(x, P) or x is None


def _axis_size(mesh, entry) -> int:
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P | None, shape, mesh) -> P:
    if spec is None:
        return P()
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for entry, dim in zip(entries, shape):
        if entry is None:
            out.append(None)
        elif dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def sanitize_pspecs(spec_tree: Any, like_tree: Any, mesh) -> Any:
    """Spec pytree + shape pytree -> divisibility-safe spec pytree."""
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    flat_spec = treedef.flatten_up_to(spec_tree)
    fixed = [sanitize_spec(s, l.shape, mesh)
             for s, l in zip(flat_spec, flat_like)]
    return jax.tree_util.tree_unflatten(treedef, fixed)


def named_shardings(spec_tree: Any, mesh, like_tree: Any | None = None) -> Any:
    """Spec pytree -> NamedSharding pytree (sanitized if shapes given)."""
    if like_tree is not None:
        spec_tree = sanitize_pspecs(spec_tree, like_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=_is_spec)
