"""Serving runtime: batched prefill + decode steps on the production mesh.

Serving has no gradient traffic, so NEURON-Fabric modes are a no-op here
(the paper's identity/bypass path); the cells still exercise the full
distribution stack: batch over DP, heads over TP, and — for the
long-context batch=1 cell — the KV-cache *sequence* dim sharded over the
DP axes (flash-decode style sequence parallelism, resolved by GSPMD into
partial-softmax + combine collectives).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import (ModelConfig, cache_pspecs, decode_step, forward,
                      init_cache, init_params, param_pspecs)
from .shardings import named_shardings


def serve_shardings(cfg: ModelConfig, mesh, *, batch: int, max_seq: int,
                    dp_axes=("data",)) -> dict:
    """Input/output shardings for one decode step.

    If the global batch is divisible by the DP degree, batch is sharded
    over DP and the cache over (batch x kv-heads).  Otherwise (the
    long_500k batch=1 cell) the cache sequence dim is sharded over DP.
    """
    dp = tuple(dp_axes)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    shard_seq = batch % dp_size != 0
    tok_spec = P() if shard_seq else P(dp, None)
    cache_specs = cache_pspecs(cfg, shard_seq=shard_seq, dp_axes=dp)
    cache_like = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    return {
        "token": NamedSharding(mesh, tok_spec),
        "cache": named_shardings(cache_specs, mesh, cache_like),
        "shard_seq": shard_seq,
    }


def build_serve_step(cfg: ModelConfig, mesh=None, *, batch: int,
                     max_seq: int, dp_axes=("data",), donate: bool = True):
    """jitted (params, token, cache, position) -> (logits, cache).

    ``position`` may be scalar or (B,) int32 (continuous batching — see
    :func:`repro.models.decode_step`).  ``mesh=None`` builds the same
    step single-host/unsharded (the serving-engine and unit-test path).
    """
    def step(params, token, cache, position):
        return decode_step(params, cfg, token, cache, position)

    if mesh is None:
        sh = {"token": None, "cache": None, "params": None,
              "shard_seq": False}
        return jax.jit(step, donate_argnums=(2,) if donate else ()), sh

    sh = serve_shardings(cfg, mesh, batch=batch, max_seq=max_seq,
                         dp_axes=dp_axes)
    params_like = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_sh = named_shardings(param_pspecs(cfg), mesh, params_like)

    sh["params"] = p_sh
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, sh["token"], sh["cache"], None),
        out_shardings=(None, sh["cache"]),
        donate_argnums=(2,) if donate else ())
    return jitted, sh


def build_cached_prefill(cfg: ModelConfig, mesh=None, *,
                         dp_axes=("data",), donate: bool = True):
    """jitted (params, tokens, length, cache) -> (last_logits, cache).

    Cache-filling prefill: feeds ``tokens[:, :length]`` through
    :func:`decode_step` with a ``fori_loop`` over a *traced* length, so
    one compile covers every prompt length up to the padded width.
    ``tokens`` is (B, P) int32 (pad past ``length`` arbitrarily);
    returns the logits at the last prompt position plus the cache filled
    at positions ``[0, length)`` — ready for decode at ``length``.
    """
    def run(params, tokens, length, cache):
        tok0 = jax.lax.dynamic_slice_in_dim(tokens, 0, 1, axis=1)
        logits, cache = decode_step(params, cfg, tok0, cache,
                                    jnp.int32(0))

        def body(i, carry):
            _, c = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
            return decode_step(params, cfg, tok, c, i)

        return jax.lax.fori_loop(1, length, body, (logits, cache))

    if mesh is None:
        return jax.jit(run, donate_argnums=(3,) if donate else ())

    params_like = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_sh = named_shardings(param_pspecs(cfg), mesh, params_like)
    b_sh = NamedSharding(mesh, P(tuple(dp_axes), None))
    return jax.jit(run, in_shardings=(p_sh, b_sh, None, None),
                   out_shardings=None,
                   donate_argnums=(3,) if donate else ())


def build_prefill(cfg: ModelConfig, mesh, *, dp_axes=("data",)):
    """jitted prefill: (params, batch) -> logits, batch sharded over DP."""
    dp = tuple(dp_axes)
    params_like = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_sh = named_shardings(param_pspecs(cfg), mesh, params_like)
    b_sh = NamedSharding(mesh, P(dp))

    def run(params, batch):
        return forward(params, cfg, batch)

    return jax.jit(run, in_shardings=(p_sh, b_sh), out_shardings=None)
