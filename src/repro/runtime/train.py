"""Distributed training runtime: the NEURON-Fabric train step and Trainer.

The train step is the integration point of the whole system (DESIGN.md §4):

  * gradients are computed inside a *partial-manual* ``jax.shard_map`` —
    manual over the DP axes (``('pod','data')``), auto over ``'model'`` —
    so per-worker gradients are visible to the aggregation layer exactly
    like per-worker payloads are visible to the paper's controller;
  * each bucket is aggregated under its admitted mode through the
    :class:`repro.fabric.Fabric` session: FP32 buckets via psum, low-bit
    buckets via whichever registered schedule backend the plan names;
  * the optimizer runs *outside* the shard_map in auto-SPMD land, so
    ZeRO-1 optimizer-state sharding is pure GSPMD;
  * one compiled step per AdmissionPlan signature, cached inside the
    Fabric — the XLA analogue of the paper's controller mode latch.

The Trainer owns the host-side control loop: checkpointing, failure
recovery, the straggler watchdog, and — via an attached
:class:`repro.fabric.control.Controller` — the admission-control plane.
Each step it emits one typed :class:`~repro.fabric.control.Telemetry`
record (built from the Fabric-compiled step's metrics) to the
controller, which owns warm-up/calibration/admission/recovery policy and
the mode latch.  Step compilation and aggregation policy live in the
Fabric session it drives.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from ..core import AdmissionPlan, GroupRules, plan_traffic_ratio
from ..checkpoint import CheckpointManager
from ..fabric import CompiledStep, Fabric, TrainState, dp_num_workers
from ..fabric.control import Telemetry, make_controller
from ..fabric.session import _named
from ..models import ModelConfig, init_params, param_pspecs
from ..optim import Optimizer
from .fault import (FailureInjector, SimulatedFailure, StepTimer,
                    StragglerWatchdog)

log = logging.getLogger("repro.train")

__all__ = ["TrainState", "Trainer", "TrainerConfig", "build_train_step",
           "dp_num_workers"]


# ---------------------------------------------------------------------------
# step builder (legacy shim)
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh, optimizer: Optimizer,
                     plan: AdmissionPlan, params_like: Any, *,
                     dp_axes=("data",), rules: GroupRules | None = None,
                     with_diagnostics: bool = False,
                     loss: Callable | None = None,
                     zero1: bool = True,
                     grad_accum: int = 1,
                     donate: bool = True) -> CompiledStep:
    """Deprecated free-function shim — use ``Fabric(...).build_step``.

    Constructs a throwaway session and compiles one step; returns the
    legacy 4-tuple-compatible :class:`CompiledStep`
    ``(jitted, state_shardings, batch_sharding, aux)``.
    """
    fabric = Fabric(mesh, dp_axes, rules=rules)
    return fabric.build_step(cfg, optimizer, plan, params_like,
                             with_diagnostics=with_diagnostics, loss=loss,
                             zero1=zero1, grad_accum=grad_accum,
                             donate=donate)


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    dp_axes: tuple = ("data",)
    checkpoint_interval: int = 100
    checkpoint_keep: int = 3
    log_interval: int = 10
    max_restarts: int = 10
    zero1: bool = True


class Trainer:
    """Host control loop with admission control and fault tolerance.

    Runs on a :class:`repro.fabric.Fabric` session — pass one via
    ``fabric=`` to share schedule backends / compiled-step caches across
    components, or let the Trainer construct its own from ``mesh`` and
    ``tcfg.dp_axes``.

    Admission control is a pluggable controller: pass ``controller=``
    (an instance or a ``@register_controller`` name) or attach one to
    the session beforehand (``fabric.attach_controller(...)``) — both
    drive the same telemetry -> observe -> latch path.  ``plan=``
    without a controller is the static fast path (bit-identical to
    pre-controller behaviour).
    """

    def __init__(self, cfg: ModelConfig, mesh, optimizer: Optimizer,
                 data: Iterator[dict], *,
                 tcfg: TrainerConfig | None = None,
                 controller=None,
                 control=None,
                 plan: AdmissionPlan | None = None,
                 rules: GroupRules | None = None,
                 fabric: Fabric | None = None,
                 ckpt_dir: str | None = None,
                 failure_injector: FailureInjector | None = None,
                 loss: Callable | None = None,
                 seed: int = 0):
        if tcfg is None:
            # fresh per-Trainer config (a dataclass default instance would
            # be shared across every Trainer constructed without one)
            tcfg = TrainerConfig(dp_axes=(fabric.dp_axes if fabric is not None
                                          else ("data",)))
        if fabric is None:
            fabric = Fabric(mesh, tcfg.dp_axes, rules=rules)
        else:
            # an explicit fabric owns mesh + dp_axes + rules; conflicting
            # direct arguments would otherwise be silently ignored
            if mesh is not None and mesh != fabric.mesh:
                raise ValueError("mesh argument conflicts with fabric.mesh; "
                                 "pass one or the other")
            if tuple(tcfg.dp_axes) != fabric.dp_axes:
                raise ValueError(
                    f"tcfg.dp_axes {tuple(tcfg.dp_axes)} conflicts with "
                    f"fabric.dp_axes {fabric.dp_axes}; construct the Fabric "
                    f"with these axes")
            if rules is not None and rules is not fabric.rules:
                raise ValueError("rules argument conflicts with fabric.rules"
                                 "; construct the Fabric with these rules")
        self.fabric = fabric
        self.cfg, self.mesh, self.optimizer = cfg, fabric.mesh, optimizer
        self.tcfg = tcfg
        self.rules = fabric.rules
        # controller resolution: explicit argument (new `controller=` or
        # legacy `control=`) > the session's attached controller
        if controller is not None and control is not None:
            raise ValueError("pass either controller= or the deprecated "
                             "control=, not both")
        controller = controller if controller is not None else control
        if isinstance(controller, str):
            controller = make_controller(controller)
        if controller is None:
            controller = fabric.controller
        elif fabric.controller is not None \
                and fabric.controller is not controller:
            raise ValueError("controller argument conflicts with the "
                             "controller already attached to this fabric")
        else:
            fabric.attach_controller(controller)
        self.controller = controller
        self.control = controller          # backward-compatible alias
        self.static_plan = plan
        self.data = data
        self.loss = loss
        self.seed = seed
        self.failure_injector = failure_injector
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(ckpt_dir,
                                       interval=tcfg.checkpoint_interval,
                                       keep=tcfg.checkpoint_keep)
                     if ckpt_dir else None)
        self.state: TrainState | None = None
        self.history: list[dict] = []
        self.restarts = 0
        self.traffic_log: list[float] = []
        self._sizes = None
        self._just_restarted = False

    # -- state ----------------------------------------------------------
    def init_state(self) -> TrainState:
        key = jax.random.PRNGKey(self.seed)
        with jax.set_mesh(self.mesh):
            params = init_params(key, self.cfg)
        pspecs = param_pspecs(self.cfg)
        params = jax.device_put(params, _named(self.mesh, pspecs))
        opt = self.optimizer.init(params)
        plan = self._current_plan()
        policies = self.fabric.resolve(params, plan, pspecs=pspecs)
        ef = self.fabric.init_ef(params, policies)
        self.state = TrainState(params=params, opt=opt, ef=ef,
                                step=jnp.zeros((), jnp.int32))
        self._sizes = self.fabric.group_sizes(params)
        return self.state

    def _current_plan(self) -> AdmissionPlan:
        if self.controller is not None:
            return self.controller.plan
        return self.static_plan or AdmissionPlan.fp32_all()

    def _get_step(self, plan: AdmissionPlan, diagnostics: bool):
        step = self.fabric.step_for(
            self.cfg, self.optimizer, plan, self.state.params,
            with_diagnostics=diagnostics, loss=self.loss,
            zero1=self.tcfg.zero1)
        return step, step.batch_sharding

    # -- loop -----------------------------------------------------------
    def run(self, num_steps: int) -> list[dict]:
        if self.state is None:
            if self.ckpt is not None:
                restored = None
                try:
                    self.init_state()
                    restored = self.ckpt.restore(self.state,
                                                 controller=self.controller)
                except FileNotFoundError:
                    restored = None
                if restored is not None:
                    step, tree, _ = restored
                    self.state = tree
                    self._just_restarted = True
                    log.info("restored checkpoint at step %d", step)
            else:
                self.init_state()

        it = iter(self.data)
        done = int(self.state.step)
        while done < num_steps:
            try:
                done = self._run_until(num_steps, it)
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                log.warning("%s -> restart %d (restore + replay)",
                            e, self.restarts)
                self._recover()
                done = int(self.state.step)
        if self.ckpt is not None:
            self.ckpt.maybe_save(int(self.state.step), self.state, force=True,
                                 controller=self.controller)
            self.ckpt.wait()
        return self.history

    def _recover(self):
        """Node-failure recovery: restore last durable checkpoint.

        The controller is restored alongside the model state, so CUSUM
        statistics, the Supervisor cooldown, and the admitted plan pick
        up where the checkpoint left them instead of resetting the
        control plane to warm-up.
        """
        if self.ckpt is None:
            raise RuntimeError("failure without checkpointing enabled")
        restored = self.ckpt.restore(self.state, controller=self.controller)
        if restored is None:
            self.init_state()
        else:
            _, self.state, _ = restored
        self._just_restarted = True

    def _run_until(self, num_steps: int, it: Iterator[dict]) -> int:
        while int(self.state.step) < num_steps:
            step = int(self.state.step)
            if self.failure_injector is not None:
                self.failure_injector.check(step)

            plan = self._current_plan()
            # the controller owns the calibration window (single source of
            # truth for warm-up length): compile with diagnostics while it
            # asks for them, so admission can retry until cosines land
            calibrating = bool(self.controller is not None and getattr(
                self.controller, "wants_diagnostics", False))
            jitted, b_sh = self._get_step(plan, calibrating)
            if hasattr(self.data, "batch_at"):   # deterministic replay
                batch = self.data.batch_at(step)
            else:
                batch = next(it)
            batch = jax.tree.map(
                lambda a: jax.device_put(jnp.asarray(a), b_sh), batch)

            with StepTimer() as t:
                self.state, metrics = jitted(self.state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
            self.watchdog.observe(step, t.duration)

            metrics["step"] = step
            metrics["plan"] = plan.signature()
            metrics["traffic_ratio"] = plan_traffic_ratio(self._sizes, plan)
            self.traffic_log.append(metrics["traffic_ratio"])
            self.history.append(metrics)

            if self.controller is not None:
                telemetry = Telemetry.from_metrics(
                    step, metrics, step_time_s=t.duration,
                    restart=self._just_restarted)
                self._just_restarted = False
                self.controller.observe(telemetry)

            if self.ckpt is not None:
                self.ckpt.maybe_save(step + 1, self.state,
                                     extra={"plan": plan.signature()},
                                     controller=self.controller)
            if step % self.tcfg.log_interval == 0:
                log.info("step %d loss %.4f traffic %.4f plan=%s", step,
                         metrics["loss"], metrics["traffic_ratio"],
                         plan.signature()[:48])
        return int(self.state.step)
