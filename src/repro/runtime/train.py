"""Distributed training runtime: the NEURON-Fabric train step and Trainer.

The train step is the integration point of the whole system (DESIGN.md §4):

  * gradients are computed inside a *partial-manual* ``jax.shard_map`` —
    manual over the DP axes (``('pod','data')``), auto over ``'model'`` —
    so per-worker gradients are visible to the aggregation layer exactly
    like per-worker payloads are visible to the paper's controller;
  * each bucket is aggregated under its admitted mode
    (core.aggregate_gradients): FP32 buckets via psum, low-bit buckets via
    int8 vote psum or the packed all_to_all controller schedule;
  * the optimizer runs *outside* the shard_map in auto-SPMD land, so
    ZeRO-1 optimizer-state sharding is pure GSPMD;
  * one compiled step per AdmissionPlan signature, cached — the XLA
    analogue of the paper's controller mode latch.

The Trainer owns the host-side control loop: warm-up/calibration, the
Predictor/Commander/Supervisor control plane, checkpointing, failure
recovery, and the straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import (AdmissionPlan, ControlPlane, GroupRules,
                    aggregate_gradients, assign_groups, cosines_to_host,
                    group_cosines_from_mean, group_sizes, init_ef_states,
                    plan_traffic_ratio, resolve_policies)
from ..checkpoint import CheckpointManager
from ..models import ModelConfig, init_params, loss_fn as model_loss_fn, \
    param_pspecs
from ..optim import Optimizer, optimizer_state_pspecs
from .fault import (FailureInjector, SimulatedFailure, StepTimer,
                    StragglerWatchdog)
from .shardings import sanitize_pspecs

log = logging.getLogger("repro.train")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    ef: Any                    # error-feedback residuals (sentinel tree)
    step: jax.Array


def dp_num_workers(mesh, dp_axes) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes]))


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


# ---------------------------------------------------------------------------
# step builder
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh, optimizer: Optimizer,
                     plan: AdmissionPlan, params_like: Any, *,
                     dp_axes=("data",), rules: GroupRules | None = None,
                     with_diagnostics: bool = False,
                     loss: Callable | None = None,
                     zero1: bool = True,
                     grad_accum: int = 1,
                     donate: bool = True):
    """Compile one train step for a given admission plan.

    ``params_like``: a concrete or abstract (ShapeDtypeStruct) params tree —
    used only for structure/paths.  ``grad_accum`` splits the per-device
    batch into that many sequentially-scanned microbatches (activation
    memory / grad_accum, one aggregation per step — communication volume
    unchanged, overlap-friendly).  Returns (jitted_step, state_shardings,
    batch_shardings, aux).
    """
    rules = rules or GroupRules()
    dp = tuple(dp_axes)
    w = dp_num_workers(mesh, dp)
    pspecs = sanitize_pspecs(param_pspecs(cfg), params_like, mesh)
    policies = resolve_policies(params_like, plan, pspecs=pspecs, rules=rules)
    groups = assign_groups(params_like, rules)
    lf = loss or (lambda p, b: model_loss_fn(p, cfg, b))

    pol_leaves, pol_def = jax.tree_util.tree_flatten(
        policies, is_leaf=lambda x: hasattr(x, "mode"))
    spec_leaves = pol_def.flatten_up_to(pspecs)
    ef_spec_leaves = [
        P(dp, *tuple(sp or P())) if pol.error_feedback else P()
        for pol, sp in zip(pol_leaves, spec_leaves)]
    ef_specs = jax.tree_util.tree_unflatten(pol_def, ef_spec_leaves)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(dp), ef_specs),
        out_specs=(P(), P(), ef_specs),
        axis_names=frozenset(dp), check_vma=False)
    def _grad_agg(params, batch, ef):
        if grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                lacc, gacc = carry
                l, g = jax.value_and_grad(lf)(params, mb)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (lacc + l, gacc), None

            (lval, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), micro)
            lval = lval / grad_accum
            grads = jax.tree.map(lambda x: x / grad_accum, grads)
        else:
            lval, grads = jax.value_and_grad(lf)(params, batch)
        agg, new_ef = aggregate_gradients(grads, policies, dp, w,
                                          ef_states=ef)
        lval = jax.lax.pmean(lval, dp)
        return lval, agg, new_ef

    def step_fn(state: TrainState, batch):
        lval, agg, new_ef = _grad_agg(state.params, batch, state.ef)
        metrics = {"loss": lval}
        if with_diagnostics:
            cos = group_cosines_from_mean(agg, groups)
            for g, d in sorted(cos.items()):
                metrics[f"cos/{g}/gbinary"] = d["gbinary"]
                metrics[f"cos/{g}/gternary"] = d["gternary"]
        gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                          for x in jax.tree.leaves(agg)))
        metrics["agg_norm"] = gn
        new_params, new_opt = optimizer.apply(state.params, agg, state.opt)
        return (TrainState(params=new_params, opt=new_opt, ef=new_ef,
                           step=state.step + 1), metrics)

    # shardings for explicit jit I/O (also consumed by the dry-run)
    param_sh = _named(mesh, pspecs)
    opt_specs = optimizer_state_pspecs(pspecs, params_like, dp_axes=dp,
                                       dp_size=w, zero1=zero1)
    mu_sh = _named(mesh, opt_specs)
    state_shardings = TrainState(
        params=param_sh,
        opt=_opt_shardings(optimizer, mu_sh, mesh),
        ef=_named(mesh, ef_specs),
        step=NamedSharding(mesh, P()))
    batch_sharding = NamedSharding(mesh, P(dp))

    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, batch_sharding),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else ())
    aux = {"policies": policies, "groups": groups, "num_workers": w,
           "ef_specs": ef_specs, "pspecs": pspecs}
    return jitted, state_shardings, batch_sharding, aux


def _is_abstract(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and isinstance(leaves[0], jax.ShapeDtypeStruct)


def _opt_shardings(optimizer: Optimizer, mu_sh, mesh):
    """OptState(step, mu, nu) sharding tree matching optimizer kind."""
    from ..optim.optimizers import OptState
    scalar = NamedSharding(mesh, P())
    has_nu = type(optimizer).__name__ == "AdamW"
    return OptState(step=scalar, mu=mu_sh, nu=mu_sh if has_nu else None)


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    dp_axes: tuple = ("data",)
    warmup_steps: int = 20            # FP32 calibration window
    checkpoint_interval: int = 100
    checkpoint_keep: int = 3
    log_interval: int = 10
    max_restarts: int = 10
    zero1: bool = True


class Trainer:
    """Host control loop with admission control and fault tolerance."""

    def __init__(self, cfg: ModelConfig, mesh, optimizer: Optimizer,
                 data: Iterator[dict], *,
                 tcfg: TrainerConfig = TrainerConfig(),
                 control: ControlPlane | None = None,
                 plan: AdmissionPlan | None = None,
                 rules: GroupRules | None = None,
                 ckpt_dir: str | None = None,
                 failure_injector: FailureInjector | None = None,
                 loss: Callable | None = None,
                 seed: int = 0):
        self.cfg, self.mesh, self.optimizer = cfg, mesh, optimizer
        self.tcfg = tcfg
        self.rules = rules or GroupRules()
        self.control = control
        self.static_plan = plan
        self.data = data
        self.loss = loss
        self.seed = seed
        self.failure_injector = failure_injector
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(ckpt_dir,
                                       interval=tcfg.checkpoint_interval,
                                       keep=tcfg.checkpoint_keep)
                     if ckpt_dir else None)
        self._compiled: dict[str, Any] = {}
        self.state: TrainState | None = None
        self.history: list[dict] = []
        self.restarts = 0
        self.traffic_log: list[float] = []
        self._sizes = None

    # -- state ----------------------------------------------------------
    def init_state(self) -> TrainState:
        key = jax.random.PRNGKey(self.seed)
        with jax.set_mesh(self.mesh):
            params = init_params(key, self.cfg)
        pspecs = param_pspecs(self.cfg)
        params = jax.device_put(params, _named(self.mesh, pspecs))
        opt = self.optimizer.init(params)
        plan = self._current_plan()
        policies = resolve_policies(params, plan, pspecs=pspecs,
                                    rules=self.rules)
        ef = init_ef_states(params, policies)
        # EF leaves need the leading-DP dim
        w = dp_num_workers(self.mesh, self.tcfg.dp_axes)
        ef = jax.tree.map(
            lambda e: (jnp.broadcast_to(e, (w,) + e.shape[1:])
                       if e.ndim > 0 else e), ef)
        self.state = TrainState(params=params, opt=opt, ef=ef,
                                step=jnp.zeros((), jnp.int32))
        self._sizes = group_sizes(params, self.rules)
        return self.state

    def _current_plan(self) -> AdmissionPlan:
        if self.control is not None:
            return self.control.plan
        return self.static_plan or AdmissionPlan.fp32_all()

    def _get_step(self, plan: AdmissionPlan, diagnostics: bool):
        key = (plan.signature(), diagnostics)
        if key not in self._compiled:
            jitted, st_sh, b_sh, aux = build_train_step(
                self.cfg, self.mesh, self.optimizer, plan,
                self.state.params, dp_axes=self.tcfg.dp_axes,
                rules=self.rules, with_diagnostics=diagnostics,
                loss=self.loss, zero1=self.tcfg.zero1)
            self._compiled[key] = (jitted, b_sh)
        return self._compiled[key]

    # -- loop -----------------------------------------------------------
    def run(self, num_steps: int) -> list[dict]:
        if self.state is None:
            if self.ckpt is not None:
                restored = None
                try:
                    self.init_state()
                    restored = self.ckpt.restore(self.state)
                except FileNotFoundError:
                    restored = None
                if restored is not None:
                    step, tree, _ = restored
                    self.state = tree
                    log.info("restored checkpoint at step %d", step)
            else:
                self.init_state()

        it = iter(self.data)
        done = int(self.state.step)
        while done < num_steps:
            try:
                done = self._run_until(num_steps, it)
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                log.warning("%s -> restart %d (restore + replay)",
                            e, self.restarts)
                self._recover()
                done = int(self.state.step)
        if self.ckpt is not None:
            self.ckpt.maybe_save(int(self.state.step), self.state, force=True)
            self.ckpt.wait()
        return self.history

    def _recover(self):
        """Node-failure recovery: restore last durable checkpoint."""
        if self.ckpt is None:
            raise RuntimeError("failure without checkpointing enabled")
        restored = self.ckpt.restore(self.state)
        if restored is None:
            self.init_state()
        else:
            _, self.state, _ = restored

    def _run_until(self, num_steps: int, it: Iterator[dict]) -> int:
        dp = self.tcfg.dp_axes
        while int(self.state.step) < num_steps:
            step = int(self.state.step)
            if self.failure_injector is not None:
                self.failure_injector.check(step)

            plan = self._current_plan()
            calibrating = (self.control is not None
                           and step < self.tcfg.warmup_steps)
            jitted, b_sh = self._get_step(plan, calibrating)
            if hasattr(self.data, "batch_at"):   # deterministic replay
                batch = self.data.batch_at(step)
            else:
                batch = next(it)
            batch = jax.tree.map(
                lambda a: jax.device_put(jnp.asarray(a), b_sh), batch)

            with StepTimer() as t:
                self.state, metrics = jitted(self.state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
            self.watchdog.observe(step, t.duration)

            metrics["step"] = step
            metrics["plan"] = plan.signature()
            metrics["traffic_ratio"] = plan_traffic_ratio(self._sizes, plan)
            self.traffic_log.append(metrics["traffic_ratio"])
            self.history.append(metrics)

            if self.control is not None:
                cos = None
                if calibrating and step == self.tcfg.warmup_steps - 1:
                    cos = {g: {"gbinary": metrics.get(f"cos/{g}/gbinary", 0.0),
                               "gternary": metrics.get(f"cos/{g}/gternary", 0.0)}
                           for g in self._sizes}
                self.control.step(metrics["loss"], cosines=cos)

            if self.ckpt is not None:
                self.ckpt.maybe_save(step + 1, self.state,
                                     extra={"plan": plan.signature()})
            if step % self.tcfg.log_interval == 0:
                log.info("step %d loss %.4f traffic %.4f plan=%s", step,
                         metrics["loss"], metrics["traffic_ratio"],
                         plan.signature()[:48])
        return int(self.state.step)
