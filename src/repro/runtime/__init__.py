"""Training/serving runtime: step builders, Trainer, fault tolerance."""
from .fault import (FailureInjector, SimulatedFailure, StragglerWatchdog,
                    StepTimer)
from .train import (Trainer, TrainerConfig, TrainState, build_train_step,
                    dp_num_workers)
from .serve import (build_cached_prefill, build_prefill, build_serve_step,
                    serve_shardings)

__all__ = [
    "FailureInjector", "SimulatedFailure", "StragglerWatchdog", "StepTimer",
    "Trainer", "TrainerConfig", "TrainState", "build_train_step",
    "dp_num_workers", "build_cached_prefill", "build_prefill",
    "build_serve_step", "serve_shardings",
]
