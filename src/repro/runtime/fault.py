"""Fault-tolerance utilities: failure injection, straggler watchdog.

On a real multi-pod job, node failures surface as collective timeouts /
process exits and restarts go through the checkpoint path.  The trainer
here exercises exactly that path: :class:`FailureInjector` raises at
configured steps, and the trainer's recovery logic restores the latest
atomic checkpoint and replays the deterministic data stream — the same
control flow a production launcher (GKE/Borg restart policy) would drive.

Straggler mitigation in a synchronous SPMD world is a *scheduling* concern:
the watchdog detects persistent slow steps (EWMA outliers) and reports
them; the trainer's hook can then rebalance (skip-batch, reshard, or mark
the host for replacement at the next checkpoint boundary).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence


class SimulatedFailure(RuntimeError):
    """Stands in for a node crash / collective abort."""


@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedFailure at the given steps (each fires once)."""
    at_steps: Sequence[int] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    ewma_s: float


class StragglerWatchdog:
    """EWMA-based step-time outlier detector with a mitigation hook."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.1,
                 warmup: int = 3,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.events: list[StragglerEvent] = []
        self._seen = 0

    def observe(self, step: int, duration_s: float) -> bool:
        self._seen += 1
        if self.ewma is None:
            self.ewma = duration_s
            return False
        is_straggler = (self._seen > self.warmup
                        and duration_s > self.threshold * self.ewma)
        if is_straggler:
            ev = StragglerEvent(step, duration_s, self.ewma)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration_s
        return is_straggler


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self.t0
