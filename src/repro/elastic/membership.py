"""Membership ledger: epoch-numbered worker views over a dynamic fleet.

The unit of truth for *who is training* is the :class:`WorkerView` — an
immutable, epoch-numbered snapshot of the live worker-id set.  Every
membership change (graceful ``join``/``leave``, involuntary ``crash``)
bumps the epoch, and everything keyed on the live fleet — bucket
layouts, jitted steps (``Fabric.step_for``), EF state shapes — re-keys
on ``(num_workers, epoch)`` so stale artifacts can never be served
after a re-plan (DESIGN.md §10).

A :class:`Membership` ledger owns the current view plus an optional
*deterministic event schedule*: a step-stamped list of events applied at
step boundaries, so a scripted crash→rejoin run is exactly replayable
(and replayable offline through ``repro.elastic.replay``).  Fault models
(``repro.elastic.faults``) inject further events at run time through the
same :meth:`Membership.apply` path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

__all__ = ["MembershipEvent", "WorkerView", "Membership", "view_trace"]

EVENT_KINDS = ("join", "leave", "crash")


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One membership change: ``worker`` does ``kind`` at ``step``.

    ``join``/``leave`` are graceful (step-boundary re-plan, no rollback);
    ``crash`` is involuntary (the ElasticTrainer rolls back to the last
    durable checkpoint and replays under the shrunken view).
    """
    step: int
    kind: str
    worker: int

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown membership event kind {self.kind!r}; "
                             f"expected one of {EVENT_KINDS}")

    def to_jsonable(self) -> dict:
        return {"step": int(self.step), "kind": self.kind,
                "worker": int(self.worker)}


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """Immutable epoch-numbered snapshot of the live worker-id set."""
    epoch: int
    workers: tuple[int, ...]

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    def index_of(self, worker: int) -> int:
        """Dense slot of ``worker`` in this view (EF/batch leading axis)."""
        return self.workers.index(worker)

    def to_jsonable(self) -> dict:
        return {"epoch": int(self.epoch),
                "workers": [int(w) for w in self.workers]}


class Membership:
    """Epoch-numbered membership ledger with a deterministic schedule.

    ``Membership(4)`` starts with workers ``(0, 1, 2, 3)`` at epoch 0.
    Scheduled events (``schedule=``) fire when the driving loop calls
    :meth:`step_events`; ad-hoc events (fault models, external signals)
    go straight through :meth:`apply`.  The full ``(event, view)`` log
    is kept for replay and reporting.
    """

    def __init__(self, initial: int | Iterable[int], *,
                 schedule: Sequence[MembershipEvent] = ()):
        workers = (tuple(range(initial)) if isinstance(initial, int)
                   else tuple(sorted(int(w) for w in initial)))
        if not workers:
            raise ValueError("membership needs at least one initial worker")
        if len(set(workers)) != len(workers):
            raise ValueError(f"duplicate worker ids in {workers}")
        self.view = WorkerView(epoch=0, workers=workers)
        self.schedule = tuple(sorted(schedule, key=lambda e: e.step))
        self._pending = list(self.schedule)
        self.log: list[tuple[MembershipEvent, WorkerView]] = []

    # -- event application ----------------------------------------------

    def apply(self, event: MembershipEvent) -> WorkerView:
        """Apply one event; returns the new (epoch-bumped) view.

        Joining a live worker or removing an absent one is a schedule
        bug, not a state to paper over — both raise.
        """
        live = set(self.view.workers)
        if event.kind == "join":
            if event.worker in live:
                raise ValueError(f"worker {event.worker} is already live "
                                 f"(epoch {self.view.epoch})")
            live.add(event.worker)
        else:                                       # leave / crash
            if event.worker not in live:
                raise ValueError(f"worker {event.worker} is not live "
                                 f"(epoch {self.view.epoch})")
            live.remove(event.worker)
        if not live:
            raise ValueError(f"event {event} would empty the fleet")
        self.view = WorkerView(epoch=self.view.epoch + 1,
                               workers=tuple(sorted(live)))
        self.log.append((event, self.view))
        return self.view

    def step_events(self, step: int) -> tuple[MembershipEvent, ...]:
        """Pop (without applying) all *scheduled* events due at ``step``.

        Events scheduled before ``step`` that were never polled fire too
        (a recovered run resumes polling mid-schedule); each scheduled
        event fires exactly once.
        """
        due = [e for e in self._pending if e.step <= step]
        self._pending = [e for e in self._pending if e.step > step]
        return tuple(due)

    def to_jsonable(self) -> dict:
        return {"view": self.view.to_jsonable(),
                "schedule": [e.to_jsonable() for e in self.schedule],
                "log": [{"event": e.to_jsonable(), "view": v.to_jsonable()}
                        for e, v in self.log]}


def view_trace(initial: int | Iterable[int],
               events: Sequence[MembershipEvent],
               num_steps: int) -> list[tuple[int, int, WorkerView]]:
    """Pure offline expansion of a schedule into ``(start, stop, view)``.

    Walks steps ``0..num_steps`` applying every event at its stamped
    step (crashes count as leaves — the replayer does not model the
    rollback window, only the view each step runs under) and returns the
    maximal constant-view phases.  Used by ``repro.elastic.replay``.
    """
    ledger = Membership(initial, schedule=events)
    phases: list[tuple[int, int, WorkerView]] = []
    current, start = ledger.view, 0
    for step in range(num_steps):
        due = ledger.step_events(step)
        for ev in due:
            ledger.apply(ev)
        if due and ledger.view.epoch != current.epoch:
            if step > start:
                phases.append((start, step, current))
            current, start = ledger.view, step
    phases.append((start, num_steps, current))
    return phases
