"""repro.elastic — elastic membership, fault injection, async strategies.

The paper's premise is a fabric-resident aggregation path that training
sessions attach to and detach from; this package makes the fleet
dynamic while everything underneath stays the registry-driven stack:

  * :mod:`membership` — epoch-numbered :class:`WorkerView` ledger with
    deterministic join/leave/crash schedules;
  * :mod:`faults`    — ``@register_fault`` registry (built-ins
    ``crash``, ``straggler``, ``link_degrade``) driving both live runs
    and offline ``repro.sim`` replays from one scenario description;
  * :mod:`detector`  — per-worker step-time spike detection feeding
    ``Telemetry``, plus the ``straggler_aware`` admission controller;
  * :mod:`trainer`   — :class:`ElasticTrainer`: re-plans buckets and
    rebuilds the jitted step on every membership epoch, rolls back to
    the last durable checkpoint on a crash (controller state included);
  * :mod:`strategies` — DeMoSim-style local-SGD expressed purely
    through the public codec/schedule/controller seams (``local``
    codec, ``local_accum`` transport, ``local_sgd`` controller);
  * :mod:`replay`    — the same schedule priced offline, per-phase
    exposed-time reporting through the DES.

Importing the package registers the built-in fault models, the
``straggler_aware``/``local_sgd`` controllers, the ``local`` codec, and
the ``local_accum`` schedule backend.
"""
from .detector import StepTimeStats, StragglerAwareController, StragglerDetector
from .faults import (Crash, FaultModel, LinkDegrade, Straggler,
                     available_faults, combined_bandwidth_scale,
                     combined_step_time_scale, get_fault, make_fault,
                     register_fault, resolve_faults, unregister_fault)
from .membership import Membership, MembershipEvent, WorkerView, view_trace
from .replay import (BANDWIDTH_KWARGS, ReplayPhase, ReplayReport,
                     replay_schedule)
from .strategies import (LocalAccumBackend, LocalAccumCodec,
                         LocalSgdController, local_plan)
from .trainer import ElasticConfig, ElasticFailure, ElasticTrainer

__all__ = [
    "BANDWIDTH_KWARGS", "Crash", "ElasticConfig", "ElasticFailure",
    "ElasticTrainer", "FaultModel", "LinkDegrade", "LocalAccumBackend",
    "LocalAccumCodec", "LocalSgdController", "Membership",
    "MembershipEvent", "ReplayPhase", "ReplayReport", "StepTimeStats",
    "Straggler", "StragglerAwareController", "StragglerDetector",
    "WorkerView", "available_faults", "combined_bandwidth_scale",
    "combined_step_time_scale", "get_fault", "local_plan", "make_fault",
    "register_fault", "replay_schedule", "resolve_faults",
    "unregister_fault", "view_trace",
]
