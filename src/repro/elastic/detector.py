"""Straggler/spike detection from per-worker step-time statistics.

The ElasticTrainer times every step per worker (wall time on the live
path, fault-scaled nominal time on deterministic runs) and feeds the
mapping to a :class:`StragglerDetector`, which flags workers whose step
time spikes relative to the fleet median.  The resulting
:class:`StepTimeStats` ride into :class:`repro.fabric.control.Telemetry`
(``worker_step_times`` / ``stragglers``), where any controller can react
— the built-in ``straggler_aware`` controller demotes the backbone to a
low-bit plan under sustained straggler pressure (shrinking the exposed
communication the slow worker serializes behind) and recovers to FP32
once membership and step times have been stable for a window.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from ..core import AdmissionPlan
from ..core.admission import ControlEvent
from ..fabric.control import (Telemetry, plan_from_jsonable, plan_presets,
                              plan_to_jsonable, register_controller)

__all__ = ["StepTimeStats", "StragglerDetector", "StragglerAwareController"]


@dataclasses.dataclass(frozen=True)
class StepTimeStats:
    """One step of fleet timing: who is slow, and by how much."""
    step: int
    times: Mapping[int, float]          # worker id -> step time (s)
    median_s: float
    max_s: float
    stragglers: tuple[int, ...]         # flagged worker ids, sorted

    @property
    def slowdown(self) -> float:
        """Fleet exposure ratio: slowest worker over the median."""
        return self.max_s / self.median_s if self.median_s > 0 else 1.0


class StragglerDetector:
    """Median-relative spike detector over per-worker EWMA step times.

    A worker is flagged when its smoothed step time exceeds
    ``threshold`` times the fleet median of smoothed times.  EWMA
    (``alpha``) absorbs one-off jitter (GC pauses, first-step compile)
    without missing a sustained slowdown; ``warmup`` steps are observed
    but never flagged, since compile-heavy early steps are all spikes.
    """

    def __init__(self, threshold: float = 2.0, alpha: float = 0.3,
                 warmup: int = 1):
        if threshold <= 1.0:
            raise ValueError(f"threshold {threshold} must be > 1")
        self.threshold = float(threshold)
        self.alpha = float(alpha)
        self.warmup = int(warmup)
        self._ewma: dict[int, float] = {}
        self._seen = 0

    def observe(self, step: int,
                times: Mapping[int, float]) -> StepTimeStats:
        for w, t in times.items():
            prev = self._ewma.get(w)
            self._ewma[w] = (float(t) if prev is None
                             else self.alpha * float(t)
                             + (1 - self.alpha) * prev)
        # drop departed workers so a shrunken fleet's median is honest
        self._ewma = {w: v for w, v in self._ewma.items() if w in times}
        self._seen += 1
        smoothed = sorted(self._ewma.values())
        n = len(smoothed)
        median = (smoothed[n // 2] if n % 2 == 1
                  else 0.5 * (smoothed[n // 2 - 1] + smoothed[n // 2]))
        flagged: tuple[int, ...] = ()
        if self._seen > self.warmup and median > 0:
            flagged = tuple(sorted(
                w for w, v in self._ewma.items()
                if v > self.threshold * median))
        return StepTimeStats(step=int(step), times=dict(times),
                             median_s=median,
                             max_s=max(times.values(), default=0.0),
                             stragglers=flagged)

    def state_dict(self) -> dict:
        return {"ewma": {str(w): v for w, v in self._ewma.items()},
                "seen": self._seen}

    def load_state_dict(self, state: dict) -> None:
        self._ewma = {int(w): float(v) for w, v in state["ewma"].items()}
        self._seen = int(state["seen"])


@register_controller("straggler_aware")
class StragglerAwareController:
    """Demote to low-bit under straggler pressure; recover when stable.

    Reads only the elastic Telemetry fields (``stragglers``,
    ``membership_epoch``) — never raw timings — and latches one of two
    plans: ``fp32_plan`` nominally, ``lowbit_plan`` after
    ``demote_after`` consecutive straggler-flagged steps.  Recovery to
    FP32 requires ``recover_after`` consecutive *stable* steps, where a
    step is stable only when no straggler is flagged **and** the
    membership epoch did not change — a churning fleet keeps the cheap
    plan until it settles.
    """

    name = "straggler_aware"
    wants_diagnostics = False

    def __init__(self, lowbit_plan: AdmissionPlan | str = "gbin_vote",
                 fp32_plan: AdmissionPlan | str = "fp32",
                 demote_after: int = 2, recover_after: int = 8):
        presets = plan_presets(error_feedback=True)
        if isinstance(lowbit_plan, str):
            lowbit_plan = presets[lowbit_plan]
        if isinstance(fp32_plan, str):
            fp32_plan = presets[fp32_plan]
        self.lowbit_plan, self.fp32_plan = lowbit_plan, fp32_plan
        self.demote_after = int(demote_after)
        self.recover_after = int(recover_after)
        self.plan = fp32_plan
        self.phase = "fp32"
        self.events: list[ControlEvent] = []
        self._pressure = 0
        self._stable = 0
        self._last_epoch: int | None = None

    def observe(self, telemetry: Telemetry) -> AdmissionPlan:
        epoch = telemetry.membership_epoch
        epoch_changed = (self._last_epoch is not None
                         and epoch is not None
                         and epoch != self._last_epoch)
        self._last_epoch = epoch if epoch is not None else self._last_epoch
        if telemetry.stragglers:
            self._pressure += 1
            self._stable = 0
        else:
            self._pressure = 0
            self._stable = 0 if epoch_changed else self._stable + 1
        if self.phase == "fp32" and self._pressure >= self.demote_after:
            self.phase, self.plan = "lowbit", self.lowbit_plan
            self._stable = 0
            self.events.append(ControlEvent(telemetry.step, "demoted",
                                            self.plan.signature()))
        elif self.phase == "lowbit" and self._stable >= self.recover_after:
            self.phase, self.plan = "fp32", self.fp32_plan
            self._pressure = 0
            self.events.append(ControlEvent(telemetry.step, "recovered",
                                            self.plan.signature()))
        return self.plan

    def state_dict(self) -> dict:
        return {"phase": self.phase,
                "plan": plan_to_jsonable(self.plan),
                "pressure": self._pressure, "stable": self._stable,
                "last_epoch": self._last_epoch}

    def load_state_dict(self, state: dict) -> None:
        self.phase = state["phase"]
        self.plan = plan_from_jsonable(state["plan"])
        self._pressure = int(state["pressure"])
        self._stable = int(state["stable"])
        self._last_epoch = state["last_epoch"]
