"""Offline replay of an elastic schedule through the ``repro.sim`` DES.

The same :class:`~repro.elastic.membership.Membership` schedule and
fault models that drive a live ElasticTrainer run replay here without
touching a device: the step range splits into maximal **phases** of
constant ``(worker view, straggler inflation, bandwidth scale)``, each
phase simulates the plan's bucket layout once under its effective fleet
size / compute time / link rate, and the report aggregates per-phase
exposed communication time — the paper's reporting basis — across the
whole scenario.  This is how a crash→rejoin or link-degrade scenario is
priced before (or instead of) running it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from ..core import AdmissionPlan
from ..fabric import Fabric
from ..sim import get_topology, simulate_layout
from .faults import (FaultModel, combined_bandwidth_scale,
                     combined_step_time_scale, resolve_faults)
from .membership import Membership, MembershipEvent, view_trace

__all__ = ["ReplayPhase", "ReplayReport", "replay_schedule",
           "BANDWIDTH_KWARGS"]

#: which constructor kwarg scales each built-in topology's bottleneck
#: link; custom topologies pass ``bandwidth_kwarg=`` explicitly.
BANDWIDTH_KWARGS = {
    "cxl_direct": "link_bytes_per_s",
    "cxl_switched": "uplink_bytes_per_s",
    "multihop": "link_bytes_per_s",
}


@dataclasses.dataclass(frozen=True)
class ReplayPhase:
    """One maximal run of steps with a constant elastic regime."""
    start: int
    stop: int
    epoch: int
    num_workers: int
    straggler_scale: float
    bandwidth_scale: float
    step_time_s: float
    exposed_s: float
    exposed_pct: float
    hidden: bool

    @property
    def steps(self) -> int:
        return self.stop - self.start

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    """Per-phase exposed-time accounting for one elastic scenario."""
    topology: str
    num_steps: int
    phases: tuple[ReplayPhase, ...]

    @property
    def total_time_s(self) -> float:
        return sum(p.steps * p.step_time_s for p in self.phases)

    @property
    def total_exposed_s(self) -> float:
        return sum(p.steps * p.exposed_s for p in self.phases)

    @property
    def exposed_pct(self) -> float:
        t = self.total_time_s
        return 100.0 * self.total_exposed_s / t if t > 0 else 0.0

    def summary(self) -> dict:
        return {"topology": self.topology, "num_steps": self.num_steps,
                "num_phases": len(self.phases),
                "total_time_s": self.total_time_s,
                "total_exposed_s": self.total_exposed_s,
                "exposed_pct": self.exposed_pct}

    def to_jsonable(self) -> dict:
        return {**self.summary(),
                "phases": [p.to_jsonable() for p in self.phases]}


def _scenario_events(membership: Membership | int,
                     faults: Sequence[FaultModel]) -> tuple:
    """Static event list: the ledger's schedule plus fault-caused ones."""
    events: list[MembershipEvent] = []
    if isinstance(membership, Membership):
        events.extend(membership.schedule)
        initial = membership.view.workers
    else:
        initial = tuple(range(membership))
    for f in faults:
        events.extend(f.scheduled_events())
    return initial, tuple(sorted(events, key=lambda e: e.step))


def replay_schedule(params_like: Any, plan: AdmissionPlan,
                    membership: Membership | int,
                    num_steps: int, *,
                    faults: Sequence = (),
                    topology: str = "cxl_direct",
                    compute_time_s: float = 1e-3,
                    overlap_fraction: float = 1.0,
                    bandwidth_kwarg: str | None = None,
                    rules=None,
                    **topology_kwargs) -> ReplayReport:
    """Replay an elastic scenario offline; returns per-phase exposure.

    ``membership`` is a fresh ledger (its deterministic schedule is
    read, not consumed) or an initial worker count; ``faults`` accepts
    the same specs as the ElasticTrainer.  Per phase, the fleet's
    compute time inflates by the worst live straggler factor (lock-step
    steps serialize behind the slowest worker) and the topology's
    bottleneck-link rate scales by the tightest ``link_degrade`` cut.
    """
    faults = resolve_faults(faults)
    initial, events = _scenario_events(membership, faults)
    kwarg = bandwidth_kwarg or BANDWIDTH_KWARGS.get(topology)
    base_bw = (getattr(get_topology(topology, **topology_kwargs), kwarg)
               if kwarg is not None else None)

    fabric = Fabric(num_workers=len(initial), rules=rules)
    layout = fabric.layout_for(params_like, plan)

    # per-step regime, then coalesce into maximal constant phases
    views = {}
    for start, stop, view in view_trace(initial, events, num_steps):
        for s in range(start, stop):
            views[s] = view
    regimes = []
    for s in range(num_steps):
        view = views[s]
        straggler = max(
            [combined_step_time_scale(faults, s, w) for w in view.workers],
            default=1.0)
        bw = combined_bandwidth_scale(faults, s)
        regimes.append((view, straggler, bw))

    phases: list[ReplayPhase] = []
    start = 0
    for s in range(1, num_steps + 1):
        boundary = (s == num_steps or
                    (regimes[s][0].epoch, regimes[s][1], regimes[s][2])
                    != (regimes[start][0].epoch, regimes[start][1],
                        regimes[start][2]))
        if not boundary:
            continue
        view, straggler, bw = regimes[start]
        kwargs = dict(topology_kwargs)
        if bw != 1.0:
            if kwarg is None:
                raise ValueError(
                    f"link_degrade on topology {topology!r} needs "
                    f"bandwidth_kwarg= (no entry in BANDWIDTH_KWARGS)")
            kwargs[kwarg] = base_bw * bw
        rep = simulate_layout(layout, view.num_workers, topology=topology,
                              compute_time_s=compute_time_s * straggler,
                              overlap_fraction=overlap_fraction, **kwargs)
        phases.append(ReplayPhase(
            start=start, stop=s, epoch=view.epoch,
            num_workers=view.num_workers, straggler_scale=straggler,
            bandwidth_scale=bw, step_time_s=rep.step_time_s,
            exposed_s=rep.exposed_s, exposed_pct=rep.exposed_pct,
            hidden=rep.hidden))
        start = s
    return ReplayReport(topology=str(topology), num_steps=num_steps,
                        phases=tuple(phases))
