"""Local-SGD / async gradient strategies through the public seams.

DeMoSim's ``TrainNode`` carries a pluggable ``gradient_strategy``; here
the same idea decomposes onto the three seams this repo already has —
no edits inside ``fabric/backends.py`` dispatch:

  * a **codec** (``local``) whose wire payload is zero bits — nothing
    crosses the fabric on a local step, and ``plan_traffic_ratio``
    prices it honestly at 0;
  * a **schedule backend** (``local_accum``) that skips the collective
    entirely and banks the step's gradient into the error-feedback
    residual (``e' = e + g``), returning a zero aggregate — the
    optimizer still runs (LR schedules and momentum decay advance), but
    parameters only move on sync steps;
  * a **controller** (``local_sgd``) alternating ``H - 1`` local
    plan-latches with one sync latch whose codec threads EF, so the
    banked sum ``Σg`` is injected as ``g_eff = g + e`` at the sync
    step and voted fleet-wide (DeMoSim's sign-of-accumulated-gradient
    exchange).

Because each piece is independently registered, every existing surface
composes for free: the plan signature keys the jit cache, the sim
prices the sync step's wire bytes and the local step at zero, and the
ElasticTrainer re-plans the whole strategy across membership changes.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core import AdmissionPlan, GroupPolicy
from ..core.admission import ControlEvent
from ..fabric.codecs import CodecLane, GradientCodec, register_codec
from ..fabric.control import (Telemetry, plan_from_jsonable, plan_presets,
                              plan_to_jsonable, register_controller)
from ..fabric.registry import AggregationContext, register_schedule

__all__ = ["LocalAccumCodec", "LocalAccumBackend", "LocalSgdController",
           "local_plan"]


@register_codec("local")
class LocalAccumCodec(GradientCodec):
    """Zero-wire codec for local (no-communication) steps.

    ``bits_per_element = 0`` makes the traffic model price local steps
    at zero; ``threads_ef`` lets the bucket layer hand the residual to
    the ``local_accum`` transport, which is where the accumulation
    actually lives.  ``reduction = "local"`` canonicalizes any built-in
    collective a policy might nominally name onto ``local_accum``
    (``core.modes.wire_schedule``) — a zero-bit payload riding a real
    psum would ship FP32 bytes the traffic model prices at zero.
    """

    name = "local"
    bits_per_element = 0.0
    reduction = "local"
    threads_ef = True
    lane = CodecLane("fp32_bypass", fused=True)  # zero-wire: nothing to stage
    default_schedule = "local_accum"


@register_schedule("local_accum")
class LocalAccumBackend:
    """No-collective transport: bank the gradient, emit a zero update.

    Deliberately **not fusable**: the fused bucket path hardcodes the
    EF-signSGD residual update after scatter, while this transport *is*
    its own EF rule (pure accumulation).  Per-leaf dispatch keeps full
    control of the residual.  Requires ``error_feedback=True`` on the
    policy — without a residual there is nowhere to bank the step and
    the gradient would be silently dropped.
    """

    name = "local_accum"
    fusable = False
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        if ef is None:
            raise ValueError(
                "local_accum requires error_feedback=True on the policy: "
                "the EF residual is the local accumulator")
        return jnp.zeros_like(g), (ef + g).astype(ef.dtype)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        return 0.0


def local_plan() -> AdmissionPlan:
    """Every group on the zero-wire local-accumulation path."""
    return AdmissionPlan(
        default=GroupPolicy("local", "local_accum", error_feedback=True))


@register_controller("local_sgd")
class LocalSgdController:
    """Sync-every-H strategy: H-1 zero-wire steps, then one EF sync.

    ``sync_plan`` must thread EF on **every** group — the local plan
    banks all groups' gradients into the residual, and only groups whose
    sync policy injects EF ever release them (a backbone-only sync plan
    would silently never train the head).  The default votes on
    ``sign(g + Σg_local)`` fleet-wide, the DeMoSim-style low-bit
    exchange of the accumulated direction; the residual then carries
    the quantization error forward per standard EF-signSGD.
    ``observe`` latches the plan for the *next* step, so with
    ``sync_every=H`` steps ``H-1, 2H-1, ...`` are sync steps.
    """

    name = "local_sgd"
    wants_diagnostics = False

    def __init__(self, sync_every: int = 8,
                 sync_plan: AdmissionPlan | str | None = None,
                 local: AdmissionPlan | None = None):
        if sync_every < 2:
            raise ValueError(f"sync_every {sync_every} must be >= 2")
        if sync_plan is None:
            sync_plan = AdmissionPlan.lowbit_all(
                "gbinary", schedule="vote_psum", error_feedback=True)
        elif isinstance(sync_plan, str):
            sync_plan = plan_presets(error_feedback=True)[sync_plan]
        self.sync_every = int(sync_every)
        self.sync_plan = sync_plan
        self.local_plan = local if local is not None else local_plan()
        self.observed = 0
        self.plan = self.local_plan
        self.events: list[ControlEvent] = []

    def observe(self, telemetry: Telemetry) -> AdmissionPlan:
        self.observed += 1
        nxt = ((self.observed + 1) % self.sync_every == 0)
        plan = self.sync_plan if nxt else self.local_plan
        if plan is not self.plan:
            self.events.append(ControlEvent(
                telemetry.step, "sync" if nxt else "local",
                plan.signature()))
        self.plan = plan
        return self.plan

    def state_dict(self) -> dict:
        return {"observed": self.observed,
                "plan": plan_to_jsonable(self.plan)}

    def load_state_dict(self, state: dict) -> None:
        self.observed = int(state["observed"])
        self.plan = plan_from_jsonable(state["plan"])
