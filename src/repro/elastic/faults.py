"""Pluggable fault models: what goes wrong, expressed once for two paths.

A fault model is a small object describing one failure scenario through
three hooks, each a pure function of the step number:

  * :meth:`FaultModel.membership_events` — membership changes the fault
    causes (a ``crash`` removes a worker; its optional rejoin adds it
    back), consumed live by the ElasticTrainer and statically by the
    ``repro.sim`` replayer;
  * :meth:`FaultModel.step_time_scale` — per-worker step-time inflation
    (``straggler``), feeding the detector and the replayer's per-phase
    compute time;
  * :meth:`FaultModel.bandwidth_scale` — fleet-wide link-bandwidth cuts
    (``link_degrade``), scaling the replayed topology's link rate.

Models are registered on the shared :class:`repro.core.registry.Registry`
machinery under string names (``@register_fault``), same contract as
schedule backends / codecs / controllers / topologies / serve policies:
``make_fault("crash", worker=3, step=8)`` anywhere a spec is stringly
typed, instances anywhere code is in charge.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from ..core.registry import Registry
from .membership import MembershipEvent

__all__ = ["FaultModel", "Crash", "Straggler", "LinkDegrade",
           "register_fault", "unregister_fault", "get_fault", "make_fault",
           "available_faults", "resolve_faults",
           "combined_step_time_scale", "combined_bandwidth_scale"]


class FaultModel:
    """Base fault model: no-op hooks, one-shot membership-event firing.

    Subclasses override :meth:`scheduled_events` (static event list, used
    by the offline replayer) and/or the scale hooks.  The live-path
    :meth:`membership_events` derives from :meth:`scheduled_events` with
    exactly-once firing, so checkpoint-rollback replay through the same
    step numbers cannot re-fire a crash.
    """

    name = "fault"

    def __init__(self):
        self._fired: set[MembershipEvent] = set()

    def scheduled_events(self) -> tuple[MembershipEvent, ...]:
        """All membership events this fault will ever cause (static)."""
        return ()

    def membership_events(self, step: int) -> tuple[MembershipEvent, ...]:
        """Events due at ``step`` that have not fired yet (live path)."""
        due = tuple(e for e in self.scheduled_events()
                    if e.step <= step and e not in self._fired)
        self._fired.update(due)
        return due

    def step_time_scale(self, step: int, worker: int) -> float:
        """Multiplier on ``worker``'s step time at ``step`` (1.0 = none)."""
        return 1.0

    def bandwidth_scale(self, step: int) -> float:
        """Multiplier on link bandwidth at ``step`` (1.0 = none)."""
        return 1.0

    def reset(self) -> None:
        """Forget firing state (fresh run over the same schedule)."""
        self._fired.clear()

    def to_jsonable(self) -> dict:
        return {"name": self.name,
                "events": [e.to_jsonable() for e in self.scheduled_events()]}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _describe(obj: Any) -> str:
    return getattr(obj, "__name__", type(obj).__name__)


_FAULTS = Registry("fault model", key_fn=str, describe=_describe,
                   register_hint="@register_fault({key!r})",
                   format_available=", ".join)


def register_fault(name: str, *aliases: str, override: bool = False):
    """Class/factory decorator: register a fault model under ``name``.

    The registered object is called with ``make_fault``'s kwargs and must
    return a :class:`FaultModel`-shaped instance (the three hooks above).
    """
    return _FAULTS.register(name, *aliases, override=override)


def unregister_fault(name: str) -> None:
    _FAULTS.unregister(name)


def get_fault(name: str):
    """The registered factory (class) for ``name``."""
    return _FAULTS.get(name)


def make_fault(name: str, **kwargs) -> FaultModel:
    """Instantiate a registered fault model: ``make_fault("crash", ...)``."""
    return _FAULTS.get(name)(**kwargs)


def available_faults() -> tuple[str, ...]:
    return tuple(_FAULTS.available())


def resolve_faults(specs: Sequence) -> tuple[FaultModel, ...]:
    """Normalize a mixed fault spec list into instances.

    Accepts instances, ``(name, kwargs)`` pairs, and ``{"name": ...,
    **kwargs}`` dicts — the shapes a JSON scenario file produces.
    """
    out = []
    for spec in specs:
        if isinstance(spec, tuple):
            name, kwargs = spec
            out.append(make_fault(name, **kwargs))
        elif isinstance(spec, dict):
            kwargs = dict(spec)
            out.append(make_fault(kwargs.pop("name"), **kwargs))
        else:
            out.append(spec)
    return tuple(out)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

@register_fault("crash")
class Crash(FaultModel):
    """Worker ``worker`` crashes at ``step``; optionally rejoins later.

    The crash is involuntary — the ElasticTrainer rolls back to the last
    durable checkpoint and replays under the shrunken view.  The rejoin
    (if any) is graceful: a step-boundary re-plan with no rollback.
    """

    name = "crash"

    def __init__(self, worker: int, step: int, rejoin_step: int | None = None):
        super().__init__()
        if rejoin_step is not None and rejoin_step <= step:
            raise ValueError(f"rejoin_step {rejoin_step} must come after "
                             f"the crash step {step}")
        self.worker, self.step, self.rejoin_step = worker, step, rejoin_step

    def scheduled_events(self) -> tuple[MembershipEvent, ...]:
        events = [MembershipEvent(self.step, "crash", self.worker)]
        if self.rejoin_step is not None:
            events.append(MembershipEvent(self.rejoin_step, "join",
                                          self.worker))
        return tuple(events)


@register_fault("straggler")
class Straggler(FaultModel):
    """Worker ``worker`` runs ``factor``x slow on steps [start, stop)."""

    name = "straggler"

    def __init__(self, worker: int, start: int, stop: int,
                 factor: float = 4.0):
        super().__init__()
        if factor < 1.0:
            raise ValueError(f"straggler factor {factor} must be >= 1")
        self.worker, self.start, self.stop = worker, start, stop
        self.factor = float(factor)

    def step_time_scale(self, step: int, worker: int) -> float:
        if worker == self.worker and self.start <= step < self.stop:
            return self.factor
        return 1.0

    def to_jsonable(self) -> dict:
        return {"name": self.name, "worker": self.worker,
                "start": self.start, "stop": self.stop,
                "factor": self.factor}


@register_fault("link_degrade")
class LinkDegrade(FaultModel):
    """Fleet-wide link bandwidth drops to ``factor``x on [start, stop)."""

    name = "link_degrade"

    def __init__(self, start: int, stop: int, factor: float = 0.25):
        super().__init__()
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"link_degrade factor {factor} must be in "
                             f"(0, 1]")
        self.start, self.stop, self.factor = start, stop, float(factor)

    def bandwidth_scale(self, step: int) -> float:
        return self.factor if self.start <= step < self.stop else 1.0

    def to_jsonable(self) -> dict:
        return {"name": self.name, "start": self.start, "stop": self.stop,
                "factor": self.factor}


def combined_step_time_scale(faults: Sequence[FaultModel], step: int,
                             worker: int) -> float:
    """Max over models — concurrent slowdowns do not stack multiplicatively."""
    return max([f.step_time_scale(step, worker) for f in faults],
               default=1.0)


def combined_bandwidth_scale(faults: Sequence[FaultModel],
                             step: int) -> float:
    """Min over models — the tightest cut governs the link."""
    return min([f.bandwidth_scale(step) for f in faults], default=1.0)
