"""ElasticTrainer: the Trainer control loop over a dynamic worker fleet.

Virtual workers via ``jax.vmap(..., axis_name="w")`` over a host-local
``Fabric(dp_axes=("w",))`` session — the same collectives the mesh path
runs under shard_map resolve against the vmapped axis, so per-worker
gradients, EF residuals, and votes behave exactly as on hardware while
the worker count is free to change between steps.

Lifecycle on a membership change (DESIGN.md §10):

  * graceful ``join``/``leave`` — step-boundary re-plan: the fleet's
    :class:`~repro.elastic.membership.WorkerView` epoch bumps, the
    session re-binds (``Fabric.bind_membership``), EF residuals are
    re-seated by worker id (survivors keep theirs, joiners start at
    zero), and the next step compiles fresh under the new
    ``(num_workers, epoch)`` cache key — a stale jitted step or
    ``BucketLayout`` can never be served;
  * ``crash`` — involuntary: same view change, then rollback to the
    last durable checkpoint and deterministic replay under the shrunken
    fleet.  Controller state (CUSUM, cooldown, the admitted plan) rides
    the checkpoint via the ``controller=`` threading, so recovery never
    resets the control plane to warm-up.  EF residuals are worker-local
    state and do not survive a crash (documented loss, like the paper's
    fabric-resident accumulators).

Per-worker step times (wall-clock, or a deterministic nominal time
scaled by the active fault models) feed the
:class:`~repro.elastic.detector.StragglerDetector`, whose statistics
ride into :class:`~repro.fabric.control.Telemetry` for any controller
to act on.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..core import AdmissionPlan, GroupRules, plan_traffic_ratio
from ..core.diagnostics import group_cosines_from_mean
from ..fabric import Fabric, TrainState
from ..fabric.control import Telemetry, make_controller
from ..fabric.session import aggregate_tree, aggregate_tree_bucketed
from ..models import ModelConfig, init_params
from ..models import loss_fn as model_loss_fn
from ..optim import Optimizer
from ..runtime.fault import StepTimer
from .detector import StragglerDetector
from .faults import (FaultModel, combined_step_time_scale, resolve_faults)
from .membership import Membership, MembershipEvent

log = logging.getLogger("repro.elastic")

__all__ = ["ElasticConfig", "ElasticFailure", "ElasticTrainer"]


class ElasticFailure(RuntimeError):
    """A worker crashed: roll back to the last durable checkpoint."""

    def __init__(self, event: MembershipEvent):
        super().__init__(f"worker {event.worker} crashed at step "
                         f"{event.step}")
        self.event = event


@dataclasses.dataclass
class ElasticConfig:
    checkpoint_interval: int = 10
    checkpoint_keep: int = 3
    log_interval: int = 50
    max_restarts: int = 10
    #: None -> measure wall time per step; a float makes per-worker step
    #: times fully deterministic (nominal seconds scaled by the active
    #: fault models) for reproducible detector/controller runs.
    synthetic_step_time_s: float | None = None
    fused: bool = True


def _worker_stream(data: Any, worker: int):
    """Per-worker deterministic stream from one stream template.

    ``data`` is either a factory ``worker_id -> stream`` or a
    dataclass stream with a ``host_index`` field (SyntheticLMStream):
    each worker draws from its own host slot, so the *effective batch of
    a step depends only on the live worker set*, never on fleet history.
    """
    if callable(data) and not hasattr(data, "batch_at"):
        return data(worker)
    if dataclasses.is_dataclass(data) and hasattr(data, "host_index"):
        return dataclasses.replace(data, host_index=worker)
    raise TypeError(
        "data must be a worker_id -> stream factory or a dataclass "
        "stream with a host_index field (e.g. SyntheticLMStream)")


def _resize_ef(ef: Any, old_workers: Sequence[int],
               new_workers: Sequence[int]) -> Any:
    """Re-seat per-worker EF rows across a view change, keyed by id."""
    slot = {w: i for i, w in enumerate(old_workers)}

    def leaf(e):
        rows = [e[slot[w]] if w in slot else jnp.zeros_like(e[0])
                for w in new_workers]
        return jnp.stack(rows)

    return jax.tree.map(leaf, ef)


class ElasticTrainer:
    """Host control loop over an elastic virtual-worker fleet.

    ``membership`` is a :class:`Membership` ledger (or an int for a
    fixed initial fleet); graceful events come from its deterministic
    schedule, involuntary ones from the ``faults`` models
    (:func:`repro.elastic.resolve_faults` shapes accepted).  Controller
    resolution mirrors :class:`repro.runtime.Trainer`: ``controller=``
    (instance or registered name) for adaptive plans, ``plan=`` for the
    static fast path.
    """

    def __init__(self, cfg: ModelConfig, optimizer: Optimizer, data: Any,
                 membership: Membership | int, *,
                 faults: Sequence = (),
                 controller=None,
                 plan: AdmissionPlan | None = None,
                 rules: GroupRules | None = None,
                 ecfg: ElasticConfig | None = None,
                 ckpt_dir: str | None = None,
                 detector: StragglerDetector | None = None,
                 loss: Callable | None = None,
                 seed: int = 0):
        self.cfg, self.optimizer, self.data = cfg, optimizer, data
        self.membership = (membership if isinstance(membership, Membership)
                           else Membership(membership))
        self.faults: tuple[FaultModel, ...] = resolve_faults(faults)
        if isinstance(controller, str):
            controller = make_controller(controller)
        self.controller = controller
        self.static_plan = plan
        self.ecfg = ecfg or ElasticConfig()
        self.loss = loss
        self.seed = seed
        self.fabric = Fabric(dp_axes=("w",),
                             num_workers=self.membership.view.num_workers,
                             rules=rules, fused=self.ecfg.fused)
        self.fabric.bind_membership(self.membership.view)
        if controller is not None:
            self.fabric.attach_controller(controller)
        self.detector = detector or StragglerDetector()
        self.ckpt = (CheckpointManager(
            ckpt_dir, interval=self.ecfg.checkpoint_interval,
            keep=self.ecfg.checkpoint_keep) if ckpt_dir else None)
        self.state: TrainState | None = None
        self.history: list[dict] = []
        self.recoveries: list[dict] = []
        self.restarts = 0
        self.executed_steps = 0
        self.replayed_steps = 0
        self.total_traffic = 0.0
        self.unique_traffic = 0.0
        self._high_water = 0
        self._sizes = None
        self._streams: dict[int, Any] = {}
        self._compiled: dict[tuple, Any] = {}
        self._just_restarted = False

    # -- state ----------------------------------------------------------

    def init_state(self) -> TrainState:
        params = init_params(jax.random.PRNGKey(self.seed), self.cfg)
        opt = self.optimizer.init(params)
        self.state = TrainState(params=params, opt=opt,
                                ef=self._fresh_ef(params),
                                step=jnp.zeros((), jnp.int32))
        self._sizes = self.fabric.group_sizes(params)
        return self.state

    def _fresh_ef(self, params: Any) -> Any:
        """Full per-worker residual tree, ``(W, 1, *shape)`` per leaf.

        Capacity for *any* plan the controller may latch later (the EF
        gate in aggregation is per-policy, so non-EF plans simply pass
        the rows through untouched) — unlike the mesh Trainer, elastic
        plans change too often to size EF off the initial plan.
        """
        w = self.membership.view.num_workers
        return jax.tree.map(
            lambda p: jnp.zeros((w, 1) + tuple(p.shape), jnp.float32),
            params)

    def _current_plan(self) -> AdmissionPlan:
        if self.controller is not None:
            return self.controller.plan
        return self.static_plan or AdmissionPlan.fp32_all()

    def _stream(self, worker: int):
        if worker not in self._streams:
            self._streams[worker] = _worker_stream(self.data, worker)
        return self._streams[worker]

    def _batch(self, step: int):
        """Stacked per-worker batch, leading axis = live workers."""
        parts = [self._stream(w).batch_at(step)
                 for w in self.membership.view.workers]
        return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x)
                                                   for x in xs]), *parts)

    # -- step compilation ------------------------------------------------

    def _get_step(self, plan: AdmissionPlan, diagnostics: bool):
        # num_workers + membership epoch in the key: a step compiled for
        # one view is never served after a re-plan (same fix as
        # Fabric.step_for)
        key = (plan.signature(), diagnostics,
               self.membership.view.num_workers,
               self.fabric.membership_epoch)
        if key not in self._compiled:
            self._compiled[key] = self._build_step(plan, diagnostics)
        return self._compiled[key]

    def _build_step(self, plan: AdmissionPlan, diagnostics: bool):
        fabric, cfg, optimizer = self.fabric, self.cfg, self.optimizer
        params_like = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(self.seed), cfg))
        policies = fabric.resolve(params_like, plan)
        groups = fabric.groups(params_like)
        ctx = fabric.context
        use_fused = fabric.fused
        layout = (fabric.layout_for(params_like, policies)
                  if use_fused else None)
        lf = self.loss or (lambda p, b: model_loss_fn(p, cfg, b))

        def per_worker(params, batch, ef):
            lval, grads = jax.value_and_grad(lf)(params, batch)
            if use_fused:
                agg, new_ef = aggregate_tree_bucketed(
                    ctx, grads, policies, ef_states=ef, layout=layout)
            else:
                agg, new_ef = aggregate_tree(ctx, grads, policies,
                                             ef_states=ef)
            return jax.lax.pmean(lval, "w"), agg, new_ef

        def step_fn(state: TrainState, batch):
            lval, agg, new_ef = jax.vmap(
                per_worker, in_axes=(None, 0, 0),
                axis_name="w")(state.params, batch, state.ef)
            # post-collective values are replicated over w; take slot 0
            loss0 = lval[0]
            agg0 = jax.tree.map(lambda a: a[0], agg)
            metrics = {"loss": loss0}
            if diagnostics:
                cos = group_cosines_from_mean(agg0, groups)
                for g, d in sorted(cos.items()):
                    metrics[f"cos/{g}/gbinary"] = d["gbinary"]
                    metrics[f"cos/{g}/gternary"] = d["gternary"]
            new_params, new_opt = optimizer.apply(state.params, agg0,
                                                  state.opt)
            return (TrainState(params=new_params, opt=new_opt, ef=new_ef,
                               step=state.step + 1), metrics)

        return jax.jit(step_fn)

    # -- membership ------------------------------------------------------

    def _apply_events(self, step: int) -> MembershipEvent | None:
        """Apply all events due at ``step``; returns a crash, if any.

        Graceful scheduled events apply first, then fault-driven ones;
        every view change re-binds the session (epoch into the jit-cache
        key) and re-seats EF rows by worker id.
        """
        events = list(self.membership.step_events(step))
        for f in self.faults:
            events.extend(f.membership_events(step))
        if not events:
            return None
        old = self.membership.view
        crash = None
        for ev in events:
            self.membership.apply(ev)
            if ev.kind == "crash":
                crash = ev
        new = self.membership.view
        self.fabric.bind_membership(new)
        if self.state is not None:
            self.state = TrainState(
                params=self.state.params, opt=self.state.opt,
                ef=_resize_ef(self.state.ef, old.workers, new.workers),
                step=self.state.step)
        log.info("membership epoch %d -> %d: %s (W=%d)", old.epoch,
                 new.epoch, [e.to_jsonable() for e in events],
                 new.num_workers)
        return crash

    # -- checkpointing ---------------------------------------------------

    def _ckpt_tree(self) -> dict:
        """Durable state: params/opt/step only — EF rows are worker-local
        (their shapes change with the fleet; a crash loses them)."""
        return {"params": self.state.params, "opt": self.state.opt,
                "step": self.state.step}

    def _restore(self) -> bool:
        try:
            restored = self.ckpt.restore(self._ckpt_tree(),
                                         controller=self.controller)
        except FileNotFoundError:
            return False
        if restored is None:
            return False
        _, tree, _ = restored
        self.state = TrainState(
            params=tree["params"], opt=tree["opt"],
            ef=self._fresh_ef(tree["params"]),
            step=jnp.asarray(tree["step"], jnp.int32))
        self._just_restarted = True
        return True

    def _recover(self, failure: ElasticFailure) -> None:
        crash_step = failure.event.step
        if self.ckpt is None or not self._restore():
            # no durable checkpoint yet: deterministic re-init from step 0
            self.init_state()
            self._just_restarted = True
        restored_step = int(self.state.step)
        self.recoveries.append({
            "crash_step": crash_step,
            "restored_step": restored_step,
            "steps_to_recover": crash_step - restored_step,
            "epoch": self.membership.view.epoch,
            "num_workers": self.membership.view.num_workers,
        })
        log.warning("recovered from %s: rolled back %d steps (restart %d)",
                    failure, crash_step - restored_step, self.restarts)

    # -- loop ------------------------------------------------------------

    def run(self, num_steps: int) -> list[dict]:
        if self.state is None:
            self.init_state()
            if self.ckpt is not None and self._restore():
                log.info("restored checkpoint at step %d",
                         int(self.state.step))
        while int(self.state.step) < num_steps:
            try:
                self._run_until(num_steps)
            except ElasticFailure as e:
                self.restarts += 1
                if self.restarts > self.ecfg.max_restarts:
                    raise
                self._recover(e)
        if self.ckpt is not None:
            self.ckpt.maybe_save(int(self.state.step), self._ckpt_tree(),
                                 force=True, controller=self.controller)
            self.ckpt.wait()
        return self.history

    def _run_until(self, num_steps: int) -> None:
        while int(self.state.step) < num_steps:
            step = int(self.state.step)
            crash = self._apply_events(step)
            if crash is not None:
                raise ElasticFailure(crash)
            view = self.membership.view

            plan = self._current_plan()
            calibrating = bool(self.controller is not None and getattr(
                self.controller, "wants_diagnostics", False))
            jitted = self._get_step(plan, calibrating)
            batch = self._batch(step)

            with StepTimer() as t:
                self.state, metrics = jitted(self.state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}

            self.executed_steps += 1
            replay = step < self._high_water
            if replay:
                self.replayed_steps += 1
            self._high_water = max(self._high_water, step + 1)

            base = (self.ecfg.synthetic_step_time_s
                    if self.ecfg.synthetic_step_time_s is not None
                    else t.duration)
            times = {w: base * combined_step_time_scale(self.faults, step, w)
                     for w in view.workers}
            stats = self.detector.observe(step, times)

            ratio = plan_traffic_ratio(self._sizes, plan)
            self.total_traffic += ratio
            if not replay:
                self.unique_traffic += ratio
            metrics.update(step=step, plan=plan.signature(),
                           traffic_ratio=ratio,
                           step_time_s=max(times.values()),
                           num_workers=view.num_workers,
                           membership_epoch=view.epoch,
                           stragglers=stats.stragglers)
            self.history.append(metrics)

            if self.controller is not None:
                telemetry = dataclasses.replace(
                    Telemetry.from_metrics(step, metrics,
                                           step_time_s=max(times.values()),
                                           restart=self._just_restarted),
                    worker_step_times=times, stragglers=stats.stragglers,
                    membership_epoch=view.epoch)
                self._just_restarted = False
                self.controller.observe(telemetry)

            if self.ckpt is not None:
                self.ckpt.maybe_save(
                    step + 1, self._ckpt_tree(),
                    extra={"plan": plan.signature(),
                           "membership": view.to_jsonable()},
                    controller=self.controller)
            if step % self.ecfg.log_interval == 0:
                log.info("step %d loss %.4f W=%d epoch=%d plan=%s", step,
                         metrics["loss"], view.num_workers, view.epoch,
                         plan.signature()[:48])

    # -- reporting -------------------------------------------------------

    @property
    def traffic_overhead(self) -> float:
        """Executed over ideal gradient traffic (1.0 = no replay waste)."""
        return (self.total_traffic / self.unique_traffic
                if self.unique_traffic > 0 else 1.0)

    def report(self) -> dict:
        return {
            "steps": self._high_water,
            "executed_steps": self.executed_steps,
            "replayed_steps": self.replayed_steps,
            "traffic_overhead": self.traffic_overhead,
            "restarts": self.restarts,
            "recoveries": list(self.recoveries),
            "final_view": self.membership.view.to_jsonable(),
            "compiled_steps": len(self._compiled),
        }
