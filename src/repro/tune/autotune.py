"""The autotune entry point: plan selection as an offline compile step.

``autotune(fabric, params_like)`` prices every admissible candidate in
a :class:`~repro.tune.space.SearchSpace` against one (model, topology)
pair — analytic models for pruning, the :mod:`repro.sim` DES for
certification — and returns a :class:`~repro.tune.artifact.TunedPlan`:
the winning ``(AdmissionPlan, bucket_bytes)`` plus the full decision
record.  ``rescore`` replays a loaded artifact through the same
machinery and must reproduce it bit-identically; anything else means
the environment drifted (different codecs registered, different sim
constants, different model) and the artifact should not be trusted.
"""
from __future__ import annotations

from typing import Any

from .artifact import RunnerUp, TunedPlan, model_census
from .cost import CostModel, Objective
from .search import ScoredCandidate, make_search
from .space import Candidate, SearchSpace, default_space

__all__ = ["autotune", "rescore"]

#: estimate-pruned candidates recorded in the artifact beyond the
#: sim-certified set — enough to audit the pruning, small enough that
#: artifacts stay readable
_MAX_PRUNED_RECORDED = 8


def _runner_up(s: ScoredCandidate) -> RunnerUp:
    return RunnerUp(name=s.candidate.name, plan=s.candidate.plan,
                    bucket_bytes=s.candidate.bucket_bytes, cost=s.cost,
                    score=s.score, objective=s.objective)


def autotune(fabric, params_like: Any, space: SearchSpace | None = None, *,
             topology: str = "ici_ring", strategy: Any = "grid",
             shortlist: int = 8, objective: Objective | None = None,
             compute_time_s: float = 0.0, overlap_fraction: float = 1.0,
             pspecs: Any | None = None, name: str | None = None,
             error_feedback: bool = False,
             **topology_kwargs) -> TunedPlan:
    """Search ``space`` for the best plan on ``topology``; certify by sim.

    ``fabric``       — the session supplying worker count + group rules
                       (``params_like`` may be abstract ShapeDtypeStructs).
    ``space``        — a :class:`SearchSpace`; default:
                       :func:`~repro.tune.space.default_space` (all
                       presets + generated low-bit axes, head pinned to
                       FP32).
    ``strategy``     — a registered search-strategy name (``"grid"``,
                       ``"random"``, ``"successive_halving"``) or an
                       instance with a ``search`` method.
    ``objective``    — scalarization to minimize; default pure modeled
                       step time.
    ``topology_kwargs`` flow into the sim topology factory (e.g.
    ``workers_per_node=8`` for ``multihop``).

    The returned :class:`TunedPlan`'s sim-scored step time is never
    worse than any seed preset in the space under the same objective:
    every strategy sim-scores seeds, and the winner is the argmin over
    the sim-scored set.
    """
    space = space if space is not None else default_space(
        error_feedback=error_feedback)
    objective = objective if objective is not None else Objective()
    model = CostModel(fabric, params_like, topology=topology,
                      compute_time_s=compute_time_s,
                      overlap_fraction=overlap_fraction, pspecs=pspecs,
                      **topology_kwargs)
    candidates = list(space.enumerate(model.sizes))
    if not candidates:
        raise ValueError(
            f"search space admitted no candidates for this model "
            f"(constraints: {[c.name for c in space.constraints]}) — "
            f"relax a constraint or add seed plans that satisfy them")
    search = (strategy if hasattr(strategy, "search")
              else make_search(strategy))
    scored = search.search(candidates, model, objective,
                           shortlist=shortlist)
    certified = [s for s in scored if s.score is not None]
    if not certified:
        raise RuntimeError(
            f"search strategy {getattr(search, 'name', search)!r} "
            f"sim-scored no candidates — a strategy must certify at "
            f"least its shortlist")
    best, rest = certified[0], scored[1:]
    pruned_kept = 0
    runners: list[RunnerUp] = []
    for s in rest:
        if s.score is None:
            if pruned_kept >= _MAX_PRUNED_RECORDED:
                continue
            pruned_kept += 1
        runners.append(_runner_up(s))
    provenance = {
        "version": 1,
        "model": model_census(fabric, params_like),
        "sim": model.sim_constants(),
        "objective": objective.to_jsonable(),
        "strategy": getattr(search, "name", type(search).__name__),
        "shortlist": int(shortlist),
        "space": space.signature(),
        "constraints": [c.name for c in space.constraints],
        "candidates": {"enumerated": len(candidates),
                       "estimated": model.estimates,
                       "sim_scored": model.simulations},
    }
    return TunedPlan(
        name=name or f"tuned_{topology}",
        plan=best.candidate.plan,
        bucket_bytes=best.candidate.bucket_bytes,
        topology=topology,
        num_workers=fabric.num_workers,
        objective=float(best.objective),
        score=best.score,
        cost=best.cost,
        runners_up=tuple(runners),
        provenance=provenance)


def rescore(tuned: TunedPlan, fabric, params_like: Any, *,
            pspecs: Any | None = None) -> TunedPlan:
    """Re-derive a :class:`TunedPlan`'s scores in this environment.

    Rebuilds the cost model from the artifact's recorded sim constants,
    re-prices the winner and every sim-certified runner-up, and returns
    a new artifact carrying the recomputed numbers (provenance copied
    verbatim).  Because the analytic models and the DES are
    deterministic, ``rescore(TunedPlan.load(p), fabric, params)
    .to_jsonable() == TunedPlan.load(p).to_jsonable()`` whenever the
    environment matches; a mismatched model census raises instead of
    silently producing scores for the wrong network.
    """
    sim = dict(tuned.provenance.get("sim", {}))
    census = tuned.provenance.get("model")
    here = model_census(fabric, params_like)
    if census is not None and census != here:
        raise ValueError(
            f"model census mismatch: artifact was tuned for "
            f"{census}, this session sees {here}")
    if int(tuned.num_workers) != int(fabric.num_workers):
        raise ValueError(
            f"worker-count mismatch: artifact tuned for "
            f"{tuned.num_workers} workers, session has "
            f"{fabric.num_workers}")
    objective = Objective.from_jsonable(
        tuned.provenance.get("objective", Objective().to_jsonable()))
    model = CostModel(
        fabric, params_like,
        topology=sim.get("topology", tuned.topology),
        compute_time_s=sim.get("compute_time_s", 0.0),
        overlap_fraction=sim.get("overlap_fraction", 1.0),
        pspecs=pspecs, **sim.get("topology_kwargs", {}))

    def reprice(name, plan, bucket_bytes, had_score):
        cand = Candidate(name=name, plan=plan,
                         bucket_bytes=int(bucket_bytes))
        cost = model.estimate(cand)
        score = model.simulate(cand) if had_score else None
        return cost, score

    cost, score = reprice(tuned.name, tuned.plan, tuned.bucket_bytes, True)
    runners = []
    for r in tuned.runners_up:
        r_cost, r_score = reprice(r.name, r.plan, r.bucket_bytes,
                                  r.score is not None)
        runners.append(RunnerUp(
            name=r.name, plan=r.plan, bucket_bytes=r.bucket_bytes,
            cost=r_cost, score=r_score,
            objective=(None if r_score is None
                       else objective.of_score(r_score))))
    return TunedPlan(
        name=tuned.name, plan=tuned.plan,
        bucket_bytes=tuned.bucket_bytes, topology=tuned.topology,
        num_workers=tuned.num_workers,
        objective=float(objective.of_score(score)),
        score=score, cost=cost, runners_up=tuple(runners),
        provenance=dict(tuned.provenance))
