"""Search-strategy registry: how the candidate space is explored.

The seventh registry — same :class:`repro.core.registry.Registry`
backbone, same extension idiom as schedules / codecs / controllers /
topologies / serve policies / faults: strategies register under a
string name and ``fabric.autotune(..., strategy="successive_halving")``
addresses them without touching the tuner.

A strategy turns ``(candidates, cost model, objective)`` into a list of
:class:`ScoredCandidate` — every candidate it visited, the shortlist it
chose to certify carrying a full :class:`~repro.tune.cost.SimScore`,
the rest carrying only the analytic :class:`~repro.tune.cost
.CostEstimate`.  One invariant is shared by every built-in and expected
of extensions: **seed candidates are always sim-scored**.  Seeds are
the preset baselines the tuned plan claims to beat; pruning one on the
cheap estimate would turn "never slower than the best preset it
searched over" into a hope instead of a property.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Protocol, Sequence, runtime_checkable

from ..core.registry import Registry
from .cost import CostEstimate, CostModel, Objective, SimScore
from .space import Candidate

__all__ = [
    "GridSearch", "RandomSearch", "ScoredCandidate", "SearchStrategy",
    "SuccessiveHalving", "available_searches", "get_search", "make_search",
    "register_search", "unregister_search",
]


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    """One visited candidate with whatever fidelity it reached.

    ``score``/``objective`` are None for candidates pruned on the
    analytic estimate; ``estimate_objective`` is always present (the
    pruning-fidelity scalar, comparable only to other estimates).
    """
    candidate: Candidate
    cost: CostEstimate
    score: SimScore | None = None
    objective: float | None = None
    estimate_objective: float = math.inf


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@runtime_checkable
class SearchStrategy(Protocol):
    """Protocol every registered strategy implements."""

    name: str

    def search(self, candidates: Sequence[Candidate], model: CostModel,
               objective: Objective, *,
               shortlist: int = 8) -> list[ScoredCandidate]: ...


#: strategies are stateless-per-run but construction-parametric
#: (``random`` takes a sample budget, ``successive_halving`` an eta),
#: so — like controllers — the registry holds factories and
#: :func:`make_search` builds a fresh instance per call.
_SEARCHES = Registry(
    "search strategy", key_fn=str,
    describe=lambda f: getattr(f, "__name__", type(f).__name__),
    register_hint="@register_search({key!r})")


def register_search(name: str, *aliases: str, override: bool = False):
    """Class/factory decorator registering a search strategy."""
    return _SEARCHES.register(name, *aliases, override=override)


def unregister_search(name: str) -> None:
    """Remove a strategy factory and all its aliases."""
    _SEARCHES.unregister(name)


def get_search(name: str):
    """Resolve a strategy name to its registered factory."""
    return _SEARCHES.get(name)


def make_search(name: str, **kwargs) -> SearchStrategy:
    """Construct a fresh strategy instance from its registered name."""
    return get_search(name)(**kwargs)


def available_searches() -> tuple[str, ...]:
    return _SEARCHES.available()


# ---------------------------------------------------------------------------
# shared machinery
# ---------------------------------------------------------------------------

def _estimate_all(cands: Sequence[Candidate], model: CostModel,
                  objective: Objective) -> list[tuple[Candidate,
                                                      CostEstimate, float]]:
    out = []
    for c in cands:
        cost = model.estimate(c)
        out.append((c, cost, objective.of_estimate(cost)))
    # deterministic rank: estimate scalar, then bytes, then name
    out.sort(key=lambda e: (e[2], e[1].wire_bytes, e[0].name))
    return out


def _certify(entries, model: CostModel, objective: Objective, keep: set
             ) -> list[ScoredCandidate]:
    """Full-sim the kept candidates, carry the rest estimate-only."""
    scored: list[ScoredCandidate] = []
    for cand, cost, est in entries:
        if cand.signature() in keep:
            score = model.simulate(cand)
            scored.append(ScoredCandidate(cand, cost, score,
                                          objective.of_score(score), est))
        else:
            scored.append(ScoredCandidate(cand, cost,
                                          estimate_objective=est))
    scored.sort(key=_result_rank)
    return scored


def _result_rank(s: ScoredCandidate):
    """Sim-certified first (by objective), then pruned (by estimate)."""
    if s.objective is not None:
        return (0, s.objective, s.score.wire_bytes, s.candidate.name)
    return (1, s.estimate_objective, s.cost.wire_bytes, s.candidate.name)


def _with_seeds(keep, entries) -> set:
    keep = set(keep)
    keep.update(c.signature() for c, _, _ in entries if c.seed)
    return keep


# ---------------------------------------------------------------------------
# built-in strategies
# ---------------------------------------------------------------------------

@register_search("grid")
class GridSearch:
    """Exhaustive estimate, sim-certify the analytic top-``shortlist``.

    The default: visits every candidate at the cheap fidelity, then
    runs the DES only on the best ``shortlist`` (plus every seed).
    """

    name = "grid"

    def search(self, candidates, model, objective, *, shortlist: int = 8
               ) -> list[ScoredCandidate]:
        entries = _estimate_all(candidates, model, objective)
        keep = _with_seeds(
            (c.signature() for c, _, _ in entries[:max(1, shortlist)]),
            entries)
        return _certify(entries, model, objective, keep)


@register_search("random")
class RandomSearch:
    """Uniform subsample of the generated space (seeds always kept).

    For spaces too large to estimate exhaustively: visits ``samples``
    non-seed candidates drawn with a fixed ``seed`` (deterministic
    artifacts), then behaves like :class:`GridSearch` on the sample.
    """

    name = "random"

    def __init__(self, samples: int = 32, seed: int = 0):
        self.samples = int(samples)
        self.seed = int(seed)

    def search(self, candidates, model, objective, *, shortlist: int = 8
               ) -> list[ScoredCandidate]:
        seeds = [c for c in candidates if c.seed]
        rest = [c for c in candidates if not c.seed]
        if len(rest) > self.samples:
            rng = random.Random(self.seed)
            rest = rng.sample(rest, self.samples)
        entries = _estimate_all(seeds + rest, model, objective)
        keep = _with_seeds(
            (c.signature() for c, _, _ in entries[:max(1, shortlist)]),
            entries)
        return _certify(entries, model, objective, keep)


@register_search("successive_halving", "sha")
class SuccessiveHalving:
    """Multi-fidelity halving: estimate -> transport-only sim -> full sim.

    Rung 0 ranks everything on the closed-form estimate; rung 1 replays
    the top ``1/eta`` through the DES with a zero-cost datapath
    (transport + queueing only — real contention, no flit pipeline);
    the final rung certifies the survivors (never fewer than
    ``shortlist``, seeds always included) with the full 5-stage
    datapath.  The middle rung is what lets a candidate the analytic
    model misranks under queueing claw its way back before the
    expensive fidelity.
    """

    name = "successive_halving"

    def __init__(self, eta: float = 2.0):
        if eta <= 1.0:
            raise ValueError(f"eta must be > 1, got {eta}")
        self.eta = float(eta)

    def search(self, candidates, model, objective, *, shortlist: int = 8
               ) -> list[ScoredCandidate]:
        entries = _estimate_all(candidates, model, objective)
        floor = max(1, shortlist)
        n1 = max(floor, math.ceil(len(entries) / self.eta))
        rung1 = _with_seeds(
            (c.signature() for c, _, _ in entries[:n1]), entries)
        # mid fidelity: transport-only DES on the rung-1 survivors
        mid: list[tuple[Candidate, float]] = []
        for cand, _cost, _est in entries:
            if cand.signature() in rung1:
                s = model.simulate(cand, datapath=None)
                mid.append((cand, objective.of_score(s)))
        mid.sort(key=lambda e: (e[1], e[0].name))
        n2 = max(floor, math.ceil(len(mid) / self.eta))
        keep = _with_seeds(
            (c.signature() for c, _ in mid[:n2]), entries)
        return _certify(entries, model, objective, keep)
