"""The ``TunedPlan`` artifact: a compiled plan choice with provenance.

Tuning treats plan selection as *compilation*, and a compiler's output
must be reproducible and inspectable.  A :class:`TunedPlan` therefore
carries everything needed to (a) use the plan — the winning
:class:`~repro.core.buckets.AdmissionPlan` plus its bucket budget —
and (b) re-derive the decision — the model census, sim constants,
objective weights, search-space signature, and the runner-up table the
online controller re-ranks at runtime.

The artifact round-trips through JSON bit-identically:
``TunedPlan.from_jsonable(t.to_jsonable()) == t`` and a
:func:`rescore` of the loaded artifact (same session, same model)
reproduces the exact scores — the DES and the analytic models are
deterministic, and every knob they read is in the provenance.

``install()`` registers the winning plan as a named
:func:`~repro.fabric.control.plan_presets` entry, so a tuned plan is
addressed exactly like a hand-written preset — ``--plan`` on the
launcher, ``StaticController(plan="tuned_ici_ring")``, a Commander
ladder target.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from ..core.buckets import AdmissionPlan
from .cost import CostEstimate, SimScore

__all__ = ["ARTIFACT_VERSION", "RunnerUp", "TunedPlan"]

#: bumped when the JSON schema changes; ``from_jsonable`` rejects
#: artifacts from a newer schema instead of misreading them
ARTIFACT_VERSION = 1


def _plan_to_jsonable(plan: AdmissionPlan) -> dict:
    from ..fabric.control import plan_to_jsonable
    return plan_to_jsonable(plan)


def _plan_from_jsonable(obj: Mapping) -> AdmissionPlan:
    from ..fabric.control import plan_from_jsonable
    return plan_from_jsonable(dict(obj))


@dataclasses.dataclass(frozen=True)
class RunnerUp:
    """One non-winning candidate kept in the artifact.

    Sim-certified runners-up carry a full :class:`SimScore` (these are
    what the online controller may switch to); estimate-pruned ones
    carry only the analytic figures, recorded so a re-run can audit
    what the pruning fidelity claimed.
    """
    name: str
    plan: AdmissionPlan
    bucket_bytes: int
    cost: CostEstimate
    score: SimScore | None = None
    objective: float | None = None

    def to_jsonable(self) -> dict:
        return {"name": self.name,
                "plan": _plan_to_jsonable(self.plan),
                "bucket_bytes": int(self.bucket_bytes),
                "cost": self.cost.to_jsonable(),
                "score": (None if self.score is None
                          else self.score.to_jsonable()),
                "objective": self.objective}

    @staticmethod
    def from_jsonable(d: Mapping) -> "RunnerUp":
        score = d.get("score")
        return RunnerUp(
            name=str(d["name"]),
            plan=_plan_from_jsonable(d["plan"]),
            bucket_bytes=int(d["bucket_bytes"]),
            cost=CostEstimate.from_jsonable(d["cost"]),
            score=None if score is None else SimScore.from_jsonable(score),
            objective=(None if d.get("objective") is None
                       else float(d["objective"])))


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """The autotuner's output: one certified plan + its decision record."""
    name: str
    plan: AdmissionPlan
    bucket_bytes: int
    topology: str
    num_workers: int
    objective: float            # the winner's scalarized sim objective
    score: SimScore
    cost: CostEstimate
    runners_up: tuple = ()      # tuple[RunnerUp], best first
    provenance: Mapping = dataclasses.field(default_factory=dict)

    # -- use -------------------------------------------------------------

    def group_policy(self, group: str):
        """The tuned plan's policy for one parameter group."""
        return self.plan.policy_for(group)

    def apply(self, fabric) -> AdmissionPlan:
        """Point a session at this plan: set its bucket budget, clear
        stale layout/step caches, return the plan to train with."""
        fabric.bucket_bytes = int(self.bucket_bytes)
        fabric.clear_cache()
        return self.plan

    def install(self, name: str | None = None, *,
                override: bool = False) -> str:
        """Register the winning plan as a named preset.

        After ``tuned.install()`` the plan resolves anywhere presets
        do: ``plan_presets()[tuned.name]``, the launcher's ``--plan``,
        ``StaticController(plan=tuned.name)``.  Returns the name.
        """
        from ..fabric.control import register_plan_preset
        name = name or self.name
        register_plan_preset(name, self.plan, override=override)
        return name

    # -- persistence -----------------------------------------------------

    def to_jsonable(self) -> dict:
        return {"version": ARTIFACT_VERSION,
                "name": self.name,
                "plan": _plan_to_jsonable(self.plan),
                "plan_signature": self.plan.signature(),
                "bucket_bytes": int(self.bucket_bytes),
                "topology": self.topology,
                "num_workers": int(self.num_workers),
                "objective": float(self.objective),
                "score": self.score.to_jsonable(),
                "cost": self.cost.to_jsonable(),
                "runners_up": [r.to_jsonable() for r in self.runners_up],
                "provenance": dict(self.provenance)}

    @staticmethod
    def from_jsonable(d: Mapping) -> "TunedPlan":
        version = int(d.get("version", 0))
        if version > ARTIFACT_VERSION:
            raise ValueError(
                f"TunedPlan artifact version {version} is newer than this "
                f"build understands ({ARTIFACT_VERSION}); refusing to "
                f"misread it")
        plan = _plan_from_jsonable(d["plan"])
        recorded = d.get("plan_signature")
        if recorded is not None and plan.signature() != recorded:
            raise ValueError(
                f"TunedPlan plan decoded to signature "
                f"{plan.signature()!r} but the artifact recorded "
                f"{recorded!r} — the artifact references codecs/schedules "
                f"not registered in this process, or was edited")
        return TunedPlan(
            name=str(d["name"]), plan=plan,
            bucket_bytes=int(d["bucket_bytes"]),
            topology=str(d["topology"]),
            num_workers=int(d["num_workers"]),
            objective=float(d["objective"]),
            score=SimScore.from_jsonable(d["score"]),
            cost=CostEstimate.from_jsonable(d["cost"]),
            runners_up=tuple(RunnerUp.from_jsonable(r)
                             for r in d.get("runners_up", ())),
            provenance=dict(d.get("provenance", {})))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_jsonable(), f, indent=1, sort_keys=True)
        return path

    @staticmethod
    def load(path: str) -> "TunedPlan":
        with open(path) as f:
            return TunedPlan.from_jsonable(json.load(f))

    def summary(self) -> dict:
        """Compact scalars for logs / benchmark JSON."""
        return {"name": self.name,
                "plan_signature": self.plan.signature(),
                "bucket_bytes": int(self.bucket_bytes),
                "topology": self.topology,
                "step_time_s": self.score.step_time_s,
                "exposed_pct": self.score.exposed_pct,
                "wire_bytes": self.score.wire_bytes,
                "launches": self.score.launches,
                "traffic_ratio": self.cost.traffic_ratio,
                "objective": float(self.objective)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"TunedPlan({self.name!r}, topology={self.topology!r}, "
                f"step={self.score.step_time_s * 1e6:.1f}us, "
                f"{len(self.runners_up)} runners-up)")


def model_census(fabric, params_like: Any) -> dict:
    """The provenance record tying an artifact to its model.

    Leaf count, total parameters, and the group census — enough for
    :func:`~repro.tune.autotune.rescore` to refuse a mismatched model
    without hashing array contents (the tuner never reads values).
    """
    import jax
    leaves = jax.tree_util.tree_leaves(params_like)
    sizes = fabric.group_sizes(params_like)
    return {"num_leaves": len(leaves),
            "total_params": int(sum(sizes.values())),
            "group_sizes": {g: int(n) for g, n in sorted(sizes.items())}}
