"""repro.tune — sim-driven plan autotuning: plan selection as compilation.

The repo exposes a large configuration surface — codecs, schedules, hop
plans, bucket budgets, per-group overrides — and the paper picks one
point in it by hand.  This package searches that surface the way a
compiler searches loop schedules, against the modeling stack the repo
already trusts:

  * :mod:`space`    — :class:`SearchSpace`: declarative candidate
    enumeration (seed presets + generated codec/schedule/EF/group/bucket
    axes) with accuracy guardrails as *admission constraints*
    (:class:`PinGroup`, :class:`MaxLowbitFraction`) — a violating plan
    is never part of the space;
  * :mod:`cost`     — :class:`CostModel`: analytic
    ``modeled_layout_comm_time`` / ``MultiHopModel`` pricing for cheap
    pruning, the :mod:`repro.sim` DES for certification; one
    :class:`Objective` scalarization shared by both fidelities;
  * :mod:`search`   — the seventh registry, ``@register_search``:
    ``grid``, ``random``, ``successive_halving`` built-ins.  Invariant:
    seed presets are always sim-scored, so the tuned result is provably
    no worse than any preset it searched over;
  * :mod:`artifact` — :class:`TunedPlan`: a reproducible JSON record
    (plan + bucket budget + scores + runner-up table + provenance) that
    ``install()``s back into :func:`~repro.fabric.control.plan_presets`
    by name;
  * :mod:`autotune` — the :func:`autotune` orchestration (also exposed
    as ``Fabric.autotune``) and :func:`rescore` bit-identical
    revalidation;
  * :mod:`online`   — the ``"tuned"`` controller: re-ranks the
    sim-certified shortlist from live :class:`Telemetry` step times
    through the standard controller seam.

Importing the package registers the built-in search strategies and the
``"tuned"`` controller.
"""
from .artifact import ARTIFACT_VERSION, RunnerUp, TunedPlan, model_census
from .autotune import autotune, rescore
from .cost import CostEstimate, CostModel, Objective, SimScore
from .online import TunedPlanController
from .search import (GridSearch, RandomSearch, ScoredCandidate,
                     SearchStrategy, SuccessiveHalving, available_searches,
                     get_search, make_search, register_search,
                     unregister_search)
from .space import (Candidate, Constraint, MaxLowbitFraction, PinGroup,
                    SearchSpace, default_space)

__all__ = [
    "ARTIFACT_VERSION", "Candidate", "Constraint", "CostEstimate",
    "CostModel", "GridSearch", "MaxLowbitFraction", "Objective",
    "PinGroup", "RandomSearch", "RunnerUp", "ScoredCandidate",
    "SearchSpace", "SearchStrategy", "SimScore", "SuccessiveHalving",
    "TunedPlan", "TunedPlanController", "autotune", "available_searches",
    "default_space", "get_search", "make_search", "model_census",
    "register_search", "rescore", "unregister_search",
]
