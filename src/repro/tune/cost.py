"""Candidate pricing: analytic estimates for pruning, DES sim for truth.

Two fidelities, exactly the repo's two modeling layers:

  * :meth:`CostModel.estimate` — closed-form
    :func:`~repro.core.traffic.modeled_layout_comm_time` /
    :func:`~repro.core.traffic.modeled_layout_multihop_time` over the
    candidate's bucket layout.  Cheap (no event loop), monotone in
    bytes and launches — good enough to *rank* candidates for pruning,
    not to certify a winner.
  * :meth:`CostModel.simulate` — the :mod:`repro.sim` discrete-event
    replay of the same layout (queueing, per-bucket pipelining,
    compute overlap, datapath exposure).  This is the score the tuned
    plan is certified against; PR 4 validated it within 1% of the
    analytic models on their shared domain, which is what makes the
    offline objective trustworthy.

Layouts are planned once per candidate signature and cached — the
candidate's own ``bucket_bytes`` is part of the plan, so two candidates
differing only in bucket budget price differently (launch-latency
amortization vs emission granularity).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from ..core.buckets import AdmissionPlan, plan_buckets
from ..core.traffic import (IciModel, MultiHopModel,
                            hop_wire_bytes_per_device,
                            modeled_layout_comm_time,
                            modeled_layout_multihop_time,
                            plan_traffic_ratio)
from .space import Candidate

__all__ = ["CostEstimate", "CostModel", "Objective", "SimScore"]


# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Closed-form price of one candidate (the pruning fidelity)."""
    comm_time_s: float          # modeled collective time, all launches
    wire_bytes: float           # per-device bytes crossing links
    launches: int
    traffic_ratio: float        # payload accounting vs FP32

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_jsonable(d: Mapping) -> "CostEstimate":
        return CostEstimate(comm_time_s=float(d["comm_time_s"]),
                            wire_bytes=float(d["wire_bytes"]),
                            launches=int(d["launches"]),
                            traffic_ratio=float(d["traffic_ratio"]))


@dataclasses.dataclass(frozen=True)
class SimScore:
    """DES-simulated price of one candidate (the certifying fidelity)."""
    step_time_s: float
    exposed_pct: float
    wire_bytes: float
    launches: int
    hidden: bool

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_jsonable(d: Mapping) -> "SimScore":
        return SimScore(step_time_s=float(d["step_time_s"]),
                        exposed_pct=float(d["exposed_pct"]),
                        wire_bytes=float(d["wire_bytes"]),
                        launches=int(d["launches"]),
                        hidden=bool(d["hidden"]))


@dataclasses.dataclass(frozen=True)
class Objective:
    """Scalarization the tuner minimizes.

    ``value`` is modeled step seconds plus wire traffic priced at
    ``wire_byte_weight`` seconds/byte — the default weights reduce to
    pure step time (the ROADMAP north star), with wire bytes kept as a
    deterministic tiebreak at the selection site rather than in the
    scalar.  The same weights apply to both fidelities, so analytic
    pruning and sim certification optimize the same thing.
    """
    step_time_weight: float = 1.0
    wire_byte_weight: float = 0.0

    def value(self, step_time_s: float, wire_bytes: float) -> float:
        return (self.step_time_weight * step_time_s
                + self.wire_byte_weight * wire_bytes)

    def of_score(self, score: SimScore) -> float:
        return self.value(score.step_time_s, score.wire_bytes)

    def of_estimate(self, cost: CostEstimate) -> float:
        return self.value(cost.comm_time_s, cost.wire_bytes)

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_jsonable(d: Mapping) -> "Objective":
        return Objective(step_time_weight=float(d["step_time_weight"]),
                         wire_byte_weight=float(d["wire_byte_weight"]))


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class CostModel:
    """Prices candidates for one (session, model, topology) triple.

    ``fabric`` supplies worker count, group rules, and policy
    resolution; ``params_like`` may be concrete arrays or abstract
    ShapeDtypeStructs (only shapes/dtypes are read).  ``topology`` is a
    registered sim topology name; the analytic fidelity routes
    ``"multihop"`` through :class:`~repro.core.traffic.MultiHopModel`
    and everything else through the ring :class:`IciModel` — an
    approximation for the CXL lanes, which is exactly why seeds and the
    shortlist are re-scored by the DES before anything is certified.
    """

    def __init__(self, fabric, params_like: Any, *,
                 topology: str = "ici_ring",
                 compute_time_s: float = 0.0,
                 overlap_fraction: float = 1.0,
                 pspecs: Any | None = None,
                 ici: IciModel | None = None,
                 multihop: MultiHopModel | None = None,
                 **topology_kwargs):
        self.fabric = fabric
        self.params_like = params_like
        self.topology = str(topology)
        self.compute_time_s = float(compute_time_s)
        self.overlap_fraction = float(overlap_fraction)
        self.pspecs = pspecs
        self.ici = ici or IciModel()
        self.multihop = multihop or MultiHopModel()
        self.topology_kwargs = dict(topology_kwargs)
        self.sizes = fabric.group_sizes(params_like)
        self._layouts: dict[str, Any] = {}
        #: sim fidelity counters (land in TunedPlan provenance)
        self.estimates = 0
        self.simulations = 0

    # -- layout ----------------------------------------------------------

    def layout(self, cand: Candidate):
        """The candidate's bucket layout (cached per signature)."""
        sig = cand.signature()
        if sig not in self._layouts:
            from ..fabric.session import _registry_fusable
            policies = self.fabric.resolve(self.params_like, cand.plan,
                                           pspecs=self.pspecs)
            self._layouts[sig] = plan_buckets(
                self.params_like, policies,
                bucket_bytes=cand.bucket_bytes,
                fusable=_registry_fusable)
        return self._layouts[sig]

    # -- fidelity 1: closed-form estimate --------------------------------

    def estimate(self, cand: Candidate) -> CostEstimate:
        layout = self.layout(cand)
        w = self.fabric.num_workers
        if self.topology == "multihop":
            t = modeled_layout_multihop_time(layout, w, self.multihop)
        else:
            t = modeled_layout_comm_time(layout, w, self.ici)
        wire = sum(
            sum(hop_wire_bytes_per_device(n, key.mode, key.schedule, w))
            for key, n in layout.launches())
        self.estimates += 1
        return CostEstimate(
            comm_time_s=float(t), wire_bytes=float(wire),
            launches=layout.num_launches,
            traffic_ratio=float(plan_traffic_ratio(self.sizes, cand.plan)))

    # -- fidelity 2: discrete-event simulation ---------------------------

    def simulate(self, cand: Candidate, *, datapath: Any = "default"
                 ) -> SimScore:
        """Replay the candidate's layout through :mod:`repro.sim`.

        ``datapath="default"`` uses the paper's 5-stage
        :class:`~repro.sim.FlitPipeline`; ``datapath=None`` simulates
        transport only (the cheaper mid-fidelity rung successive
        halving climbs through — note ``simulate_layout`` would coerce
        None back to the full pipeline, so this goes through
        ``simulate_launches``, which honors it).
        """
        from ..sim import (FlitPipeline, layout_launch_specs,
                           simulate_launches)
        if datapath == "default":
            datapath = FlitPipeline()
        w = self.fabric.num_workers
        specs = layout_launch_specs(self.layout(cand), w,
                                    compute_time_s=self.compute_time_s)
        report = simulate_launches(
            specs, w, topology=self.topology, datapath=datapath,
            overlap_fraction=self.overlap_fraction,
            compute_time_s=self.compute_time_s, **self.topology_kwargs)
        self.simulations += 1
        return SimScore(
            step_time_s=float(report.step_time_s),
            exposed_pct=float(report.exposed_pct),
            wire_bytes=float(report.wire_bytes_total),
            launches=report.num_launches,
            hidden=bool(report.hidden))

    # -- provenance ------------------------------------------------------

    def sim_constants(self) -> dict:
        """The knobs a bit-identical re-score must reproduce."""
        return {"topology": self.topology,
                "compute_time_s": self.compute_time_s,
                "overlap_fraction": self.overlap_fraction,
                "topology_kwargs": dict(self.topology_kwargs)}
