"""Search-space spec: which plan configurations the tuner may propose.

A :class:`SearchSpace` enumerates *candidates* — concrete
``(AdmissionPlan, bucket_bytes)`` pairs — from two sources:

  * **seed plans**: named :func:`~repro.fabric.control.plan_presets`
    entries (or any hand-built plans).  Seeds are the baselines the
    tuned plan must beat, so every search strategy sim-scores them in
    full — a seed can never be pruned away on the analytic estimate and
    then turn out faster than the winner.
  * **generated plans**: the cross product of the codec / schedule /
    error-feedback axes over the backbone group, optionally crossed
    with per-group override axes (``group_axes``) such as "also admit
    the embedding tables" — the paper's layer-group admission ladder
    expressed as a search dimension.

Every candidate — seed or generated — passes the space's *admission
constraints* before it is emitted.  Constraints are the accuracy
guardrails of the controller ladder expressed declaratively (sensitive
groups pinned to FP32, a cap on the admitted low-bit fraction), so the
search can never propose a plan the control plane would reject: a
violating configuration is not "searched and discarded", it simply is
not part of the space.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterator, Mapping, Protocol, runtime_checkable

from ..core.buckets import AdmissionPlan, DEFAULT_BUCKET_BYTES, GroupPolicy
from ..core.modes import canonical_mode, codec_name, schedule_name
from ..fabric.codecs import get_codec

__all__ = [
    "Candidate", "Constraint", "MaxLowbitFraction", "PinGroup",
    "SearchSpace", "default_space",
]


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    """One concrete configuration the tuner can score.

    ``seed`` marks plans carried in verbatim (presets / user baselines);
    strategies always sim-score seeds so the tuned result is provably
    no worse than any of them under the same objective.
    """
    name: str
    plan: AdmissionPlan
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    seed: bool = False

    def signature(self) -> str:
        """Dedup / artifact identity: plan signature + bucket budget."""
        return f"{self.plan.signature()}@bb={int(self.bucket_bytes)}"


def _bb_tag(bucket_bytes: int) -> str:
    mib = 2 ** 20
    if bucket_bytes % mib == 0:
        return f"{bucket_bytes // mib}MiB"
    return f"{bucket_bytes}B"


# ---------------------------------------------------------------------------
# admission constraints (accuracy guardrails)
# ---------------------------------------------------------------------------

@runtime_checkable
class Constraint(Protocol):
    """A predicate every emitted candidate plan must satisfy.

    ``sizes`` is the model's ``group -> element count`` census (from
    :func:`repro.core.buckets.group_sizes`), so constraints can reason
    about admitted fractions, not just group names.
    """

    name: str

    def admits(self, plan: AdmissionPlan, sizes: Mapping[str, int]) -> bool: ...


@dataclasses.dataclass(frozen=True)
class PinGroup:
    """Pin one parameter group to a fixed codec (default: FP32).

    The paper's central guardrail — the classifier head (and anything
    head-like) never rides the low-bit path — as a space constraint:
    ``PinGroup("head")`` removes every plan whose head policy resolves
    to anything but ``fp32`` from the search space.
    """
    group: str
    mode: str = "fp32"

    @property
    def name(self) -> str:
        return f"pin:{self.group}={codec_name(self.mode)}"

    def admits(self, plan: AdmissionPlan, sizes: Mapping[str, int]) -> bool:
        return (codec_name(plan.policy_for(self.group).mode)
                == codec_name(self.mode))


@dataclasses.dataclass(frozen=True)
class MaxLowbitFraction:
    """Cap the parameter fraction admitted to sub-FP32 codecs.

    A group counts as low-bit when its codec's ``bits_per_element`` is
    below 32 (votes, quantizers, sparsifiers, hierarchical routes whose
    backbone hop is low-bit — the same accounting the traffic model
    uses).  ``MaxLowbitFraction(0.0)`` degenerates to "FP32 everywhere".
    """
    max_fraction: float

    @property
    def name(self) -> str:
        return f"lowbit<={self.max_fraction:g}"

    def admits(self, plan: AdmissionPlan, sizes: Mapping[str, int]) -> bool:
        total = sum(sizes.values())
        if total == 0:
            return True
        low = sum(n for g, n in sizes.items()
                  if get_codec(plan.policy_for(g).mode).bits_per_element
                  < 32.0)
        return low / total <= self.max_fraction + 1e-12


# ---------------------------------------------------------------------------
# the space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Declarative candidate enumeration for the plan autotuner.

    ``plans``       — seed ``(name, AdmissionPlan)`` pairs (presets).
    ``codecs``      — backbone codec axis for generated plans.
    ``schedules``   — schedule axis (None = the codec's default).
    ``error_feedback`` — EF axis; coerced off per candidate when the
                      backbone codec declares ``threads_ef=False`` (an
                      EF flag on such a codec allocates residuals that
                      never update — the same rule ``plan_presets``
                      applies to ``int4_backbone``).
    ``group_axes``  — per-group override axes for generated plans:
                      ``((group, (codec, ...)), ...)``; ``"fp32"``
                      keeps the group on the default bypass.
    ``bucket_bytes`` — fused-bucket budget axis (applies to seeds too).
    ``constraints`` — admission guardrails every emitted candidate must
                      pass (:class:`PinGroup`, :class:`MaxLowbitFraction`,
                      or any :class:`Constraint`).

    Candidates are deduplicated on ``(plan signature, bucket_bytes)`` —
    a generated plan identical to a seed keeps the seed entry (and its
    always-sim-scored status).
    """
    plans: tuple = ()                 # ((name, AdmissionPlan), ...)
    codecs: tuple = ()
    schedules: tuple = (None,)
    error_feedback: tuple = (False,)
    group_axes: tuple = ()            # ((group, (codec, ...)), ...)
    bucket_bytes: tuple = (DEFAULT_BUCKET_BYTES,)
    constraints: tuple = ()

    def __post_init__(self):
        if not self.bucket_bytes:
            raise ValueError("SearchSpace needs at least one bucket_bytes "
                             "entry")
        if not self.plans and not self.codecs:
            raise ValueError("empty SearchSpace: give seed plans and/or a "
                             "generated codec axis")

    # -- provenance ------------------------------------------------------

    def signature(self) -> str:
        """Stable description of the axes (lands in TunedPlan provenance)."""
        seeds = ",".join(n for n, _ in self.plans)
        sch = ",".join("auto" if s is None else schedule_name(s)
                       for s in self.schedules)
        groups = ";".join(f"{g}:{','.join(codec_name(c) for c in cs)}"
                          for g, cs in self.group_axes)
        cons = ",".join(c.name for c in self.constraints)
        return ("seeds[" + seeds + "]|codecs["
                + ",".join(codec_name(c) for c in self.codecs)
                + f"]|schedules[{sch}]|ef["
                + ",".join(str(int(e)) for e in self.error_feedback)
                + f"]|groups[{groups}]|bb["
                + ",".join(str(int(b)) for b in self.bucket_bytes)
                + f"]|constraints[{cons}]")

    # -- enumeration -----------------------------------------------------

    def admits(self, plan: AdmissionPlan, sizes: Mapping[str, int]) -> bool:
        return all(c.admits(plan, sizes) for c in self.constraints)

    def _generated(self) -> Iterator[tuple[str, AdmissionPlan]]:
        group_axes = tuple((g, tuple(cs)) for g, cs in self.group_axes)
        axis_groups = [g for g, _ in group_axes]
        axis_choices = [cs for _, cs in group_axes]
        for codec, sched, ef in itertools.product(
                self.codecs, self.schedules, self.error_feedback):
            ef = bool(ef) and get_codec(codec).threads_ef
            for choices in itertools.product(*axis_choices):
                d = {"backbone": GroupPolicy(canonical_mode(codec), sched,
                                             ef)}
                tags = []
                for g, choice in zip(axis_groups, choices):
                    if codec_name(choice) == "fp32":
                        continue      # default bypass: no override entry
                    g_ef = bool(ef) and get_codec(choice).threads_ef
                    d[g] = GroupPolicy(canonical_mode(choice), sched, g_ef)
                    tags.append(f"+{g}={codec_name(choice)}")
                plan = AdmissionPlan.from_dict(
                    d, default=GroupPolicy(canonical_mode("fp32")))
                name = (codec_name(codec)
                        + ("" if sched is None
                           else f"@{schedule_name(sched)}")
                        + ("+ef" if ef else "") + "".join(tags))
                yield name, plan

    def enumerate(self, sizes: Mapping[str, int]) -> Iterator[Candidate]:
        """Yield every admissible candidate, seeds first, deduplicated.

        ``sizes`` is the target model's group census — constraints are
        evaluated against it, so the same space can admit different
        plans on different models (a plan whose low-bit fraction is
        fine on one architecture may breach the cap on another).
        """
        seen: set[str] = set()
        entries = ([(n, p, True) for n, p in self.plans]
                   + [(n, p, False) for n, p in self._generated()])
        for name, plan, is_seed in entries:
            if not self.admits(plan, sizes):
                continue
            for bb in self.bucket_bytes:
                cand = Candidate(name=f"{name}/{_bb_tag(int(bb))}",
                                 plan=plan, bucket_bytes=int(bb),
                                 seed=is_seed)
                sig = cand.signature()
                if sig in seen:
                    continue
                seen.add(sig)
                yield cand


# ---------------------------------------------------------------------------
# the default space (presets + the registered extension codecs)
# ---------------------------------------------------------------------------

def default_space(*, error_feedback: bool = False,
                  bucket_bytes: tuple = (8 * 2 ** 20, DEFAULT_BUCKET_BYTES),
                  constraints: tuple | None = None,
                  preset_names: tuple | None = None) -> SearchSpace:
    """The out-of-the-box space ``fabric.autotune`` searches.

    Seeds every :func:`~repro.fabric.control.plan_presets` entry
    (optionally filtered to ``preset_names``), adds a generated backbone
    axis over the low-bit built-ins and extension codecs, and crosses
    both with two bucket budgets (8 MiB / the paper's 32 MiB).  The
    default constraint set pins the classifier head to FP32 — the
    paper's non-negotiable guardrail — which also drops the
    ``lowbit_all`` style full-path presets from the space.
    """
    from ..fabric.control import plan_presets
    presets = plan_presets(error_feedback=error_feedback)
    if preset_names is not None:
        unknown = set(preset_names) - set(presets)
        if unknown:
            raise KeyError(f"unknown plan presets {sorted(unknown)}; "
                           f"available: {tuple(sorted(presets))}")
        presets = {n: presets[n] for n in preset_names}
    if constraints is None:
        constraints = (PinGroup("head"),)
    return SearchSpace(
        plans=tuple(sorted(presets.items())),
        codecs=("gbinary", "gternary", "int4", "topk"),
        schedules=(None,),
        error_feedback=(False, True) if error_feedback else (False,),
        group_axes=(("embed", ("fp32", "gbinary")),),
        bucket_bytes=tuple(int(b) for b in bucket_bytes),
        constraints=tuple(constraints))
