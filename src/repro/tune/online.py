"""Online re-ranking: the tuned shortlist as a runtime controller.

The offline tuner certifies its ranking against the simulator; the
``"tuned"`` controller closes the loop against reality.  It starts on
the :class:`~repro.tune.artifact.TunedPlan`'s winner and watches live
:class:`~repro.fabric.control.Telemetry` step times.  While the
observed EWMA stays within ``tolerance`` of the sim's prediction the
latch never moves — the offline decision stands.  When observations
breach the band for ``patience`` consecutive steps (the sim mispriced
this machine: different link rates, a noisy neighbor, a slow NIC), the
controller re-ranks the artifact's *sim-certified* entries by observed
time where it has observations and predicted time where it does not,
latches the new best, and emits a ``"retune"`` control event.

Only entries sharing the winner's ``bucket_bytes`` are eligible: the
bucket budget is a session/compile-time knob (it changes the layout the
jit cache is keyed on), not a per-step latch — switching it mid-run is
a recompile, which is the offline tuner's job, not a controller's.

Registered as ``"tuned"`` on ``repro.tune`` import, so
``fabric.attach_controller("tuned", tuned=artifact)`` works exactly
like attaching ``"paper"`` or ``"static"``.
"""
from __future__ import annotations

from typing import Mapping

from ..core.admission import ControlEvent
from ..core.buckets import AdmissionPlan
from ..fabric.control import Telemetry, register_controller
from .artifact import TunedPlan

__all__ = ["TunedPlanController"]


@register_controller("tuned")
class TunedPlanController:
    """Latch a TunedPlan's winner; re-rank its shortlist on live misses.

    ``tuned``     — a :class:`TunedPlan` or a path to a saved artifact.
    ``patience``  — consecutive out-of-band steps before a re-rank.
    ``tolerance`` — relative band around the predicted step time
                    (0.25 = switch only when >25% slower than the sim
                    said).
    ``alpha``     — EWMA smoothing for observed step times.
    """

    name = "tuned"
    wants_diagnostics = False

    def __init__(self, tuned: TunedPlan | str, *, patience: int = 5,
                 tolerance: float = 0.25, alpha: float = 0.3):
        if isinstance(tuned, str):
            tuned = TunedPlan.load(tuned)
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.tuned = tuned
        self.patience = int(patience)
        self.tolerance = float(tolerance)
        self.alpha = float(alpha)
        # eligible latch targets: the winner plus every sim-certified
        # runner-up at the same bucket budget, keyed by candidate name
        self._entries: dict[str, tuple[AdmissionPlan, float]] = {
            tuned.name: (tuned.plan, float(tuned.score.step_time_s))}
        for r in tuned.runners_up:
            if r.score is not None and r.bucket_bytes == tuned.bucket_bytes:
                self._entries.setdefault(
                    r.name, (r.plan, float(r.score.step_time_s)))
        self._active = tuned.name
        self._ewma: dict[str, float] = {}
        self._strikes = 0
        self.events: list[ControlEvent] = []

    # -- Controller surface ----------------------------------------------

    @property
    def plan(self) -> AdmissionPlan:
        return self._entries[self._active][0]

    @property
    def active(self) -> str:
        """Name of the currently latched shortlist entry."""
        return self._active

    def predicted(self, name: str | None = None) -> float:
        """The sim-predicted step time for an entry (default: active)."""
        return self._entries[name or self._active][1]

    def _expected(self, name: str) -> float:
        """Observed EWMA where we have one, sim prediction where not."""
        return self._ewma.get(name, self._entries[name][1])

    def observe(self, telemetry: Telemetry) -> AdmissionPlan:
        t = telemetry.step_time_s
        if t is None:
            return self.plan
        prev = self._ewma.get(self._active)
        self._ewma[self._active] = (
            float(t) if prev is None
            else self.alpha * float(t) + (1.0 - self.alpha) * prev)
        band = self._entries[self._active][1] * (1.0 + self.tolerance)
        if self._ewma[self._active] > band:
            self._strikes += 1
        else:
            self._strikes = 0
        if self._strikes >= self.patience:
            self._strikes = 0
            best = min(self._entries, key=lambda n: (self._expected(n), n))
            if best != self._active:
                self._active = best
                self.events.append(ControlEvent(
                    telemetry.step, "retune", self.plan.signature()))
        return self.plan

    # -- persistence -------------------------------------------------------

    def state_dict(self) -> dict:
        return {"tuned": self.tuned.to_jsonable(),
                "active": self._active,
                "ewma": dict(self._ewma),
                "strikes": self._strikes,
                "events": [[e.step, e.kind, e.plan_signature]
                           for e in self.events]}

    def load_state_dict(self, state: Mapping) -> None:
        self.tuned = TunedPlan.from_jsonable(state["tuned"])
        self._entries = {
            self.tuned.name: (self.tuned.plan,
                              float(self.tuned.score.step_time_s))}
        for r in self.tuned.runners_up:
            if (r.score is not None
                    and r.bucket_bytes == self.tuned.bucket_bytes):
                self._entries.setdefault(
                    r.name, (r.plan, float(r.score.step_time_s)))
        if state["active"] not in self._entries:
            raise ValueError(
                f"checkpointed active entry {state['active']!r} not in "
                f"this artifact's shortlist ({sorted(self._entries)})")
        self._active = state["active"]
        self._ewma = {k: float(v) for k, v in state["ewma"].items()}
        self._strikes = int(state["strikes"])
        self.events = [ControlEvent(int(s), k, sig)
                       for s, k, sig in state["events"]]
