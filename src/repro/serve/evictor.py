"""LRU eviction of cold KV blocks into a modeled CXL memory tier.

Preempted requests do not lose their KV state: their blocks turn *cold*
(registered with :class:`LRUEvictor`) and stay resident until the
allocator actually needs the space, at which point the least-recently-
used cold block spills — swap-style, whole blocks — into
:class:`CxlTier`, the modeled far-memory pool on the fabric device.
Resuming a request fetches its spilled blocks back.  The tier accounts
spill/fetch traffic at the KV codec's wire price (``kv_bytes``), which
is what :meth:`ServeEngine.simulate` replays through ``repro.sim``.
"""
from __future__ import annotations

import dataclasses
from typing import Any


class LRUEvictor:
    """Tracks evictable (cold) blocks ordered by last-use tick."""

    def __init__(self):
        self._cold: dict[int, int] = {}     # block_id -> last_use tick

    def add(self, block_id: int, tick: int) -> None:
        """Mark a block cold (evictable) as of ``tick``."""
        self._cold[block_id] = int(tick)

    def remove(self, block_id: int) -> None:
        """A cold block became hot again (its request resumed)."""
        self._cold.pop(block_id, None)

    def pop_lru(self):
        """Evict the least-recently-used cold block (None when empty)."""
        if not self._cold:
            return None
        bid = min(self._cold, key=lambda b: (self._cold[b], b))
        del self._cold[bid]
        return bid

    def __contains__(self, block_id: int) -> bool:
        return block_id in self._cold

    def __len__(self) -> int:
        return len(self._cold)


@dataclasses.dataclass
class CxlTier:
    """Modeled CXL far-memory pool holding spilled KV blocks.

    Blocks are stored verbatim (quantization already happened at cache
    write time, so spill/fetch round trips are lossless) but *priced* at
    the codec's wire cost: a spilled int4 block moves 8x fewer bytes
    across the CXL link than an fp32 one.
    """
    codec: Any                              # resolved Codec with kv_cache
    store: dict = dataclasses.field(default_factory=dict)
    spilled_bytes: float = 0.0
    fetched_bytes: float = 0.0
    spills: int = 0
    fetches: int = 0

    def spill(self, key, block) -> None:
        """Move one block out of the resident pool (copy — the pool slot
        is reused immediately after)."""
        self.store[key] = block.copy()
        self.spilled_bytes += self.codec.kv_bytes(block.size)
        self.spills += 1

    def fetch(self, key):
        """Bring a spilled block back; removes it from the tier."""
        block = self.store.pop(key)
        self.fetched_bytes += self.codec.kv_bytes(block.size)
        self.fetches += 1
        return block

    def drop(self, key) -> None:
        """Discard a spilled block (its request finished while out)."""
        self.store.pop(key, None)

    def __contains__(self, key) -> bool:
        return key in self.store

    def __len__(self) -> int:
        return len(self.store)
