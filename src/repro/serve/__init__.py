"""repro.serve — continuous-batching serving over a paged KV cache.

The serving-side subsystem of the repro: a block-paged, codec-quantized
KV cache (:mod:`cache`, :mod:`blocks`, :mod:`evictor`), a
continuous-batching scheduler with pluggable policies (:mod:`scheduler`)
and the :class:`ServeEngine` (:mod:`engine`) that drives the runtime's
``build_cached_prefill`` / ``build_serve_step`` over it, emitting a
per-step traffic timeline replayable through :mod:`repro.sim`.

Quick use::

    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, max_batch=4, num_blocks=64, block_size=16,
                      kv_codec="int4")
    outputs = eng.serve([{"prompt": [3, 5, 7], "max_new_tokens": 8},
                         {"prompt": [11, 2], "max_new_tokens": 8,
                          "arrival_step": 2}])
    report = eng.simulate(topology="cxl_switched")
"""
from .blocks import BlockAllocator, BlockStats, NoFreeBlocks
from .cache import PagedKVCache
from .engine import (PAGEABLE_FAMILIES, DecodeTimeline, ServeEngine,
                     StepRecord)
from .evictor import CxlTier, LRUEvictor
from .scheduler import (FcfsPolicy, Request, RequestState, Scheduler,
                        SjfPolicy, available_policies, get_policy,
                        register_policy, unregister_policy)

__all__ = [
    "BlockAllocator", "BlockStats", "NoFreeBlocks",
    "PagedKVCache",
    "PAGEABLE_FAMILIES", "DecodeTimeline", "ServeEngine", "StepRecord",
    "CxlTier", "LRUEvictor",
    "FcfsPolicy", "Request", "RequestState", "Scheduler", "SjfPolicy",
    "available_policies", "get_policy", "register_policy",
    "unregister_policy",
]
