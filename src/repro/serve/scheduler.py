"""Continuous-batching request scheduler: lifecycle, admission, preemption.

Requests move through ``waiting -> prefill -> decode -> finished``;
preemption sends a decoding request back to ``waiting`` (its KV blocks
turn cold, see :mod:`repro.serve.evictor`) and a later admission resumes
it where it left off.  *Which* request is admitted next and *which* one
is preempted under block pressure is a pluggable
:class:`SchedulingPolicy`, registered through the same generic
:class:`repro.core.registry.Registry` helper as the codec / schedule /
controller / topology seams:

    from repro.serve import register_policy

    @register_policy("my_policy")
    class MyPolicy:
        name = "my_policy"
        def admission_order(self, waiting): ...
        def preemption_victim(self, running): ...
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence

from ..core.registry import Registry


class RequestState(enum.Enum):
    WAITING = "waiting"      # queued (new, or preempted awaiting resume)
    PREFILL = "prefill"      # prompt KV being built this step
    DECODE = "decode"        # holds a batch slot, generating
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One serving request and its decode-side bookkeeping."""
    rid: int
    prompt: list
    max_new_tokens: int
    arrival_step: int = 0
    state: RequestState = RequestState.WAITING
    outputs: list = dataclasses.field(default_factory=list)
    tokens_in_cache: int = 0        # positions written to the paged cache
    pending_token: Optional[int] = None   # sampled, not yet fed
    slot: Optional[int] = None      # batch row while decoding
    preemptions: int = 0
    prefilled: bool = False

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.outputs)

    @property
    def done(self) -> bool:
        return len(self.outputs) >= self.max_new_tokens

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.outputs)


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------

def _prepare_policy(obj: Any, keys):
    return obj() if isinstance(obj, type) else obj


_POLICIES = Registry("serve policy", key_fn=str, prepare=_prepare_policy,
                     register_hint="@register_policy({key!r})")


def register_policy(name: str, *aliases: str, override: bool = False):
    """Class/instance decorator registering a scheduling policy."""
    return _POLICIES.register(name, *aliases, override=override)


def unregister_policy(name: str) -> None:
    _POLICIES.unregister(name)


def get_policy(name: Any):
    """Resolve a policy by registered name (or pass an instance through)."""
    if not isinstance(name, str):
        return name
    return _POLICIES.get(name)


def available_policies() -> tuple[str, ...]:
    return _POLICIES.available()


@register_policy("fcfs")
class FcfsPolicy:
    """First come, first served; under pressure the youngest request
    yields (its lost work is the cheapest to redo)."""

    name = "fcfs"

    def admission_order(self, waiting: Sequence[Request]) -> list[Request]:
        return sorted(waiting, key=lambda r: (r.arrival_step, r.rid))

    def preemption_victim(self, running: Sequence[Request]) -> Request:
        return max(running, key=lambda r: (r.arrival_step, r.rid))


@register_policy("sjf")
class SjfPolicy:
    """Shortest job first (by remaining token budget); the longest
    remaining job yields under pressure."""

    name = "sjf"

    def admission_order(self, waiting: Sequence[Request]) -> list[Request]:
        return sorted(waiting,
                      key=lambda r: (r.remaining, r.arrival_step, r.rid))

    def preemption_victim(self, running: Sequence[Request]) -> Request:
        return max(running, key=lambda r: (r.remaining, -r.arrival_step,
                                           -r.rid))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    """Tracks the waiting queue and the occupied batch slots."""

    def __init__(self, *, max_batch: int, policy: Any = "fcfs"):
        self.max_batch = int(max_batch)
        self.policy = get_policy(policy)
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        self._slots: list[Optional[Request]] = [None] * self.max_batch
        self.preemptions = 0

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def add(self, request: Request) -> None:
        request.state = RequestState.WAITING
        self.waiting.append(request)

    def admissible(self, now_step: int) -> list[Request]:
        """Waiting requests that have arrived, in policy order, capped
        at the number of free slots."""
        arrived = [r for r in self.waiting if r.arrival_step <= now_step]
        free = self.max_batch - len(self.running)
        return self.policy.admission_order(arrived)[:max(0, free)]

    def admit(self, request: Request) -> int:
        """Seat a waiting request in a free slot; returns the slot."""
        slot = self._slots.index(None)
        self._slots[slot] = request
        self.waiting.remove(request)
        self.running.append(request)
        request.slot = slot
        request.state = (RequestState.DECODE if request.prefilled
                         else RequestState.PREFILL)
        return slot

    def preempt(self, exclude: Optional[Request] = None) -> Optional[Request]:
        """Evict one running request back to the waiting queue.

        ``exclude`` protects the request whose allocation triggered the
        squeeze (preempting it would not free anything it can use this
        step) unless it is the only one running.
        """
        candidates = [r for r in self.running if r is not exclude]
        if not candidates:
            candidates = list(self.running)
        if not candidates:
            return None
        victim = self.policy.preemption_victim(candidates)
        self._release_slot(victim)
        victim.state = RequestState.WAITING
        victim.preemptions += 1
        self.waiting.append(victim)
        self.preemptions += 1
        return victim

    def finish(self, request: Request) -> None:
        self._release_slot(request)
        request.state = RequestState.FINISHED

    def _release_slot(self, request: Request) -> None:
        self._slots[request.slot] = None
        self.running.remove(request)
        request.slot = None
