"""Fixed-size KV block allocator: free list + reference counting.

The physical unit of the paged KV cache is a *block* — ``block_size``
token positions across every layer and both K/V planes.  The allocator
owns the block IDs only; the backing storage lives in
:class:`~repro.serve.cache.PagedKVCache`.  Reference counting makes
prefix sharing copy-on-write-free: forking a sequence increments the
refcount of its shared blocks instead of copying them, and a block
returns to the free list only when its last holder releases it.

Invariants (property-tested in ``tests/test_serve.py``):

  * a block ID is either on the free list or has ``ref_count >= 1`` —
    never both, never neither;
  * ``free`` on an unallocated block raises (no double-free);
  * refcounts never go negative.
"""
from __future__ import annotations

import dataclasses


class NoFreeBlocks(RuntimeError):
    """The pool is exhausted — caller must evict or preempt."""


@dataclasses.dataclass
class BlockStats:
    """Cumulative allocator counters (monotonic except ``peak_in_use``)."""
    allocations: int = 0
    releases: int = 0
    forks: int = 0
    peak_in_use: int = 0


class BlockAllocator:
    """LIFO free list over ``num_blocks`` block IDs with refcounts."""

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"need at least one block, got {num_blocks}")
        self.num_blocks = int(num_blocks)
        # LIFO so freshly freed (cache-warm) blocks are reused first
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._ref = [0] * self.num_blocks
        self.stats = BlockStats()

    # -- queries ----------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def ref_count(self, block_id: int) -> int:
        return self._ref[block_id]

    # -- lifecycle --------------------------------------------------------

    def allocate(self) -> int:
        """Take a free block (refcount 1) or raise :class:`NoFreeBlocks`."""
        if not self._free:
            raise NoFreeBlocks(
                f"all {self.num_blocks} KV blocks are in use")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.stats.allocations += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.num_in_use)
        return bid

    def fork(self, block_id: int) -> int:
        """Share a block (copy-on-write-free): one more holder, no copy."""
        if self._ref[block_id] < 1:
            raise ValueError(f"cannot fork unallocated block {block_id}")
        self._ref[block_id] += 1
        self.stats.forks += 1
        return block_id

    def free(self, block_id: int) -> bool:
        """Drop one holder; returns True when the block was released.

        Raises on a block that has no holders (double-free guard).
        """
        if not 0 <= block_id < self.num_blocks:
            raise ValueError(f"block {block_id} out of range "
                             f"[0, {self.num_blocks})")
        if self._ref[block_id] < 1:
            raise ValueError(f"double free of block {block_id}")
        self._ref[block_id] -= 1
        if self._ref[block_id] == 0:
            self._free.append(block_id)
            self.stats.releases += 1
            return True
        return False

    def __repr__(self) -> str:
        return (f"BlockAllocator(num_blocks={self.num_blocks}, "
                f"in_use={self.num_in_use})")
