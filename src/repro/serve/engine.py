"""ServeEngine: continuous-batching decode over the paged KV cache.

One engine step = (policy-ordered admission + prefill of newly seated
requests) followed by a single *batched* decode launch in which every
active request advances one token at its own depth — the vector-position
path of :func:`repro.models.decode_step`.  Between the logical block
tables and the dense cache the jitted step consumes, the engine
gathers/scatters through the KV codec (:mod:`repro.serve.cache`), so
every step's fabric traffic (gather + scatter + spill/fetch of preempted
state) is codec-priced and recorded in a :class:`StepRecord`.

Determinism contract (asserted in ``tests/test_serve.py``): with the
lossless ``fp32`` KV codec, each request's logits are bit-identical to
running it alone through the same jitted step — continuous batching,
paging, preemption and CXL spill round-trips are all invisible to the
numerics.  The per-step records replay through :mod:`repro.sim` via
:meth:`ServeEngine.simulate`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig, init_cache, init_params
from ..runtime.serve import build_cached_prefill, build_serve_step
from ..sim.trace import simulate_launches, timeline_launch_specs
from .blocks import NoFreeBlocks
from .cache import PagedKVCache
from .scheduler import Request, RequestState, Scheduler

#: families whose decode state is a sequence-indexed KV cache the block
#: pager can address; SSM/hybrid state and encoder cross-caches are not
#: token-paged.
PAGEABLE_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """Traffic and scheduling facts of one engine step."""
    step: int
    active: tuple               # rids that decoded this step
    admitted: tuple
    preempted: tuple
    finished: tuple
    new_tokens: int             # tokens sampled (prefill + decode)
    n_elements: int             # KV elements gathered + scattered
    wire_bytes: float           # codec-priced gather+scatter+spill+fetch
    blocks_in_use: int
    utilization: float          # of the block pool, after this step

    def to_jsonable(self) -> dict:
        d = dataclasses.asdict(self)
        for key in ("active", "admitted", "preempted", "finished"):
            d[key] = list(d[key])
        return d


@dataclasses.dataclass(frozen=True)
class DecodeTimeline:
    """The engine's step history, replayable through ``repro.sim``."""
    steps: tuple                # tuple[StepRecord]
    kv_codec: str

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def total_new_tokens(self) -> int:
        return sum(s.new_tokens for s in self.steps)

    @property
    def total_wire_bytes(self) -> float:
        return sum(s.wire_bytes for s in self.steps)

    @property
    def total_preemptions(self) -> int:
        return sum(len(s.preempted) for s in self.steps)

    def launch_specs(self, *, step_compute_s: float = 0.0,
                     schedule: str = "paged_kv"):
        """One fabric launch per step (KV movement of that step)."""
        return timeline_launch_specs(
            [{"name": f"decode:{s.step}", "n_elements": s.n_elements,
              "wire_bytes": s.wire_bytes, "ready_s": s.step * step_compute_s}
             for s in self.steps],
            mode=self.kv_codec, schedule=schedule)

    def to_jsonable(self) -> dict:
        return {"kv_codec": self.kv_codec,
                "num_steps": self.num_steps,
                "total_new_tokens": self.total_new_tokens,
                "total_wire_bytes": self.total_wire_bytes,
                "total_preemptions": self.total_preemptions,
                "steps": [s.to_jsonable() for s in self.steps]}


class ServeEngine:
    """Continuous-batching serving engine over a paged, codec-priced
    KV cache.

    ``max_batch`` fixes the decode width (one compile); requests are
    seated into its slots as they arrive and leave as they finish, so
    the batch composition changes every step.  ``num_blocks`` x
    ``block_size`` bounds resident KV; running out triggers LRU spill of
    preempted (cold) state to the modeled CXL tier, then preemption.
    """

    def __init__(self, cfg: ModelConfig, params=None, *, max_batch: int = 4,
                 max_seq: int = 128, num_blocks: int = 64,
                 block_size: int = 16, kv_codec: str = "fp32",
                 policy: Any = "fcfs", cache_dtype=np.float32,
                 seed: int = 0, collect_logits: bool = False):
        if cfg.family not in PAGEABLE_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} is not servable with a paged KV "
                f"cache (supported: {', '.join(PAGEABLE_FAMILIES)}); "
                f"SSM/hybrid recurrent state and encoder cross-caches are "
                f"not token-paged")
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(seed), cfg)
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        self.cache_dtype = np.dtype(cache_dtype)
        self.cache = PagedKVCache(cfg, num_blocks=num_blocks,
                                  block_size=block_size, kv_codec=kv_codec,
                                  dtype=self.cache_dtype)
        self.scheduler = Scheduler(max_batch=max_batch, policy=policy)
        self._prefill = build_cached_prefill(cfg, donate=False)
        self._step, _ = build_serve_step(cfg, batch=self.max_batch,
                                         max_seq=self.max_seq, donate=False)
        self.requests: dict[int, Request] = {}
        self.records: list[StepRecord] = []
        self.step_index = 0
        self.collect_logits = collect_logits
        self.logits: dict[int, list] = {}
        self._next_rid = 0
        self._tick = 0

    # -- submission -------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival_step: int = 0) -> int:
        """Queue a request; returns its rid."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + int(max_new_tokens) > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + budget ({max_new_tokens}) "
                f"exceeds max_seq={self.max_seq}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens),
                      arrival_step=int(arrival_step))
        self.requests[rid] = req
        self.scheduler.add(req)
        return rid

    # -- one engine step --------------------------------------------------

    def step(self) -> StepRecord:
        """Admit, prefill, batched-decode one token, scatter, sample."""
        now = self.step_index
        admitted: list[int] = []
        preempted: list[int] = []
        finished: list[int] = []
        new_tokens = 0
        base_elems = (self.cache.gathered_elements
                      + self.cache.scattered_elements)
        base_bytes = (self.cache.gathered_bytes + self.cache.scattered_bytes
                      + self.cache.tier.spilled_bytes
                      + self.cache.tier.fetched_bytes)

        # 1. admission (+ prefill of never-seen prompts)
        for req in self.scheduler.admissible(now):
            if not self._admit(req):
                break                     # no room this step; keep order
            admitted.append(req.rid)
            if not req.prefilled:
                self._run_prefill(req)
                new_tokens += 1
            if req.done:                  # budget met at prefill already
                self._finish(req)
                finished.append(req.rid)

        # 2. grow tables for this step's writes; preempt under pressure
        for req in list(self.scheduler.running):
            while req.slot is not None:
                try:
                    self.cache.ensure_capacity(req.rid,
                                               req.tokens_in_cache + 1)
                    break
                except NoFreeBlocks:
                    victim = self.scheduler.preempt(exclude=req)
                    if victim is None:
                        raise RuntimeError(
                            "KV pool too small for a single request")
                    self.cache.deactivate(victim.rid, self._next_tick())
                    preempted.append(victim.rid)

        # 3. one batched decode launch over every seated request
        active = sorted(self.scheduler.running, key=lambda r: r.slot)
        if active:
            logits = self._decode(active)
            for i, req in enumerate(active):
                pos = req.tokens_in_cache
                req.tokens_in_cache = pos + 1
                row = np.asarray(logits[req.slot])
                if self.collect_logits:
                    self.logits[req.rid].append(row)
                nxt = int(np.argmax(row))
                req.outputs.append(nxt)
                req.pending_token = nxt
                new_tokens += 1
                if req.done or req.total_len >= self.max_seq:
                    self._finish(req)
                    finished.append(req.rid)

        rec = StepRecord(
            step=now,
            active=tuple(r.rid for r in active),
            admitted=tuple(admitted), preempted=tuple(preempted),
            finished=tuple(finished), new_tokens=new_tokens,
            n_elements=(self.cache.gathered_elements
                        + self.cache.scattered_elements - base_elems),
            wire_bytes=(self.cache.gathered_bytes
                        + self.cache.scattered_bytes
                        + self.cache.tier.spilled_bytes
                        + self.cache.tier.fetched_bytes - base_bytes),
            blocks_in_use=self.cache.blocks_in_use,
            utilization=self.cache.utilization())
        self.records.append(rec)
        self.step_index += 1
        return rec

    # -- driving loops ----------------------------------------------------

    def run(self, max_steps: int = 10_000) -> DecodeTimeline:
        """Step until every submitted request finishes."""
        while any(r.state is not RequestState.FINISHED
                  for r in self.requests.values()):
            if self.step_index >= max_steps:
                raise RuntimeError(f"serving did not drain in "
                                   f"{max_steps} steps")
            self.step()
        return self.timeline()

    def serve(self, trace: Sequence[Any],
              max_steps: int = 10_000) -> dict[int, list[int]]:
        """Submit a whole request trace, run it dry, return outputs.

        ``trace`` entries are mappings with ``prompt`` /
        ``max_new_tokens`` / optional ``arrival_step``.
        """
        rids = [self.submit(e["prompt"], e["max_new_tokens"],
                            e.get("arrival_step", 0)) for e in map(dict, trace)]
        self.run(max_steps=max_steps)
        return {rid: list(self.requests[rid].outputs) for rid in rids}

    def timeline(self) -> DecodeTimeline:
        return DecodeTimeline(steps=tuple(self.records),
                              kv_codec=self.cache.codec.name)

    def simulate(self, timeline: Optional[DecodeTimeline] = None, *,
                 topology: Any = "cxl_direct", step_compute_s: float = 1e-3,
                 num_workers: int = 1, **topology_kwargs):
        """Replay the decode timeline's KV traffic through ``repro.sim``.

        Each engine step becomes one launch carrying that step's
        codec-priced gather/scatter/spill bytes, ready when the model
        forward of the step finishes (``step * step_compute_s``); the
        returned :class:`~repro.sim.SimReport` exposes queueing and
        exposure of the serving datapath on the chosen topology.
        """
        tl = timeline if timeline is not None else self.timeline()
        specs = tl.launch_specs(step_compute_s=step_compute_s)
        return simulate_launches(
            specs, num_workers, topology=topology, datapath=None,
            compute_time_s=tl.num_steps * step_compute_s, **topology_kwargs)

    # -- internals --------------------------------------------------------

    def _next_tick(self) -> int:
        self._tick += 1
        return self._tick

    def _admit(self, req: Request) -> bool:
        """Seat one waiting request; False when it cannot fit right now.

        Admission never preempts (that privilege belongs to requests
        already decoding) but it may spill *cold* blocks via the
        allocator's eviction path.
        """
        self.scheduler.admit(req)
        rid = req.rid
        if rid not in self.cache:
            self.cache.add_request(rid)
            if self.collect_logits:
                self.logits[rid] = []
        try:
            if req.prefilled:
                if not self.cache.activate(rid, self._next_tick()):
                    raise NoFreeBlocks(f"cannot resume request {rid}")
            else:
                self.cache.ensure_capacity(rid, len(req.prompt))
        except NoFreeBlocks:
            self._bounce(req)
            return False
        req.state = RequestState.DECODE
        return True

    def _bounce(self, req: Request) -> None:
        """Undo a failed admission: back to waiting, blocks cold."""
        self.scheduler._release_slot(req)
        req.state = RequestState.WAITING
        self.scheduler.waiting.append(req)
        self.cache.deactivate(req.rid, self._next_tick())

    def _run_prefill(self, req: Request) -> None:
        """Fill the prompt KV pages and sample the first token."""
        plen = len(req.prompt)
        tokens = np.zeros((1, self.max_seq), np.int32)
        tokens[0, :plen] = req.prompt
        cache0 = init_cache(self.cfg, 1, self.max_seq,
                            dtype=self.cache_dtype)
        logits, filled = self._prefill(self.params, jnp.asarray(tokens),
                                       jnp.int32(plen), cache0)
        self.cache.write_prompt(
            req.rid,
            np.asarray(filled["k"][:, 0, :plen]),
            np.asarray(filled["v"][:, 0, :plen]))
        row = np.asarray(logits[0])
        if self.collect_logits:
            self.logits[req.rid].append(row)
        first = int(np.argmax(row))
        req.outputs.append(first)
        req.pending_token = first
        req.tokens_in_cache = plen
        req.prefilled = True

    def _decode(self, active: Sequence[Request]):
        """Gather pages -> one vector-position decode -> scatter back."""
        cfg = self.cfg
        shape = (cfg.num_layers, self.max_batch, self.max_seq,
                 cfg.num_kv_heads, cfg.hd)
        dense_k = np.zeros(shape, self.cache_dtype)
        dense_v = np.zeros(shape, self.cache_dtype)
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch,), np.int32)
        for req in active:
            self.cache.gather_into(req.rid, dense_k[:, req.slot],
                                   dense_v[:, req.slot])
            tokens[req.slot, 0] = req.pending_token
            positions[req.slot] = req.tokens_in_cache
        logits, new_cache = self._step(
            self.params, jnp.asarray(tokens),
            {"k": jnp.asarray(dense_k), "v": jnp.asarray(dense_v)},
            jnp.asarray(positions))
        new_k = np.asarray(new_cache["k"])
        new_v = np.asarray(new_cache["v"])
        for req in active:
            pos = req.tokens_in_cache
            self.cache.write_token(req.rid, pos,
                                   new_k[:, req.slot, pos],
                                   new_v[:, req.slot, pos])
        return logits

    def _finish(self, req: Request) -> None:
        self.scheduler.finish(req)
        self.cache.release(req.rid)
