"""Paged KV cache: logical block tables over a physical block pool.

Storage model (vLLM-style paging, host-resident for the model of record):

  * the *pool* is one array of ``num_blocks`` physical blocks, each
    holding ``block_size`` token positions for every layer and both the
    K and V planes — shape ``(num_blocks, 2, L, block_size, KV, hd)``;
  * each request owns a *block table*: logical block index -> physical
    block ID (or None while that block is spilled to the CXL tier);
  * :class:`~repro.serve.blocks.BlockAllocator` hands out IDs;
    :class:`~repro.serve.evictor.LRUEvictor` +
    :class:`~repro.serve.evictor.CxlTier` give preempted requests a
    place to keep state without holding the pool.

Every write passes through the KV codec's ``kv_encode`` (any registered
codec with ``kv_cache = True`` — the PR-5 registry's serving
capability), so the pool holds exactly the values a quantized cache
decodes to, and gather/scatter/spill traffic is priced at the codec's
``kv_bytes`` wire cost.  Quantization granularity is the written
fragment: one block-aligned chunk during prefill, one token slice
during decode.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..fabric.codecs import get_codec
from .blocks import BlockAllocator, NoFreeBlocks
from .evictor import CxlTier, LRUEvictor


class PagedKVCache:
    """Block-paged KV storage for attention decoder models."""

    def __init__(self, cfg, *, num_blocks: int, block_size: int,
                 kv_codec: str = "fp32", dtype=np.float32):
        codec = get_codec(kv_codec)
        if not getattr(codec, "kv_cache", False):
            raise ValueError(
                f"codec {codec.name!r} does not support KV-cache payloads "
                f"(kv_cache=False); its alphabet cannot carry cache values")
        self.cfg = cfg
        self.codec = codec
        self.block_size = int(block_size)
        self.allocator = BlockAllocator(num_blocks)
        self.evictor = LRUEvictor()
        self.tier = CxlTier(codec)
        shape = (num_blocks, 2, cfg.num_layers, self.block_size,
                 cfg.num_kv_heads, cfg.hd)
        self.pool = np.zeros(shape, dtype)
        self.block_elements = int(np.prod(shape[1:]))
        self._tables: dict[int, list[Optional[int]]] = {}
        self._lengths: dict[int, int] = {}
        self._owner: dict[int, tuple[int, int]] = {}   # bid -> (rid, idx)
        # cumulative traffic counters (the simulate() seam)
        self.gathered_elements = 0
        self.scattered_elements = 0
        self.gathered_bytes = 0.0
        self.scattered_bytes = 0.0

    # -- request lifecycle ------------------------------------------------

    def add_request(self, rid: int) -> None:
        if rid in self._tables:
            raise ValueError(f"request {rid} already has a block table")
        self._tables[rid] = []
        self._lengths[rid] = 0

    def release(self, rid: int) -> None:
        """Free every block (resident or spilled) a request holds."""
        for idx, bid in enumerate(self._tables.pop(rid)):
            if bid is None:
                self.tier.drop((rid, idx))
            else:
                self.evictor.remove(bid)
                del self._owner[bid]
                self.allocator.free(bid)
        del self._lengths[rid]

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    def __contains__(self, rid: int) -> bool:
        return rid in self._tables

    # -- allocation / eviction --------------------------------------------

    def _take_block(self) -> int:
        """Allocate a block, spilling the LRU cold block if needed."""
        try:
            return self.allocator.allocate()
        except NoFreeBlocks:
            victim = self.evictor.pop_lru()
            if victim is None:
                raise
            vrid, vidx = self._owner.pop(victim)
            self.tier.spill((vrid, vidx), self.pool[victim])
            self._tables[vrid][vidx] = None
            self.allocator.free(victim)
            return self.allocator.allocate()

    def ensure_capacity(self, rid: int, n_tokens: int) -> None:
        """Grow the request's table to cover ``n_tokens`` positions.

        Raises :class:`NoFreeBlocks` when the pool is exhausted and no
        cold block can be spilled — the scheduler's cue to preempt.
        """
        table = self._tables[rid]
        needed = -(-int(n_tokens) // self.block_size)      # ceil div
        while len(table) < needed:
            bid = self._take_block()
            self._owner[bid] = (rid, len(table))
            table.append(bid)

    def deactivate(self, rid: int, tick: int) -> None:
        """Preemption: mark the request's resident blocks cold (LRU-
        evictable) as of ``tick``; nothing moves until space is needed."""
        for bid in self._tables[rid]:
            if bid is not None:
                self.evictor.add(bid, tick)

    def activate(self, rid: int, tick: int) -> bool:
        """Resume: re-pin resident blocks, fetch spilled ones back.

        Returns False (leaving the request deactivated) when the pool
        cannot hold the working set right now.
        """
        table = self._tables[rid]
        for bid in table:
            if bid is not None:
                self.evictor.remove(bid)
        for idx, bid in enumerate(table):
            if bid is None:
                try:
                    new = self._take_block()
                except NoFreeBlocks:
                    self.deactivate(rid, tick)
                    return False
                self.pool[new] = self.tier.fetch((rid, idx))
                self._owner[new] = (rid, idx)
                table[idx] = new
        return True

    # -- data plane -------------------------------------------------------

    def _block(self, rid: int, idx: int) -> int:
        bid = self._tables[rid][idx]
        if bid is None:
            raise RuntimeError(
                f"request {rid} block {idx} is spilled; activate() first")
        return bid

    def write_prompt(self, rid: int, k, v) -> None:
        """Scatter prefill KV.  k/v: (L, P, KV, hd) host arrays."""
        k = np.asarray(k)
        v = np.asarray(v)
        p = k.shape[1]
        self.ensure_capacity(rid, p)
        bs = self.block_size
        for idx in range(-(-p // bs)):
            lo, hi = idx * bs, min((idx + 1) * bs, p)
            bid = self._block(rid, idx)
            self.pool[bid, 0, :, :hi - lo] = self.codec.kv_encode(
                k[:, lo:hi])
            self.pool[bid, 1, :, :hi - lo] = self.codec.kv_encode(
                v[:, lo:hi])
            self._count_scatter(2 * k[:, lo:hi].size)
        self._lengths[rid] = max(self._lengths[rid], p)

    def write_token(self, rid: int, pos: int, k, v) -> None:
        """Scatter one decoded token's KV.  k/v: (L, KV, hd)."""
        idx, off = divmod(int(pos), self.block_size)
        bid = self._block(rid, idx)
        self.pool[bid, 0, :, off] = self.codec.kv_encode(np.asarray(k))
        self.pool[bid, 1, :, off] = self.codec.kv_encode(np.asarray(v))
        self._count_scatter(2 * int(np.asarray(k).size))
        self._lengths[rid] = max(self._lengths[rid], int(pos) + 1)

    def gather_into(self, rid: int, out_k, out_v) -> int:
        """Densify a request's pages into (L, S_max, KV, hd) buffers.

        Returns the number of valid token positions copied; positions
        beyond it are left untouched (the decode mask hides them).
        """
        n = self._lengths[rid]
        bs = self.block_size
        for idx in range(-(-n // bs)):
            lo, hi = idx * bs, min((idx + 1) * bs, n)
            bid = self._block(rid, idx)
            out_k[:, lo:hi] = self.codec.kv_decode(
                self.pool[bid, 0, :, :hi - lo])
            out_v[:, lo:hi] = self.codec.kv_decode(
                self.pool[bid, 1, :, :hi - lo])
            self._count_gather(2 * out_k[:, lo:hi].size)
        return n

    def _count_gather(self, elements: int) -> None:
        self.gathered_elements += elements
        self.gathered_bytes += self.codec.kv_bytes(elements)

    def _count_scatter(self, elements: int) -> None:
        self.scattered_elements += elements
        self.scattered_bytes += self.codec.kv_bytes(elements)

    # -- reporting --------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.num_in_use

    def utilization(self) -> float:
        """Fraction of pool blocks currently allocated."""
        return self.allocator.num_in_use / self.allocator.num_blocks

    def resident_bytes(self) -> float:
        """Codec-priced bytes of all resident (in-use) blocks."""
        return self.allocator.num_in_use * self.codec.kv_bytes(
            self.block_elements)
