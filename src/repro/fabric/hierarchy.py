"""Hop plans: hierarchical, per-hop-recompressing collective routes.

Everything below the controller used to assume a single flat hop: one
codec, one transport, one worker group.  A :class:`HopPlan` makes the
*route* first-class — an ordered tuple of :class:`HopSpec` legs, each
naming a codec from the registry, a worker-group size, and (optionally)
a transport — so the paper's traffic win survives an oversubscribed
inter-node fabric the way DynamiQ (PAPERS.md) does: re-compress at
every hop instead of end-to-end.  The canonical shape is intra-node
FP32 ``psum`` followed by an inter-node low-bit vote::

    plan  = HopPlan("hier_fp32_gbinary",
                    (HopSpec("fp32", workers=8),    # hop 0: intra-node
                     HopSpec("gbinary")))           # hop 1: the rest
    codec = register_hop_plan(plan)

Registration puts a :class:`HierarchicalCodec` carrying the plan into
the **codec registry** under the plan's name, so a hierarchical route
is addressed exactly like any other representation — ``GroupPolicy(
mode="hier_fp32_gbinary")``, ``AdmissionPlan.lowbit_backbone(
"hier_fp32_gbinary")``, a :func:`~repro.fabric.control.plan_presets`
entry, or a ``Commander(binary_mode="hier_fp32_gbinary")`` admission
ladder — with zero changes to the policy schema.  The codec's
``default_schedule`` is the ``hierarchical`` backend
(:mod:`repro.fabric.backends`), which composes the per-hop
encode -> reduce -> decode chain by dispatching each leg to that hop
codec's own registered transport.

Worker groups and axes
----------------------
``HopSpec.workers`` is the hop's group size: a fixed count (clamped to
the session's worker total when smaller, so an 8-wide intra-node hop
degrades gracefully on a 4-worker test mesh) or ``None`` for "the
remaining workers" (at most one hop per plan).  Hop 0 is the
*innermost* group: on a session with one data-parallel axis per hop
(``Fabric(dp_axes=("outer", "inner"))`` for a 2-hop plan), hop 0 runs
over the last axis and hop ``h`` over the axis ``h`` from the end; a
1-hop plan runs over all axes at once and is bit-identical to the flat
backend of its single codec.

Accounting
----------
``bits_per_element`` (hence the paper-style payload ratio) counts the
*backbone* — the last hop's representation, the bits that cross the
scarce inter-node links; per-leg wire bytes come from
:func:`repro.core.traffic.hop_wire_bytes_per_device`, which sums each
hop backend's own ring model at that hop's group size.
"""
from __future__ import annotations

import dataclasses

from .codecs import GradientCodec, get_codec, register_codec, \
    unregister_codec

__all__ = [
    "HierarchicalCodec", "HopPlan", "HopSpec", "INTRA_NODE_WORKERS",
    "register_hop_plan", "unregister_hop_plan",
]

#: default intra-node group size for the built-in plans (one v5e-like
#: host's worth of chips); clamped to the session's worker count.
INTRA_NODE_WORKERS = 8


@dataclasses.dataclass(frozen=True)
class HopSpec:
    """One leg of a hierarchical route.

    ``codec`` names the hop's gradient representation (codec-registry
    key); ``workers`` is the hop's group size — a fixed count or None
    for "the remaining workers"; ``schedule`` optionally pins the hop's
    transport (default: the hop codec's ``default_schedule``, with the
    usual :func:`~repro.core.modes.wire_schedule` normalization).
    """
    codec: str
    workers: int | None = None
    schedule: str | None = None

    def __post_init__(self):
        if self.workers is not None and int(self.workers) < 1:
            raise ValueError(
                f"hop group size must be >= 1, got {self.workers}")


@dataclasses.dataclass(frozen=True)
class HopPlan:
    """An ordered route of hops; hop 0 is the innermost worker group."""
    name: str
    hops: tuple

    def __post_init__(self):
        object.__setattr__(self, "hops", tuple(self.hops))
        if not self.hops:
            raise ValueError(f"hop plan {self.name!r} needs at least one hop")
        if sum(1 for h in self.hops if h.workers is None) > 1:
            raise ValueError(
                f"hop plan {self.name!r} has more than one remainder hop "
                f"(workers=None); at most one hop may absorb the leftover "
                f"workers")

    def signature(self) -> str:
        """Stable route identity (folded into the bucket fusion key)."""
        legs = ">".join(
            f"{h.codec}:{'*' if h.workers is None else int(h.workers)}"
            + (f"@{h.schedule}" if h.schedule else "")
            for h in self.hops)
        return f"{self.name}[{legs}]"

    def group_sizes(self, num_workers: int) -> tuple:
        """Per-hop worker-group sizes for a ``num_workers`` session.

        Fixed hops are clamped to the workers still unassigned (so the
        built-in 8-wide intra-node hop runs as 4-wide on a 4-worker test
        mesh) and must divide them; the remainder hop absorbs whatever
        is left.  The product of the returned sizes always equals
        ``max(1, num_workers)``.
        """
        remaining = max(1, int(num_workers))
        sizes: list = [None] * len(self.hops)
        rem_idx = None
        for i, hop in enumerate(self.hops):
            if hop.workers is None:
                rem_idx = i
                continue
            s = min(int(hop.workers), remaining)
            if remaining % s:
                raise ValueError(
                    f"hop plan {self.name!r}: hop {i} group size {s} does "
                    f"not divide the {remaining} unassigned workers "
                    f"(session has {num_workers})")
            sizes[i] = s
            remaining //= s
        if rem_idx is not None:
            sizes[rem_idx] = remaining
            remaining = 1
        if remaining != 1:
            raise ValueError(
                f"hop plan {self.name!r} covers only "
                f"{max(1, int(num_workers)) // remaining} of {num_workers} "
                f"workers; add a remainder hop (workers=None) or size the "
                f"fixed hops to the session")
        return tuple(sizes)


class HierarchicalCodec(GradientCodec):
    """A registered codec carrying a :class:`HopPlan`.

    ``reduction = "hierarchical"`` routes every built-in flat schedule
    to the ``hierarchical`` backend (see
    :func:`~repro.core.modes.wire_schedule`); the remaining contract
    attributes delegate to the hop codecs — ``bits_per_element`` and the
    sim ``lane`` to the *backbone* (last) hop, ``gated``/``threads_ef``
    to any hop declaring them, the bucket zero gate to the first gated
    hop.  ``hop_signature`` is folded into
    :class:`~repro.core.buckets.BucketKey` so buckets never mix routes.
    """

    reduction = "hierarchical"
    default_schedule = "hierarchical"
    kv_cache = False

    def __init__(self, plan: HopPlan):
        self.plan = plan
        self.name = plan.name
        self.hop_signature = plan.signature()
        hop_codecs = [get_codec(h.codec) for h in plan.hops]
        for c in hop_codecs:
            if getattr(c, "reduction", "") == "hierarchical":
                raise ValueError(
                    f"hop plan {plan.name!r}: hop codec {c.name!r} is "
                    f"itself hierarchical — hop plans do not nest")
        backbone = hop_codecs[-1]
        self.bits_per_element = backbone.bits_per_element
        self.lane = backbone.lane
        self.gated = any(c.gated for c in hop_codecs)
        self.threads_ef = any(c.threads_ef for c in hop_codecs)

    def bucket_gate(self, bucket):
        """Delegate the fused zero gate to the first gated hop codec."""
        for hop in self.plan.hops:
            c = get_codec(hop.codec)
            if c.gated:
                return c.bucket_gate(bucket)
        return None

    # -- fused kernels: resolved per hop, not for the route -------------
    def pallas_kernels(self):
        """None: a multi-hop route has no single kernel set — each hop
        leg resolves its own codec's kernels inside the hierarchical
        backend (the hop context preserves ``fused_kernels``)."""
        return None

    def kernel_signature(self) -> str | None:
        """Composed per-hop kernel signatures for the step-cache key.

        ``None`` when no hop brings kernels; otherwise one string over
        the route so swapping any hop codec's kernel set invalidates
        compiled steps exactly like a flat codec swap would.
        """
        sigs = []
        for hop in self.plan.hops:
            c = get_codec(hop.codec)
            hook = getattr(c, "kernel_signature", None)
            sigs.append(hook() if hook is not None else None)
        if not any(s is not None for s in sigs):
            return None
        return ">".join("-" if s is None else s for s in sigs)


def register_hop_plan(plan: HopPlan, *aliases: str,
                      override: bool = False) -> HierarchicalCodec:
    """Build a :class:`HierarchicalCodec` for ``plan`` and register it
    in the codec registry under ``plan.name`` (+ ``aliases``).

    The returned codec is what plans, presets, buckets, the traffic
    model, and the simulator resolve by name; tear toys down with
    :func:`unregister_hop_plan`.
    """
    codec = HierarchicalCodec(plan)
    register_codec(plan.name, *aliases, override=override)(codec)
    return codec


def unregister_hop_plan(name: str) -> None:
    """Remove a registered hop-plan codec and its aliases."""
    unregister_codec(name)


# ---------------------------------------------------------------------------
# built-in hop plans (intra-node FP32 psum -> inter-node low-bit)
# ---------------------------------------------------------------------------

register_hop_plan(HopPlan("hier_fp32_gbinary", (
    HopSpec("fp32", workers=INTRA_NODE_WORKERS),
    HopSpec("gbinary"))))

register_hop_plan(HopPlan("hier_fp32_gternary", (
    HopSpec("fp32", workers=INTRA_NODE_WORKERS),
    HopSpec("gternary"))))

register_hop_plan(HopPlan("hier_fp32_int4", (
    HopSpec("fp32", workers=INTRA_NODE_WORKERS),
    HopSpec("int4"))))
