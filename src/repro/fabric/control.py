"""Controller registry + phase-program API for admission control.

The paper's headline accuracy mechanism is its *control interface*:
warm-up on FP32, layer-aware admission to G-Binary/G-Ternary, guarded
recovery, re-admission (Sections 3 and 8).  This module makes that
control plane a first-class, pluggable subsystem — the policy analogue
of the schedule-backend registry in :mod:`repro.fabric.registry`:

  * :class:`Telemetry` — the typed per-step record controllers observe
    (step, loss, per-group cosines, traffic ratio, step wall-time,
    restart flag).  One schema, emitted once per step by the Trainer
    from the Fabric-compiled step's metrics — no more scraping
    ``metrics["cos/{g}/gbinary"]`` by string key at call sites.
  * :class:`Controller` protocol + ``@register_controller`` — policies
    register under a string name and are constructed by
    :func:`make_controller`; the Predictor/Commander/Supervisor ladder
    ships as the built-in ``"paper"`` controller (alias ``"adaptive"``),
    with trivial ``"static"`` and ``"fp32"`` controllers alongside it.
  * :class:`PolicyProgram` — a declarative phase machine (warm-up ->
    calibrate -> admit -> guarded-recovery -> re-admit, plus
    user-defined stages such as "head on FP32 after step N") that owns
    the mode latch and the control-event log.
  * ``state_dict() / load_state_dict()`` on controllers, threaded
    through :class:`repro.checkpoint.CheckpointManager`, so CUSUM
    statistics, cooldown, and the admitted plan survive failure
    recovery instead of resetting to warm-up.

Controllers only ever *read* telemetry and *write* mode metadata (an
:class:`~repro.core.buckets.AdmissionPlan`) — mirroring the paper's
"the control plane writes only mode metadata; it does not inspect
gradient payloads".  Attach one to a session with
``fabric.attach_controller("paper", warmup_steps=50)`` so the
plan-signature jit cache and the mode latch live in one object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, Sequence, \
    runtime_checkable

from ..core.admission import (Commander, ControlEvent, CusumGuard, Predictor,
                              Supervisor)
from ..core.buckets import AdmissionPlan, GroupPolicy
from ..core.modes import (AggregationMode, Schedule, canonical_mode,
                          codec_name, schedule_name)
from ..core.registry import Registry

__all__ = [
    "Controller", "ControlEvent", "FP32Controller", "PaperController",
    "Phase", "PolicyProgram", "StaticController", "Telemetry",
    "available_controllers", "get_controller", "make_controller",
    "plan_from_jsonable", "plan_presets", "plan_to_jsonable",
    "register_controller", "register_plan_preset",
    "unregister_controller", "unregister_plan_preset",
]


# ---------------------------------------------------------------------------
# Telemetry: the typed per-step record controllers observe
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Telemetry:
    """One step of training-runtime telemetry, as the controller sees it.

    ``cosines`` is ``group -> {"gbinary": cos, "gternary": cos}`` when the
    step was compiled with diagnostics (calibration), else None.  The
    record is the *only* channel between the runtime and a controller —
    controllers never see gradients, weights, or the metrics dict.
    """
    step: int
    loss: float
    cosines: Mapping[str, Mapping[str, float]] | None = None
    traffic_ratio: float | None = None
    step_time_s: float | None = None
    restart: bool = False
    plan_signature: str | None = None
    # elastic extensions (repro.elastic): per-worker wall times keyed by
    # worker id, the detector's flagged straggler set, and the membership
    # epoch of the view the step ran under.  None/() on fixed-membership
    # runs, so pre-elastic controllers are unaffected.
    worker_step_times: Mapping[int, float] | None = None
    stragglers: tuple = ()
    membership_epoch: int | None = None

    @staticmethod
    def from_metrics(step: int, metrics: Mapping[str, Any], *,
                     step_time_s: float | None = None,
                     restart: bool = False) -> "Telemetry":
        """Adapt one compiled-step metrics dict into a Telemetry record.

        The single sanctioned place where ``cos/{group}/{mode}`` metric
        keys are parsed — every consumer above this line works with the
        typed record.
        """
        cosines: dict[str, dict[str, float]] = {}
        for k, v in metrics.items():
            if k.startswith("cos/"):
                _, group, mode = k.split("/", 2)
                cosines.setdefault(group, {})[mode] = float(v)
        tr = metrics.get("traffic_ratio")
        return Telemetry(step=int(step), loss=float(metrics["loss"]),
                         cosines=cosines or None,
                         traffic_ratio=None if tr is None else float(tr),
                         step_time_s=step_time_s, restart=restart,
                         plan_signature=metrics.get("plan"))


# ---------------------------------------------------------------------------
# plan (de)serialization — controllers checkpoint their latched plans
# ---------------------------------------------------------------------------

_PLAN_TAG = "__admission_plan__"
_TUPLE_TAG = "__tuple__"


def plan_to_jsonable(plan: AdmissionPlan) -> dict:
    """AdmissionPlan -> JSON-serializable dict (for checkpoint manifests)."""
    def enc(p: GroupPolicy) -> dict:
        return {"mode": codec_name(p.mode),
                "schedule": (None if p.schedule is None
                             else schedule_name(p.schedule)),
                "error_feedback": bool(p.error_feedback)}
    return {_PLAN_TAG: {
        "policies": [[g, enc(p)] for g, p in plan.policies],
        "default": enc(plan.default)}}


def plan_from_jsonable(obj: dict) -> AdmissionPlan:
    """Inverse of :func:`plan_to_jsonable`; signature-preserving."""
    body = obj[_PLAN_TAG]

    def dec(d: dict) -> GroupPolicy:
        sched = d["schedule"]
        if sched is not None:
            try:                       # built-in enum if it is one, else the
                sched = Schedule(sched)  # registered custom-backend name
            except ValueError:
                pass
        # built-in codecs decode to their enum member, registered codec
        # names pass through as strings — signature-preserving either way
        return GroupPolicy(canonical_mode(d["mode"]), sched,
                           bool(d["error_feedback"]))

    return AdmissionPlan(
        policies=tuple((g, dec(p)) for g, p in body["policies"]),
        default=dec(body["default"]))


def _payload_to_jsonable(plan: Any) -> Any:
    """Latch payload -> JSON.  PolicyProgram latches are usually
    AdmissionPlans, but the phase machine is payload-agnostic (the
    experiments harness latches (backbone, head) rule-name pairs)."""
    if isinstance(plan, AdmissionPlan):
        return plan_to_jsonable(plan)
    if isinstance(plan, tuple):
        return {_TUPLE_TAG: list(plan)}
    return plan


def _payload_from_jsonable(obj: Any) -> Any:
    if isinstance(obj, dict) and _PLAN_TAG in obj:
        return plan_from_jsonable(obj)
    if isinstance(obj, dict) and _TUPLE_TAG in obj:
        return tuple(obj[_TUPLE_TAG])
    return obj


def _sig(plan: Any) -> str:
    return plan.signature() if hasattr(plan, "signature") else repr(plan)


_FP32_SIG = AdmissionPlan.fp32_all().signature()


# ---------------------------------------------------------------------------
# named plan presets (shared by launch/train and launch/dryrun)
# ---------------------------------------------------------------------------

def plan_presets(error_feedback: bool = False) -> dict[str, AdmissionPlan]:
    """Canonical named plans, one source for every launcher / CLI.

    ``gbin_vote``/``gter_vote`` pin the paper-faithful dense int8 vote
    schedule; ``*_packed`` pin the packed controller schedule on the ICI;
    ``gbin_packed_embed`` additionally admits the (huge) embedding tables
    while keeping head+norms on FP32 (validated in the convergence
    bench).  Codec-default-schedule presets (``gbin_backbone`` etc.)
    leave the schedule to the codec's ``default_schedule``.  Plans name
    codecs by string exactly like schedules/controllers —
    ``int4_backbone`` / ``topk_backbone`` select the registered
    extension codecs (:mod:`repro.fabric.extra_codecs`); like the
    ``fp32`` preset they pin ``error_feedback=False`` regardless of the
    argument (both codecs declare ``threads_ef=False`` — EF-signSGD
    residuals only thread through the vote codecs, so requesting EF
    would allocate residual buffers that never update).

    The ``hier_*`` presets select the built-in hop plans
    (:mod:`repro.fabric.hierarchy`): intra-node FP32 psum, then the
    named low-bit codec on the inter-node backbone hop.
    ``hier_fp32_gbinary`` / ``hier_fp32_gternary`` thread EF (the vote
    hop declares ``threads_ef``, which the wrapping codec inherits);
    ``hier_fp32_int4`` pins ``error_feedback=False`` for the same
    reason ``int4_backbone`` does.
    """
    ef = error_feedback
    packed = Schedule.PACKED_A2A
    return {
        "fp32": AdmissionPlan.fp32_all(),
        "gbin_backbone": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, error_feedback=ef),
        "gbin_vote": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, schedule=Schedule.VOTE_PSUM,
            error_feedback=ef),
        "gbin_packed": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, schedule=packed, error_feedback=ef),
        "gter_backbone": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_TERNARY, error_feedback=ef),
        "gter_vote": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_TERNARY, schedule=Schedule.VOTE_PSUM,
            error_feedback=ef),
        "lowbit_all": AdmissionPlan.lowbit_all(
            AggregationMode.G_BINARY, error_feedback=ef),
        "gbin_packed_all": AdmissionPlan.lowbit_all(
            AggregationMode.G_BINARY, schedule=packed, error_feedback=ef),
        "gbin_packed_embed": AdmissionPlan.from_dict(
            {"backbone": GroupPolicy(AggregationMode.G_BINARY, packed, ef),
             "embed": GroupPolicy(AggregationMode.G_BINARY, packed, ef)},
            default=GroupPolicy(AggregationMode.FP32)),
        # registered extension codecs, addressed purely by name;
        # error_feedback deliberately not forwarded (threads_ef=False
        # codecs — see the docstring)
        "int4_backbone": AdmissionPlan.lowbit_backbone("int4"),
        "topk_backbone": AdmissionPlan.lowbit_backbone("topk"),
        # hop-plan codecs (repro.fabric.hierarchy), addressed by name;
        # the hierarchical schedule comes from their default_schedule
        "hier_fp32_gbinary": AdmissionPlan.lowbit_backbone(
            "hier_fp32_gbinary", error_feedback=ef),
        "hier_fp32_gternary": AdmissionPlan.lowbit_backbone(
            "hier_fp32_gternary", error_feedback=ef),
        "hier_fp32_int4": AdmissionPlan.lowbit_backbone("hier_fp32_int4"),
        # registered extras (tuned plans, user presets) merge last under
        # their own names; they are concrete plans, so — like the
        # extension-codec presets — the error_feedback argument does not
        # rewrite them
        **_EXTRA_PRESETS,
    }


#: runtime-registered presets (``TunedPlan.install()``, tests, user
#: code) merged into every :func:`plan_presets` call.  Plans here are
#: concrete :class:`AdmissionPlan` values, keyed by name.
_EXTRA_PRESETS: dict[str, AdmissionPlan] = {}

#: built-in preset names, frozen once at import: the guard that keeps
#: ``register_plan_preset`` from shadowing e.g. ``"fp32"``
_BUILTIN_PRESET_NAMES = frozenset(plan_presets())


def register_plan_preset(name: str, plan: AdmissionPlan, *,
                         override: bool = False) -> None:
    """Register a named plan so :func:`plan_presets` resolves it.

    The preset seam for plans that are *data*, not code — a
    :class:`repro.tune.TunedPlan` installs its winner here so the
    launcher's ``--plan``, :class:`StaticController`, and dry-run
    tooling address it by name.  Built-in names are never overridable
    (a tuned plan shadowing ``"fp32"`` would poison every baseline);
    re-registering an extra name raises unless ``override=True``.
    """
    name = str(name)
    if name in _BUILTIN_PRESET_NAMES:
        raise ValueError(f"cannot replace built-in plan preset {name!r}; "
                         f"pick another name")
    if name in _EXTRA_PRESETS and not override:
        raise ValueError(f"plan preset {name!r} already registered; pass "
                         f"override=True to replace it")
    if not isinstance(plan, AdmissionPlan):
        raise TypeError(f"expected an AdmissionPlan, got {type(plan).__name__}")
    _EXTRA_PRESETS[name] = plan


def unregister_plan_preset(name: str) -> None:
    """Remove a runtime-registered preset (built-ins cannot be removed)."""
    if name in _BUILTIN_PRESET_NAMES:
        raise ValueError(f"cannot unregister built-in plan preset {name!r}")
    if name not in _EXTRA_PRESETS:
        raise KeyError(f"no registered plan preset {name!r}; extras: "
                       f"{tuple(sorted(_EXTRA_PRESETS))}")
    del _EXTRA_PRESETS[name]


# ---------------------------------------------------------------------------
# the PolicyProgram phase machine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Phase:
    """One named phase of a :class:`PolicyProgram`.

    ``plan``       — the latch payload while in this phase: a static value,
                     a callable ``(telemetry, program) -> payload``, or
                     None to keep the previous latch.
    ``transition`` — ``(telemetry, program) -> next_phase_name | None``;
                     None means the phase never self-advances (it can
                     still be left via :meth:`PolicyProgram.enter` —
                     e.g. a supervisor interrupt).
    ``latch``      — callable plans are evaluated once on phase entry
                     (True, the default: admission proposals) or on every
                     advance (False: live payloads such as the
                     experiments harness's mutable rule pair).
    ``event``      — control-event kind emitted on entry (default: the
                     phase name).
    """
    name: str
    plan: Any = None
    transition: Callable[["Telemetry", "PolicyProgram"],
                         str | None] | None = None
    latch: bool = True
    event: str | None = None


class PolicyProgram:
    """Declarative phase machine owning the mode latch + event log.

    ``events`` is a *transition* log: one :class:`ControlEvent` per phase
    entered after the start phase (matching the legacy ControlPlane,
    which never logged the initial warm-up phase); the current phase is
    always available as ``program.phase`` / in ``state_dict()``.

    ``advance(telemetry)`` evaluates the current phase's transition
    (chaining through consecutive transitions that fire on the same
    telemetry — e.g. warm-up ending exactly when calibration cosines
    arrive) and returns the latched plan for the *next* step.
    ``enter(name, telemetry)`` force-jumps to a phase, which is how
    event-driven interrupts (the Supervisor's guarded recovery) compose
    with the declarative nominal flow.
    """

    def __init__(self, phases: Sequence[Phase], *, start: str | None = None,
                 plan: Any = None):
        if not phases:
            raise ValueError("PolicyProgram needs at least one phase")
        self.phases: dict[str, Phase] = {}
        for p in phases:
            if p.name in self.phases:
                raise ValueError(f"duplicate phase name {p.name!r}")
            self.phases[p.name] = p
        self.phase = start if start is not None else phases[0].name
        if self.phase not in self.phases:
            raise ValueError(f"unknown start phase {self.phase!r}; have "
                             f"{sorted(self.phases)}")
        first = self.phases[self.phase]
        if first.plan is not None and not callable(first.plan):
            plan = first.plan
        self.plan = plan
        # a latched callable on the start phase needs telemetry to
        # evaluate; do it once on the first advance (until then, the
        # constructor's `plan=` fallback is the latch)
        self._entry_pending = (first.plan is not None
                               and callable(first.plan) and first.latch)
        self.entered_step = 0
        self.events: list[ControlEvent] = []

    def enter(self, name: str, telemetry: Telemetry | None = None) -> None:
        """Force a transition into ``name`` (emits its entry event).

        ``telemetry`` may be omitted only for phases whose plan is static
        (or None): a callable plan is computed *from* telemetry.
        """
        try:
            ph = self.phases[name]
        except KeyError:
            raise KeyError(f"unknown phase {name!r}; have "
                           f"{sorted(self.phases)}") from None
        if callable(ph.plan) and telemetry is None:
            raise ValueError(
                f"entering phase {name!r} requires telemetry: its plan is "
                f"computed from the telemetry record")
        self.phase = name
        self._entry_pending = False
        if telemetry is not None:
            self.entered_step = telemetry.step
        if ph.plan is not None:
            self.plan = (ph.plan(telemetry, self) if callable(ph.plan)
                         else ph.plan)
        self.events.append(ControlEvent(self.entered_step,
                                        ph.event or ph.name,
                                        _sig(self.plan)))

    def advance(self, telemetry: Telemetry) -> Any:
        """One step of policy; returns the latched plan for the next step."""
        first = True
        for _ in range(len(self.phases) + 1):
            ph = self.phases[self.phase]
            # live (latch=False) plans re-evaluate every advance, and a
            # start phase's latched callable evaluates on first advance;
            # phases just entered via enter() were already evaluated there
            if (first and ph.plan is not None and callable(ph.plan)
                    and (not ph.latch or self._entry_pending)):
                self.plan = ph.plan(telemetry, self)
            self._entry_pending = first = False
            nxt = ph.transition(telemetry, self) if ph.transition else None
            if nxt is None or nxt == self.phase:
                return self.plan
            self.enter(nxt, telemetry)
        raise RuntimeError(
            f"phase transitions did not settle after visiting every phase "
            f"once (cycle through {sorted(self.phases)}?)")

    @staticmethod
    def staged(stages: Sequence[tuple[str, Any, int | None]]
               ) -> "PolicyProgram":
        """Linear step-bounded program: ``[(name, plan, until_step), ...]``.

        Each stage latches ``plan`` and advances to the next stage at the
        first telemetry with ``step >= until_step`` (None = terminal).
        The paper's "head on FP32 after step N" style user phases are one
        call::

            PolicyProgram.staged([
                ("all_lowbit", lowbit_all_plan, 200),
                ("head_fp32", lowbit_backbone_plan, None)])
        """
        names = [s[0] for s in stages]
        phases = []
        for i, (name, plan, until) in enumerate(stages):
            transition = None
            if until is not None and i + 1 < len(stages):
                def transition(t, p, _until=until, _next=names[i + 1]):
                    return _next if t.step >= _until else None
            phases.append(Phase(name, plan=plan, transition=transition))
        return PolicyProgram(phases)

    # -- persistence -----------------------------------------------------

    def state_dict(self) -> dict:
        return {"phase": self.phase,
                "entered_step": self.entered_step,
                "plan": _payload_to_jsonable(self.plan),
                "events": [[e.step, e.kind, e.plan_signature]
                           for e in self.events]}

    def load_state_dict(self, state: dict) -> None:
        if state["phase"] not in self.phases:
            raise ValueError(f"checkpointed phase {state['phase']!r} not in "
                             f"this program ({sorted(self.phases)})")
        self.phase = state["phase"]
        self._entry_pending = False       # the latch itself was restored
        self.entered_step = int(state["entered_step"])
        self.plan = _payload_from_jsonable(state["plan"])
        self.events = [ControlEvent(int(s), k, sig)
                       for s, k, sig in state["events"]]


# ---------------------------------------------------------------------------
# Controller protocol + registry (mirrors @register_schedule)
# ---------------------------------------------------------------------------

@runtime_checkable
class Controller(Protocol):
    """Protocol every registered controller implements.

    ``observe`` consumes one :class:`Telemetry` record and returns the
    :class:`AdmissionPlan` to latch for the *next* step; ``plan`` is the
    current latch.  Optional surface the runtime uses when present:
    ``wants_diagnostics`` (compile the step with cosine diagnostics while
    True), ``state_dict()/load_state_dict()`` (checkpoint threading via
    :class:`~repro.checkpoint.CheckpointManager`), and ``events`` (the
    control-event log).
    """

    name: str
    plan: AdmissionPlan

    def observe(self, telemetry: Telemetry) -> AdmissionPlan: ...


#: backed by the shared generic :class:`repro.core.registry.Registry`;
#: unlike schedule backends (stateless, registered as instances),
#: controllers are *stateful*, so the registry holds factories and
#: :func:`make_controller` constructs a fresh instance per call.  Going
#: through the shared helper also gives ``override=True`` the alias
#: sweep the schedule/codec registries got in PR 5 (replacing a name
#: drops any other alias still bound to the replaced factory).
_CONTROLLERS = Registry("controller", key_fn=str,
                        describe=lambda f: f.__name__,
                        register_hint="@register_controller({key!r})")


def register_controller(name: str, *aliases: str, override: bool = False):
    """Class/factory decorator registering a controller under ``name``.

    ``aliases`` register the same factory under extra names;
    re-registering an existing name raises unless ``override=True``,
    which replaces the named keys *and* sweeps stale aliases of the
    replaced factory.
    """
    return _CONTROLLERS.register(name, *aliases, override=override)


def unregister_controller(name: str) -> None:
    """Remove a controller factory and all its aliases (for tests
    tearing down toys — a leftover alias would make the original
    ``@register_controller(name, *aliases)`` unrepeatable)."""
    _CONTROLLERS.unregister(name)


def get_controller(name: str) -> Callable[..., Any]:
    """Resolve a controller name to its registered factory."""
    return _CONTROLLERS.get(name)


def make_controller(name: str, **kwargs) -> Any:
    """Construct a fresh controller instance from its registered name."""
    return get_controller(name)(**kwargs)


def available_controllers() -> tuple[str, ...]:
    return _CONTROLLERS.available()


# ---------------------------------------------------------------------------
# built-in controllers
# ---------------------------------------------------------------------------

@register_controller("static")
class StaticController:
    """Fixed-plan controller: always latches the plan it was built with.

    ``plan`` may be an :class:`AdmissionPlan` or the name of a
    :func:`plan_presets` entry.  Drives the Trainer through the exact
    same path as the adaptive controllers — bit-identical history to the
    legacy ``Trainer(..., plan=...)`` static case.
    """

    name = "static"
    wants_diagnostics = False

    def __init__(self, plan: AdmissionPlan | str | None = None):
        if isinstance(plan, str):
            presets = plan_presets()
            if plan not in presets:
                raise KeyError(f"unknown plan preset {plan!r}; available: "
                               f"{tuple(sorted(presets))}")
            plan = presets[plan]
        self.plan = plan if plan is not None else AdmissionPlan.fp32_all()
        self.events: list[ControlEvent] = []

    def observe(self, telemetry: Telemetry) -> AdmissionPlan:
        return self.plan

    def state_dict(self) -> dict:
        return {"plan": plan_to_jsonable(self.plan)}

    def load_state_dict(self, state: dict) -> None:
        self.plan = plan_from_jsonable(state["plan"])


@register_controller("fp32")
class FP32Controller(StaticController):
    """Everything on the FP32 bypass path, forever (baseline runs)."""

    name = "fp32"

    def __init__(self):
        super().__init__(AdmissionPlan.fp32_all())


@register_controller("paper", "adaptive")
class PaperController:
    """The paper's Predictor/Commander/Supervisor ladder as a controller.

    Phase program (Sections 3 and 8)::

        warmup ──(warmup_steps observed)──> calibrate ──(cosines)──> admitted
           admitted/readmitted ──(CUSUM trigger)──> recovery
           recovery ──(cooldown over)──> readmitted

    Warm-up and calibration are separate phases on purpose: admission
    *retries* while calibration cosines are pending instead of being a
    one-shot window at exactly ``step == warmup_steps`` (the old dual
    warm-up-knob failure mode, where a Trainer/plane disagreement made
    admission silently never fire).  The guarded-recovery interrupt is
    event-driven (the Supervisor can fire in any admitted phase); the
    nominal flow is declarative.
    """

    name = "paper"

    def __init__(self, commander: Commander | None = None,
                 supervisor: Supervisor | None = None,
                 predictor: Predictor | None = None,
                 warmup_steps: int = 20):
        self.commander = commander or Commander()
        self.supervisor = supervisor or Supervisor()
        self.predictor = predictor
        self.warmup_steps = int(warmup_steps)
        self._observed = 0
        self._admitted_plan: AdmissionPlan | None = None
        self.program = PolicyProgram([
            Phase("warmup", plan=AdmissionPlan.fp32_all(),
                  transition=self._warmup_done),
            Phase("calibrate", transition=self._calibrated,
                  event="warmup_end"),
            Phase("admitted", plan=self._propose),
            Phase("recovery", plan=AdmissionPlan.fp32_all(),
                  transition=self._cooldown_over),
            Phase("readmitted", plan=self._repropose),
        ], plan=AdmissionPlan.fp32_all())

    # -- phase transitions / latches ------------------------------------

    def _warmup_done(self, t: Telemetry, prog: PolicyProgram) -> str | None:
        return "calibrate" if self._observed >= self.warmup_steps else None

    def _calibrated(self, t: Telemetry, prog: PolicyProgram) -> str | None:
        return "admitted" if t.cosines else None

    def _cooldown_over(self, t: Telemetry, prog: PolicyProgram) -> str | None:
        return None if self.supervisor.in_cooldown else "readmitted"

    def _propose(self, t: Telemetry, prog: PolicyProgram) -> AdmissionPlan:
        self._admitted_plan = self.commander.propose(t.cosines)
        return self._admitted_plan

    def _repropose(self, t: Telemetry, prog: PolicyProgram) -> AdmissionPlan:
        if t.cosines:              # recalibrate before re-admitting
            return self._propose(t, prog)
        return self._admitted_plan

    # -- Controller surface ---------------------------------------------

    @property
    def plan(self) -> AdmissionPlan:
        return self.program.plan

    @property
    def events(self) -> list[ControlEvent]:
        return self.program.events

    @property
    def wants_diagnostics(self) -> bool:
        """Keep the compiled step emitting cosines until admission."""
        return self.program.phase in ("warmup", "calibrate")

    def observe(self, telemetry: Telemetry) -> AdmissionPlan:
        self._observed += 1
        recovering = self.supervisor.observe(telemetry.loss)
        if recovering and _sig(self.plan) != _FP32_SIG:
            self.program.enter("recovery", telemetry)
            return self.plan
        return self.program.advance(telemetry)

    # -- persistence (threaded through CheckpointManager) ---------------

    def state_dict(self) -> dict:
        return {"observed": self._observed,
                "warmup_steps": self.warmup_steps,
                "admitted_plan": (None if self._admitted_plan is None
                                  else plan_to_jsonable(self._admitted_plan)),
                "supervisor": self.supervisor.state_dict(),
                "program": self.program.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        self._observed = int(state["observed"])
        # the checkpointed calibration window wins over the constructor's:
        # a restart launched with a different --warmup-steps must not cut
        # the restored run's warm-up short (or stretch it)
        self.warmup_steps = int(state.get("warmup_steps",
                                          self.warmup_steps))
        ap = state["admitted_plan"]
        self._admitted_plan = None if ap is None else plan_from_jsonable(ap)
        self.supervisor.load_state_dict(state["supervisor"])
        self.program.load_state_dict(state["program"])
