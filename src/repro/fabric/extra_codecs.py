"""Extension codecs beyond the paper, registered through the public API.

This module deliberately lives *outside* :mod:`repro.fabric.codecs` and
uses nothing but the public ``GradientCodec`` base + ``@register_codec``
decorator — it is the proof that the representation axis is open: both
codecs ride the existing ``psum`` mean transport, fuse into 32 MiB
buckets, show up in the traffic model, and simulate on every registered
topology without editing a single schedule backend or sim lane table.

  * ``int4``  — symmetric 4-bit quantized mean (QSGD-style, absmax
    scale, round-to-nearest).  8x payload reduction vs FP32 with a mean
    (not sign) update direction — the middle ground between the FP32
    bypass and the 1-bit vote path.
  * ``topk``  — magnitude top-k sparsified mean: each worker keeps its
    ``fraction`` largest-|g| entries, the mean runs over the sparse
    payloads.  Accounted at ``fraction * 64`` bits/element (32-bit value
    + 32-bit index per kept entry).

Quantization granularity is the collective payload (the leaf per-leaf,
the fused bucket when bucketed) — matching the paper's bucket-granular
controller, and the reason these codecs are *semantically* rather than
bit-for-bit identical across the two paths (the four built-in codecs
are statistic-free and stay bit-identical).

Both codecs also carry real fused Pallas kernels — again purely through
the public seam: ``pallas_kernels()`` returns the ``Int4KernelSet`` /
``TopKKernelSet`` exported by :mod:`repro.kernels`, replacing the
reference-jnp-only encode with single-launch kernels (bit-identical
under jit; see DESIGN.md §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .codecs import CodecLane, GradientCodec, register_codec

__all__ = ["Int4Codec", "TopKCodec"]


@functools.lru_cache(maxsize=None)
def _int4_kernels(levels: float):
    from ..kernels import Int4KernelSet
    return Int4KernelSet(levels=levels)


@functools.lru_cache(maxsize=None)
def _topk_kernels(fraction: float):
    from ..kernels import TopKKernelSet
    return TopKKernelSet(fraction)


@register_codec("int4")
class Int4Codec(GradientCodec):
    """Symmetric absmax int4 quantization of the per-worker payload.

    ``encode`` returns the dequantized values (quantize -> dequantize):
    the wire carries the 4-bit codes plus one scale, and the mean of the
    dequantized payloads is exactly the aggregate those codes decode to,
    so the functional path simulates the codec faithfully while the
    accounting counts the real 4-bit payload.
    """

    name = "int4"
    bits_per_element = 4.0
    lane = CodecLane("int4_dense", fused=True)
    default_schedule = "psum"
    kv_cache = True

    #: symmetric int4 code range: {-7, ..., +7}
    levels = 7.0

    def pallas_kernels(self):
        return _int4_kernels(self.levels)

    def encode(self, ctx, g):
        f = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(f)) / self.levels
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(f / safe), -self.levels, self.levels)
        return (q * safe).astype(g.dtype)

    def kv_encode(self, block):
        """Per-block absmax int4 quantization of a host KV-cache block.

        Same functional convention as :meth:`encode`: the stored array
        holds the dequantized values the 4-bit codes decode to (wire
        bytes are priced by ``kv_bytes`` at 4 bits/value + one scale per
        block), and the operation is idempotent — re-encoding a block
        already on the int4 grid reproduces it bit-for-bit, so repeated
        gather/spill round trips do not compound error.
        """
        f = np.asarray(block, np.float32)
        scale = float(np.max(np.abs(f))) / self.levels
        if scale <= 0.0:
            return np.asarray(block).copy()
        q = np.clip(np.round(f / scale), -self.levels, self.levels)
        return (q * scale).astype(np.asarray(block).dtype)


@register_codec("topk")
class TopKCodec(GradientCodec):
    """Magnitude top-k sparsified mean (each worker keeps its largest |g|).

    ``fraction`` of the payload survives per worker; threshold ties may
    keep a few extra entries (the model cares about the order of
    magnitude, not an exact k).  Register parameterized variants as
    instances, passing the registration key as ``name`` so errors and
    reprs point at the right registry entry:
    ``register_codec("top1pct")(TopKCodec(0.01, name="top1pct"))``.
    """

    def __init__(self, fraction: float = 1 / 16, name: str = "topk"):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = float(fraction)
        self.name = str(name)
    lane = CodecLane("sparse_topk", fused=True)
    default_schedule = "psum"

    def pallas_kernels(self):
        return _topk_kernels(self.fraction)

    @property
    def bits_per_element(self) -> float:
        # 32-bit value + 32-bit index per kept entry
        return 64.0 * self.fraction

    def encode(self, ctx, g):
        flat = jnp.abs(g.astype(jnp.float32)).reshape(-1)
        k = max(1, int(flat.shape[0] * self.fraction))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(g) >= thresh.astype(g.dtype), g,
                         jnp.zeros((), g.dtype))
