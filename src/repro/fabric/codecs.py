"""Gradient-codec registry: *what bits go on the wire*.

The fabric has two orthogonal axes, and this module owns the first:

  * **Codec** — the communicated gradient *representation* and its
    cross-worker reduction semantics: FP32 mean, packed G-Binary
    sign-count, gated G-Ternary, a quantized int4 mean, a top-k
    sparsifier, ...  A codec owns the payload contract end to end:
    per-worker encode, reduction kind, post-reduction decode, the
    ternary-gate and error-feedback capability flags, bits/element wire
    accounting, and the sim datapath lane descriptor.
  * **Schedule backend** (:mod:`repro.fabric.registry`) — the transport:
    how the encoded bytes actually move on the mesh (psum ring, dense
    int8 votes, packed ``all_to_all``, ...).

Codecs register under a string name — the same extension idiom as
schedules (PR 1), controllers (PR 3), and sim topologies (PR 4) — and
plans simply *name* them: ``GroupPolicy(mode="int4")`` works exactly
like ``GroupPolicy(mode=AggregationMode.G_BINARY)`` (the legacy enum's
values are the built-in codec names).  Schedule backends are
codec-parametric: they ask the codec for encode/decode/gate behaviour
instead of branching on a closed mode enum, so a new representation
plugs into every transport, the traffic model, and the simulator
without editing any of them::

    from repro.fabric import GradientCodec, register_codec

    @register_codec("int2")
    class Int2(GradientCodec):
        name = "int2"
        bits_per_element = 2.0
        def encode(self, ctx, g):            # per-worker wire payload
            s = jnp.max(jnp.abs(g))
            return jnp.round(g / jnp.where(s > 0, s, 1.0)) * s

    plan = AdmissionPlan.lowbit_backbone("int2")     # name it like a mode

Reduction kinds
---------------
``reduction = "mean"`` declares an elementwise-summable payload: the
transport averages the encoded per-worker payloads (``psum`` /
``sign_of_mean`` style backends), then :meth:`~GradientCodec.decode`
runs on the mean.  ``reduction = "vote"`` declares the paper's
sign-vote contract: workers contribute sign bits, the transport
popcounts them, and the majority (plus the codec's zero gate when
``gated``) decides — the G-Binary / G-Ternary pipeline of Section 2.
:func:`repro.core.modes.wire_schedule` uses the reduction kind to keep
codecs off transports that cannot realize them (a mean codec nominally
on ``vote_psum`` rides ``psum``; a vote codec on ``psum`` rides
``vote_psum`` — the historical bypass semantics, generalized).

Encode granularity is the collective payload: the leaf on the per-leaf
path, the fused flat bucket on the bucketed path (the paper's
controller is bucket-granular, Section 5.2).  Bucket-statistic codecs
(e.g. an absmax-scaled quantizer) therefore see per-bucket statistics
when fused; the four built-ins are statistic-free, which is why they
are bit-identical on both paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Protocol, runtime_checkable

import numpy as np

from ..core.modes import AggregationMode, codec_name
from ..core.registry import Registry

__all__ = [
    "Codec", "CodecLane", "GradientCodec", "MaskGate", "available_codecs",
    "get_codec", "register_codec", "resolve_leaf_gate_mask",
    "ring_wire_bytes", "unregister_codec",
]


@dataclasses.dataclass(frozen=True)
class CodecLane:
    """Sim-datapath lane descriptor for one codec.

    Field-compatible with :class:`repro.sim.datapath.LaneSpec`; the
    :class:`~repro.sim.datapath.FlitPipeline` resolves a launch's lane
    from its codec, so a registered codec times correctly in the
    simulator without touching the built-in lane table.
    """
    name: str
    #: flits issued per initiation interval slot (usually 1).
    initiation_interval: float = 1.0
    #: extra stall cycles charged per flit (gate fetch, bypass hazards).
    stall_cycles_per_flit: float = 0.0
    #: the lane's stages run as one fused pipeline (the paper's single
    #: streaming datapath stage); unfused lanes re-fill the pipeline once
    #: per staged pass (:attr:`repro.sim.datapath.FlitPipeline.unfused_passes`).
    fused: bool = False


@dataclasses.dataclass(frozen=True)
class MaskGate:
    """Bucket zero gate carrying an explicit host keep mask.

    The gate representation for codecs with arbitrary (non-2-of-3)
    keep patterns — the default :meth:`GradientCodec.bucket_gate`
    builds one from per-leaf ``leaf_gate_mask`` patterns.  Unlike
    :class:`repro.core.buckets.BucketGate` the device vector is a
    materialized constant (an arbitrary mask has no iota shortcut).
    """
    keep: Any                   # host-side boolean (N,) array

    def mask(self) -> np.ndarray:
        return np.asarray(self.keep, bool)

    def vector(self, dtype) -> Any:
        import jax.numpy as jnp
        return jnp.asarray(self.mask(), dtype)


_UNGATED_MASK_ERROR = (
    "codec {0!r} returned a leaf gate mask but declares gated=False; the "
    "vote transports only apply gates of gated codecs — set gated = True "
    "on the codec so the declared keep pattern actually takes effect")


def resolve_leaf_gate_mask(codec: "Codec", shape: Any, gate_phase: int):
    """A codec's per-leaf keep mask, validated against its ``gated`` flag.

    The single accessor the vote transports use: returns
    ``codec.leaf_gate_mask(...)`` and raises — instead of silently
    dropping the mask — when an ungated codec supplies one.
    """
    mask = codec.leaf_gate_mask(shape, gate_phase)
    if mask is not None and not getattr(codec, "gated", False):
        raise ValueError(_UNGATED_MASK_ERROR.format(codec.name))
    return mask


def ring_wire_bytes(payload_bytes: float, num_workers: int,
                    trips: float = 2.0) -> float:
    """Ring-collective bytes/device for a given payload size.

    ``trips = 2`` is the reduce-scatter + all-gather round trip of a
    ring all-reduce; the shared helper replaces the per-backend copies
    of the ``2 (W-1)/W * payload`` formula.
    """
    if num_workers <= 1:
        return 0.0
    f = (num_workers - 1) / num_workers
    return trips * f * payload_bytes


# ---------------------------------------------------------------------------
# the protocol + base class
# ---------------------------------------------------------------------------

@runtime_checkable
class Codec(Protocol):
    """Structural protocol every registered codec satisfies.

    Required attributes: ``name`` and ``bits_per_element``.  Everything
    else has paper-faithful defaults on :class:`GradientCodec`, which
    extension codecs should subclass.
    """

    name: str
    bits_per_element: float


class GradientCodec:
    """Base codec: FP32-bypass defaults, hooks for every contract axis.

    Subclasses override only what differs from a transparent mean codec:

    ``reduction``        — ``"mean"`` (encoded payloads are averaged) or
                           ``"vote"`` (sign votes + majority decode).
    ``gated``            — the codec zero-gates the majority output
                           (G-Ternary's 2-of-3 gate); drives gate-word
                           packing on the fused path and the ``ternary``
                           leg of the vote collectives.
    ``threads_ef``       — the codec consumes error-feedback residuals
                           (injected/updated per leaf by the bucket
                           layer on EF-capable transports).
    ``lane``             — :class:`CodecLane` timing descriptor for the
                           sim's flit pipeline.
    ``default_schedule`` — transport used when a plan names no schedule.
    ``pallas_kernels``   — optional fused Pallas :class:`~repro.kernels.
                           fused.KernelSet`; transports consult it when
                           the session runs with ``fused_kernels=True``.
    """

    name: str = "identity"
    bits_per_element: float = 32.0
    reduction: str = "mean"
    gated: bool = False
    threads_ef: bool = False
    lane: CodecLane = CodecLane("fp32_bypass", fused=True)
    default_schedule: str = "psum"

    # -- mean-reduction hooks (psum-style transports) --------------------
    def encode(self, ctx: Any, g: Any) -> Any:
        """Per-worker wire representation of the gradient payload."""
        return g

    def decode(self, ctx: Any, u: Any) -> Any:
        """Post-reduction decode of the averaged payload."""
        return u

    # -- vote-reduction hooks --------------------------------------------
    def bucket_gate(self, bucket: Any):
        """Zero gate for a fused bucket (``None`` when ungated).

        The default derives the fused gate from the codec's own
        declaration, so per-leaf and fused paths always zero the same
        elements: ungated codecs return None; gated codecs concatenate
        per-leaf :meth:`leaf_gate_mask` patterns (falling back, per
        leaf, to the built-in 2-of-3 flat-index gate at the bucket's
        phase — each leaf restarting at its own flat index 0, paper
        Section 2).  Override only for gate structure this composition
        cannot express; the returned object must expose
        ``mask() -> np.ndarray`` and ``vector(dtype) -> jax.Array``
        over the bucket's flat payload (see
        :class:`repro.core.buckets.BucketGate`).
        """
        from ..core.buckets import BucketGate
        phase = bucket.key.gate_phase
        masks = [self.leaf_gate_mask(s.shape, phase) for s in bucket.slots]
        if not self.gated:
            if any(m is not None for m in masks):
                raise ValueError(_UNGATED_MASK_ERROR.format(self.name))
            return None
        if all(m is None for m in masks):
            # pure 2-of-3 per-leaf segments: the device-built BucketGate
            # avoids a bucket-sized host constant in the compiled step
            return BucketGate(segments=tuple((s.size, phase)
                                             for s in bucket.slots))
        parts = []
        for slot, m in zip(bucket.slots, masks):
            if m is None:
                # per-leaf 2-of-3 fallback from the one canonical source
                m = BucketGate(segments=((slot.size, phase),)).mask()
            parts.append(np.asarray(m, bool).reshape(-1))
        return MaskGate(np.concatenate(parts))

    def leaf_gate_mask(self, shape: Any, gate_phase: int):
        """Explicit keep mask for one leaf on the per-leaf vote paths.

        ``None`` (the default) lets the collective build the built-in
        2-of-3 flat-index gate from ``gate_phase``; codecs with custom
        gate patterns return a host-side boolean ``(N,)`` array (flat
        over the leaf) here — ``vote_psum`` applies it as a device keep
        vector and ``packed_a2a`` packs it into gate words, so both
        transports zero the same elements.  (Packed gate masks require
        a fully local leaf — TP-sharded leaves must stay on
        ``vote_psum``.)
        """
        return None

    # -- fused Pallas kernels (the codec-owned kernel capability) --------
    def pallas_kernels(self):
        """The codec's fused :class:`~repro.kernels.fused.KernelSet`.

        ``None`` (the default) keeps the staged / reference-jnp path.
        Vote codecs return a vote-capable set (the ``packed_a2a``
        transport hands it the whole bucket); mean codecs return a
        mean-capable set (the psum transport runs its
        ``encode_flat``/``decode_apply`` around the collective).  The
        returned set must be bit-identical to the codec's
        :meth:`encode`/:meth:`decode` + the staged kernels wherever both
        run — sessions key compiled steps on :meth:`kernel_signature`,
        not object identity, so return a stable (cached) instance.
        """
        return None

    def kernel_signature(self) -> str | None:
        """Step-cache key component for the codec's kernel set (or None)."""
        ks = self.pallas_kernels()
        return None if ks is None else ks.signature()

    # -- accounting ------------------------------------------------------
    def payload_bytes(self, n_elements: int) -> float:
        """Wire payload bytes for ``n_elements`` under this codec."""
        return n_elements * self.bits_per_element / 8.0

    # -- KV-cache capability (serving) -----------------------------------
    #: the codec can represent KV-cache blocks (not just gradients).
    #: Sign-vote codecs stay False — a {-1, 0, +1} alphabet cannot carry
    #: key/value activations; mean-family codecs (FP32 bypass,
    #: quantizers) opt in and the serving engine routes every cache
    #: block through ``kv_encode``/``kv_decode``.
    kv_cache: bool = False

    def kv_encode(self, block: Any) -> Any:
        """Stored representation of one host-side KV-cache block.

        Mirrors :meth:`encode`'s functional convention: lossy codecs
        return the dequantized values their wire codes decode to (the
        int4 block carries 4-bit codes plus a scale on the wire; the
        functional path stores the values those codes reproduce), so
        byte accounting uses :meth:`kv_bytes` while the compute path
        sees exactly what a bit-true decoder would.  Blocks are host
        ``numpy`` arrays — encoding happens off the jitted step.
        """
        return block

    def kv_decode(self, block: Any) -> Any:
        """Inverse of :meth:`kv_encode` (identity for functional codecs)."""
        return block

    def kv_bytes(self, n_elements: int) -> float:
        """Resident/transferred bytes for ``n_elements`` KV-cache values."""
        return self.payload_bytes(n_elements)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"{type(self).__name__}(name={self.name!r}, "
                f"bits={self.bits_per_element:.3g}, {self.reduction})")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _prepare_codec(obj: Any, keys) -> Codec:
    codec = obj() if isinstance(obj, type) else obj
    if not isinstance(codec, Codec):
        raise TypeError(
            f"codec {keys[0]!r} must define 'name' and "
            f"'bits_per_element' (subclass GradientCodec)")
    return codec


#: the shared :class:`repro.core.registry.Registry` instance — the same
#: generic helper backs schedules, controllers, sim topologies, and the
#: serve scheduler policies, so the override/unregister alias sweep is
#: implemented exactly once.
_REGISTRY = Registry("codec", key_fn=codec_name, prepare=_prepare_codec,
                     register_hint="@register_codec({key!r})")


def register_codec(name: Any, *aliases: Any, override: bool = False):
    """Class/instance decorator registering a codec under ``name``.

    Accepts a codec class (instantiated with no arguments) or a ready
    instance (for parameterized codecs).  ``aliases`` register the same
    codec under extra names; re-registering raises unless
    ``override=True``, which replaces the named keys *and* removes any
    other aliases still bound to the replaced instances (a plan naming
    a stale alias must never silently resolve the old codec).
    """
    return _REGISTRY.register(name, *aliases, override=override)


def unregister_codec(name: Any) -> None:
    """Remove a codec and every alias bound to the same instance
    (primarily for tests tearing down toy codecs)."""
    _REGISTRY.unregister(name)


def get_codec(name: Any) -> Codec:
    """Resolve a codec name (str or AggregationMode enum) to its codec."""
    return _REGISTRY.get(name)


def available_codecs() -> tuple[str, ...]:
    return _REGISTRY.available()


# ---------------------------------------------------------------------------
# built-in codecs (the paper's Table 2 representations)
# ---------------------------------------------------------------------------

@register_codec(AggregationMode.FP32)
class Fp32Codec(GradientCodec):
    """Full-precision mean — warm-up / calibration / recovery bypass."""
    name = "fp32"
    bits_per_element = 32.0
    kv_cache = True           # serving: full-precision KV blocks


@register_codec(AggregationMode.IDENTITY)
class IdentityCodec(GradientCodec):
    """Original bytes (functional read-back checks only); FP32 accounting."""
    name = "identity"
    bits_per_element = 32.0
    kv_cache = True           # serving: passthrough KV blocks


@register_codec(AggregationMode.G_BINARY)
class GBinaryCodec(GradientCodec):
    """Majority sign aggregate, u = sgn(2c - W); 1 wire bit/element."""
    name = "gbinary"
    bits_per_element = 1.0
    reduction = "vote"
    threads_ef = True
    lane = CodecLane("sign_count", fused=True)
    default_schedule = "vote_psum"

    def pallas_kernels(self):
        from ..kernels.fused import vote_kernel_set
        return vote_kernel_set()


@register_codec(AggregationMode.G_TERNARY)
class GTernaryCodec(GradientCodec):
    """Gated ternary aggregate, u = m * sgn(2c - W), 2-of-3 zero gate.

    Counted at log2(3) bits/element, which reproduces the paper's
    0.0494 full-path traffic ratio (Table 6).
    """
    name = "gternary"
    bits_per_element = math.log2(3.0)
    reduction = "vote"
    gated = True
    threads_ef = True
    lane = CodecLane("ternary_gated", stall_cycles_per_flit=1.0, fused=True)
    default_schedule = "vote_psum"
    # bucket_gate: the base-class default already yields the per-leaf
    # 2-of-3 BucketGate segments (leaf_gate_mask is None everywhere)

    def pallas_kernels(self):
        # the vote chain is gate-parametric: gbinary and gternary share
        # one kernel set and differ only in the packed gate operand
        from ..kernels.fused import vote_kernel_set
        return vote_kernel_set()
