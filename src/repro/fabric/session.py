"""The Fabric session: one control surface over the aggregation fabric.

A :class:`Fabric` is constructed once from ``(mesh, dp_axes, rules,
interpret)`` and owns everything the old free-function API made every
caller re-thread by hand: the worker count, group assignment, policy
resolution, error-feedback state init/specs, schedule dispatch (via the
backend registry — by default through fused 32 MiB *buckets*, one
collective per bucket instead of one per leaf; see
:func:`aggregate_tree_bucketed`), and the per-plan-signature jit cache
for compiled train steps.  It is the seam later scaling work (new
collectives, async overlap, multi-backend) plugs into — swap or add a
registered :class:`~repro.fabric.registry.ScheduleBackend` and every
layer above (Trainer, dry-run, benchmarks) picks it up.

Layering: ``fabric`` sits above ``core`` (math + policy vocabulary) and
below ``runtime`` (Trainer control loop); model/optimizer specifics are
imported lazily inside :meth:`Fabric.build_step` so the session stays
usable for host-local aggregation without the full model stack.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.aggregate import init_ef_states
from ..core.buckets import (AdmissionPlan, BucketLayout,
                            DEFAULT_BUCKET_BYTES, GroupRules, assign_groups,
                            group_sizes, plan_buckets, resolve_policies)
from ..core.lowbit import _ef_update
from ..core.modes import codec_name, wire_schedule
from .codecs import get_codec
from .registry import AggregationContext, get_schedule

Axes = Sequence[str] | str

_is_policy = lambda x: hasattr(x, "mode") and hasattr(x, "schedule")


# ---------------------------------------------------------------------------
# leaf- and tree-level aggregation (registry-dispatched)
# ---------------------------------------------------------------------------

def aggregate_leaf(ctx: AggregationContext, g: jax.Array, policy,
                   ef: jax.Array | None = None):
    """Aggregate one gradient leaf under its admitted policy.

    Pure registry dispatch: the wire schedule (FP32/IDENTITY always ride
    psum) names the backend; the backend interprets the rest of the
    policy.  Returns ``(aggregate, new_ef)``.
    """
    backend = get_schedule(wire_schedule(policy.mode, policy.schedule))
    return backend.aggregate(ctx, g, policy, ef)


def _leaf_uses_ef(pol, e) -> bool:
    """Does this leaf thread error feedback through its collective?

    Requires the policy flag, a real residual leaf (not the scalar
    sentinel), *and* a codec that consumes EF — the same gate the fused
    path applies, so fused and per-leaf EF semantics agree for custom
    codecs too (a ``threads_ef=False`` codec never injects/updates).
    """
    return (pol.error_feedback and e is not None and e.ndim > 0
            and get_codec(pol.mode).threads_ef)


def aggregate_tree(ctx: AggregationContext, grads: Any, policies: Any,
                   ef_states: Any | None = None):
    """Aggregate a gradient pytree leaf-by-leaf under resolved policies.

    Runs inside a shard_map whose manual axes are ``ctx.dp_axes``.
    Error-feedback leaves hold a ``(1, *shape)`` local residual (globally
    ``(W, *shape)`` sharded over the DP axes); disabled leaves hold a
    scalar sentinel so the tree structure stays static across plans.
    Returns ``(aggregates, new_ef_states)`` mirroring the sentinel
    structure.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    p_leaves = treedef.flatten_up_to(policies)
    if ef_states is None:
        e_leaves = [None] * len(g_leaves)
    else:
        e_leaves = treedef.flatten_up_to(ef_states)

    agg, new_ef = [], []
    for g, pol, e in zip(g_leaves, p_leaves, e_leaves):
        use_ef = _leaf_uses_ef(pol, e)
        ef_in = e[0] if use_ef else None
        u, ef_out = aggregate_leaf(ctx, g, pol, ef=ef_in)
        agg.append(u)
        if e is None:
            new_ef.append(None)
        elif use_ef:
            new_ef.append(ef_out[None])
        else:
            new_ef.append(e)
    aggregates = jax.tree_util.tree_unflatten(treedef, agg)
    if ef_states is None:
        return aggregates, None
    return aggregates, jax.tree_util.tree_unflatten(treedef, new_ef)


# ---------------------------------------------------------------------------
# bucketed (fused) tree aggregation
# ---------------------------------------------------------------------------

def _registry_fusable(schedule: str) -> bool:
    """Layout-planner predicate: does this wire schedule's backend fuse?"""
    try:
        return bool(getattr(get_schedule(schedule), "fusable", False))
    except KeyError:
        return False        # unknown name: per-leaf path raises the
                            # canonical registry error at dispatch time


def _codec_kernel_sig(mode) -> str | None:
    """A mode's fused-kernel signature, None when it brings no kernels
    (or is not registered — the dispatch layer raises the real error)."""
    try:
        codec = get_codec(mode)
    except KeyError:
        return None
    hook = getattr(codec, "kernel_signature", None)
    return hook() if hook is not None else None


def plan_modes(plan: AdmissionPlan) -> set:
    """Every codec mode an admission plan can route a leaf to."""
    return {pol.mode for _, pol in plan.policies} | {plan.default.mode}


def layout_kernel_stats(layout: BucketLayout, num_workers: int) -> dict:
    """Modeled Pallas-launch and HBM-byte accounting for one layout.

    Sums, over every collective launch in ``layout``, the launch count
    and modeled HBM traffic of the launch codec's
    :class:`~repro.kernels.fused.KernelSet` under both datapaths —
    ``fused`` (codec-owned single/merged kernels) and ``unfused`` (the
    staged reference chain).  Hierarchical routes decompose per hop at
    that hop's group size.  Launches whose codec brings no kernel set
    (fp32 psum, custom codecs) count once under ``collectives`` but do
    not contribute kernel stats — the two paths are identical there.

    Returns ``{"launches_fused", "launches_unfused", "hbm_bytes_fused",
    "hbm_bytes_unfused", "collectives", "unkernelized"}``.
    """
    stats = {"launches_fused": 0, "launches_unfused": 0,
             "hbm_bytes_fused": 0.0, "hbm_bytes_unfused": 0.0,
             "collectives": 0, "unkernelized": 0}

    def add(ks, schedule, n, w, ef):
        if ks is None:
            stats["unkernelized"] += 1
            return
        dist = w > 1
        if ks.votes and schedule == "packed_a2a":
            pass
        elif ks.means and schedule == "psum":
            ef = False          # mean sets never thread EF in-kernel
        else:
            stats["unkernelized"] += 1
            return
        for path, fused in (("fused", True), ("unfused", False)):
            stats[f"launches_{path}"] += ks.launches(
                fused=fused, distributed=dist, ef=ef)
            stats[f"hbm_bytes_{path}"] += ks.hbm_bytes(
                n, num_workers=w, fused=fused, distributed=dist, ef=ef)

    for key, n in layout.launches():
        stats["collectives"] += 1
        try:
            codec = get_codec(key.mode)
        except KeyError:
            stats["unkernelized"] += 1
            continue
        if getattr(codec, "reduction", "") == "hierarchical":
            sizes = codec.plan.group_sizes(num_workers)
            for hop, w in zip(codec.plan.hops, sizes):
                c = get_codec(hop.codec)
                hook = getattr(c, "pallas_kernels", None)
                sched = hop.schedule or c.default_schedule
                add(hook() if hook is not None else None,
                    wire_schedule(hop.codec, sched), n, w,
                    key.error_feedback and c.threads_ef)
        else:
            hook = getattr(codec, "pallas_kernels", None)
            add(hook() if hook is not None else None, key.schedule, n,
                num_workers, key.error_feedback and codec.threads_ef)
    return stats


def aggregate_tree_bucketed(ctx: AggregationContext, grads: Any,
                            policies: Any, ef_states: Any | None = None, *,
                            layout: BucketLayout | None = None,
                            bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Aggregate a gradient pytree through fused flat buckets.

    Semantically identical to :func:`aggregate_tree` (bit-for-bit for
    every built-in schedule, EF states included) but launches **one**
    collective per bucket instead of one per leaf: compatible leaves
    (same :class:`~repro.core.buckets.BucketKey`) are flattened and
    concatenated, the backend's ``aggregate_flat`` runs on the fused
    payload, and results are scattered back to the original leaf shapes.

    Error feedback is handled per leaf *around* the fused collective —
    injection ``g + e`` before concatenation and the EF-signSGD residual
    update (whose ``beta = mean|g_eff|`` is a per-leaf statistic) after
    the scatter — which is exactly what keeps EF semantics identical to
    the per-leaf path.  TP-sharded leaves and non-fusable backends stay
    on the per-leaf path (``layout.unfused``).

    ``layout`` may be precomputed (and cached — it is stable across
    steps); otherwise it is planned here from the grads' shapes.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    p_leaves = treedef.flatten_up_to(policies)
    if ef_states is None:
        e_leaves = [None] * len(g_leaves)
    else:
        e_leaves = treedef.flatten_up_to(ef_states)
    if layout is None:
        layout = plan_buckets(grads, policies, bucket_bytes=bucket_bytes,
                              fusable=_registry_fusable)
    assert layout.num_leaves == len(g_leaves), (
        f"bucket layout planned for {layout.num_leaves} leaves applied to "
        f"a {len(g_leaves)}-leaf gradient tree")

    agg: list = [None] * len(g_leaves)
    new_ef = list(e_leaves)

    # per-leaf fallback — same dispatch (and same EF gate) as aggregate_tree
    for uf in layout.unfused:
        g, pol, e = g_leaves[uf.leaf], p_leaves[uf.leaf], e_leaves[uf.leaf]
        use_ef = _leaf_uses_ef(pol, e)
        u, ef_out = aggregate_leaf(ctx, g, pol, ef=e[0] if use_ef else None)
        agg[uf.leaf] = u
        if use_ef:
            new_ef[uf.leaf] = ef_out[None]

    for bucket in layout.buckets:
        backend = get_schedule(bucket.key.schedule)
        codec = get_codec(bucket.key.mode)
        # EF rides the fused collective only when both axes can carry it
        threads_ef = getattr(backend, "threads_ef", False) and codec.threads_ef
        flats, g_effs = [], {}
        for slot in bucket.slots:
            g = g_leaves[slot.leaf].reshape(-1)
            e, pol = e_leaves[slot.leaf], p_leaves[slot.leaf]
            if (threads_ef and pol.error_feedback and e is not None
                    and e.ndim > 0):
                g = g + e[0].reshape(-1).astype(g.dtype)
                g_effs[slot.leaf] = g
            flats.append(g)
        flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        u_flat = backend.aggregate_flat(ctx, flat, codec,
                                        gate=bucket.gate())
        for slot in bucket.slots:
            u = u_flat[slot.offset:slot.offset + slot.size]
            agg[slot.leaf] = u.reshape(slot.shape)
            if slot.leaf in g_effs:
                e = e_leaves[slot.leaf]
                g_eff = g_effs[slot.leaf].reshape(slot.shape)
                new_ef[slot.leaf] = _ef_update(g_eff, e[0])[None]

    aggregates = jax.tree_util.tree_unflatten(treedef, agg)
    if ef_states is None:
        return aggregates, None
    return aggregates, jax.tree_util.tree_unflatten(treedef, new_ef)


# ---------------------------------------------------------------------------
# train-step state (owned here; re-exported by repro.runtime)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    ef: Any                    # error-feedback residuals (sentinel tree)
    step: jax.Array


class CompiledStep(NamedTuple):
    """One compiled train step and its I/O contracts.

    Tuple-compatible with the legacy ``build_train_step`` return value
    ``(jitted, state_shardings, batch_sharding, aux)``.
    """
    step_fn: Callable
    state_shardings: Any
    batch_sharding: Any
    aux: dict

    def __call__(self, state, batch):
        return self.step_fn(state, batch)


def dp_num_workers(mesh, dp_axes: Axes) -> int:
    axes = (dp_axes,) if isinstance(dp_axes, str) else dp_axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def _optimizer_has_nu(optimizer) -> bool:
    """Does this optimizer's state carry a second moment (nu)?

    Prefers the optimizer's own ``has_nu`` hook (see
    :class:`repro.optim.optimizers.Optimizer`), falling back to probing
    the actual init state for duck-typed optimizers — never the class
    name, which breaks for subclasses and new adaptive optimizers.
    """
    flag = getattr(optimizer, "has_nu", None)
    if flag is not None:
        return bool(flag)
    from ..optim.optimizers import state_has_nu
    return state_has_nu(optimizer)


def _opt_shardings(optimizer, mu_sh, mesh):
    """OptState(step, mu, nu) sharding tree matching optimizer kind."""
    from ..optim.optimizers import OptState
    scalar = NamedSharding(mesh, P())
    return OptState(step=scalar, mu=mu_sh,
                    nu=mu_sh if _optimizer_has_nu(optimizer) else None)


def _split_microbatches(batch: Any, grad_accum: int) -> Any:
    """Reshape each batch leaf to ``(grad_accum, B // grad_accum, ...)``.

    Raises at trace time when the per-device batch is not divisible —
    the old silent ``x.shape[0] // grad_accum`` reshape dropped trailing
    samples.
    """
    def split(x):
        if x.shape[0] % grad_accum:
            raise ValueError(
                f"grad_accum={grad_accum} must divide the per-device batch "
                f"size, but got a batch leaf of shape {tuple(x.shape)} "
                f"({x.shape[0]} % {grad_accum} = {x.shape[0] % grad_accum}); "
                f"trailing samples would be silently dropped")
        return x.reshape((grad_accum, x.shape[0] // grad_accum)
                         + x.shape[1:])
    return jax.tree.map(split, batch)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class Fabric:
    """Aggregation-fabric session bound to one mesh and DP axis set.

    ``mesh=None`` gives a host-local session (virtual workers /
    single-process experiments); ``num_workers`` then defaults to 1 or
    may be forced (e.g. for abstract spec construction).
    """

    def __init__(self, mesh=None, dp_axes: Axes | None = None, *,
                 rules: GroupRules | None = None,
                 interpret: bool | None = None,
                 num_workers: int | None = None,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 fused: bool = True,
                 fused_kernels: bool = True):
        self.mesh = mesh
        if dp_axes is None:
            dp_axes = ("data",) if mesh is not None else ()
        self.dp_axes = ((dp_axes,) if isinstance(dp_axes, str)
                        else tuple(dp_axes))
        self.rules = rules or GroupRules()
        self.interpret = interpret
        if num_workers is not None:
            self.num_workers = int(num_workers)
        elif mesh is not None:
            self.num_workers = dp_num_workers(mesh, self.dp_axes)
        else:
            self.num_workers = 1
        self.bucket_bytes = int(bucket_bytes)
        self.fused = bool(fused)
        # fused_kernels routes codec payloads through their registered
        # Pallas KernelSet (repro.kernels.fused) — one kernel per bucket
        # for encode -> vote/reduce -> decode(+EF) instead of the staged
        # four-op chain.  Bit-identical either way; False pins the
        # staged pipeline (debugging / A-B validation).
        self.fused_kernels = bool(fused_kernels)
        self.membership_epoch = 0        # bumped by bind_membership
        self.controller = None           # attached admission controller
        self._compiled: dict[tuple, CompiledStep] = {}
        self._layouts: dict[tuple, BucketLayout] = {}

    # -- elastic membership ---------------------------------------------

    def bind_membership(self, view) -> None:
        """Bind this session to an epoch-numbered worker view.

        ``view`` is any object with ``num_workers`` and ``epoch``
        attributes (:class:`repro.elastic.WorkerView`).  Re-binding
        updates ``num_workers`` and stamps the membership epoch into the
        compiled-step cache key, so a jitted step built for an earlier
        view can never be served after a re-plan.  Only mesh-free
        (virtual-worker) sessions may change worker count — a mesh fixes
        the DP extent at construction.
        """
        w, epoch = int(view.num_workers), int(view.epoch)
        if self.mesh is not None and w != dp_num_workers(self.mesh,
                                                         self.dp_axes):
            raise ValueError(
                f"cannot bind a {w}-worker view: mesh fixes the DP extent "
                f"at {dp_num_workers(self.mesh, self.dp_axes)}")
        self.num_workers = w
        self.membership_epoch = epoch

    # -- admission controller -------------------------------------------

    def attach_controller(self, controller, **kwargs):
        """Attach an admission controller to this session.

        ``controller`` is either a :class:`repro.fabric.control.Controller`
        instance or a name registered via ``@register_controller``
        (``kwargs`` then go to the factory, e.g.
        ``fabric.attach_controller("paper", warmup_steps=50)``).  The
        session is the natural owner: the controller's mode latch and the
        per-plan-signature jit cache (the XLA analogue of that latch)
        then live in one object, and a Trainer built on this session
        picks the controller up automatically.  Returns the controller.
        """
        from .control import make_controller
        if isinstance(controller, str):
            controller = make_controller(controller, **kwargs)
        elif kwargs:
            raise TypeError("factory kwargs are only valid when attaching "
                            "a controller by registered name")
        self.controller = controller
        return controller

    # -- context / policy resolution ------------------------------------

    @property
    def context(self) -> AggregationContext:
        return AggregationContext(dp_axes=self.dp_axes,
                                  num_workers=self.num_workers,
                                  interpret=self.interpret, mesh=self.mesh,
                                  fused_kernels=self.fused_kernels)

    def resolve(self, params_like: Any, plan: AdmissionPlan,
                pspecs: Any | None = None) -> Any:
        """Params (+ optional PartitionSpec tree) -> LeafPolicy pytree."""
        return resolve_policies(params_like, plan, pspecs=pspecs,
                                rules=self.rules)

    def groups(self, params_like: Any) -> Any:
        return assign_groups(params_like, self.rules)

    def group_sizes(self, params_like: Any) -> dict[str, int]:
        return group_sizes(params_like, self.rules)

    # -- error-feedback state -------------------------------------------

    def init_ef(self, params: Any, policies: Any, dtype=jnp.float32) -> Any:
        """Global EF tree: ``(W, *shape)`` zeros where EF is on, scalar 0
        sentinel elsewhere (W = this session's worker count)."""
        local = init_ef_states(params, policies, dtype)
        w = self.num_workers
        return jax.tree.map(
            lambda e: (jnp.broadcast_to(e, (w,) + e.shape[1:])
                       if e.ndim > 0 else e), local)

    def ef_specs(self, policies: Any, pspecs: Any) -> Any:
        """PartitionSpecs for the EF tree (leading dim sharded over DP).

        The single implementation — both the step builder and external
        spec construction (launch/specs) derive EF shardings here.
        """
        pol_leaves, pol_def = jax.tree_util.tree_flatten(
            policies, is_leaf=_is_policy)
        spec_leaves = pol_def.flatten_up_to(pspecs)
        leaves = [
            P(self.dp_axes, *tuple(sp or P())) if pol.error_feedback else P()
            for pol, sp in zip(pol_leaves, spec_leaves)]
        return jax.tree_util.tree_unflatten(pol_def, leaves)

    # -- aggregation ----------------------------------------------------

    def layout_for(self, params_like: Any, plan: AdmissionPlan | Any,
                   pspecs: Any | None = None) -> BucketLayout:
        """Bucket layout for a (tree, plan) pair — cached per signature.

        The layout is a pure function of leaf order/shapes/dtypes, the
        resolved policies, and this session's ``bucket_bytes``, so it is
        stable across steps and shared with the compiled-step cache.
        """
        if isinstance(plan, AdmissionPlan):
            policies = self.resolve(params_like, plan, pspecs=pspecs)
        else:
            policies = plan
        leaves, treedef = jax.tree_util.tree_flatten(params_like)
        pol_leaves = tuple(jax.tree_util.tree_flatten(
            policies, is_leaf=_is_policy)[0])
        # the layout also depends on which backends currently fuse and on
        # the codecs' layout-relevant attributes (reduction drives the
        # wire schedule, gated drives gate-phase normalization), so a
        # backend *or codec* swapped under the same name
        # (register/unregister) must not hit a stale cached layout
        wires = {wire_schedule(p.mode, p.schedule) for p in pol_leaves}
        fus_sig = tuple(sorted((w, _registry_fusable(w)) for w in wires))
        modes = {codec_name(p.mode) for p in pol_leaves}
        codec_sig = tuple(sorted(
            (m, get_codec(m).reduction, bool(get_codec(m).gated),
             getattr(get_codec(m), "hop_signature", None),
             _codec_kernel_sig(m))
            for m in modes))
        key = (treedef,
               tuple((tuple(l.shape), str(l.dtype)) for l in leaves),
               pol_leaves, self.bucket_bytes, fus_sig, codec_sig)
        if key not in self._layouts:
            self._layouts[key] = plan_buckets(
                params_like, policies, bucket_bytes=self.bucket_bytes,
                fusable=_registry_fusable)
        return self._layouts[key]

    def aggregate(self, grads: Any, plan: AdmissionPlan | Any,
                  ef: Any | None = None, *, pspecs: Any | None = None,
                  fused: bool | None = None):
        """Aggregate a gradient pytree under a plan (or resolved policies).

        Runs inside a shard_map whose manual axes are this session's
        ``dp_axes`` (the train step's gradient context); with
        ``dp_axes=()`` it is the host-local/virtual-worker path.  ``plan``
        may be an :class:`AdmissionPlan` (resolved against ``grads`` with
        this session's rules) or an already-resolved LeafPolicy pytree.

        By default (``fused=None`` -> the session's ``fused`` flag, True
        unless overridden) compatible leaves are fused into flat
        ``bucket_bytes`` buckets and aggregated by one collective per
        bucket — bit-identical to the per-leaf path (``fused=False``)
        for every built-in schedule.  Returns ``(aggregates, new_ef)``.
        """
        if isinstance(plan, AdmissionPlan):
            policies = self.resolve(grads, plan, pspecs=pspecs)
        else:
            policies = plan
        use_fused = self.fused if fused is None else fused
        if use_fused:
            layout = self.layout_for(grads, policies)
            return aggregate_tree_bucketed(self.context, grads, policies,
                                           ef_states=ef, layout=layout)
        return aggregate_tree(self.context, grads, policies, ef_states=ef)

    # -- simulation -----------------------------------------------------

    def simulate(self, params_like: Any, plan: AdmissionPlan | Any, *,
                 pspecs: Any | None = None, topology: Any = "ici_ring",
                 datapath: Any | None = None,
                 overlap_fraction: float = 1.0,
                 compute_time_s: float = 0.0,
                 ready_times: Sequence[float] | None = None,
                 **topology_kwargs):
        """Simulate one aggregation pass of this session's layout.

        Replays the (cached) bucket layout for ``(params_like, plan)``
        through the :mod:`repro.sim` discrete-event simulator on any
        registered topology (``"cxl_direct"``, ``"cxl_switched"``,
        ``"ici_ring"``, ``"multihop"``, or a custom
        ``@register_topology`` entry).  ``compute_time_s`` is the
        backward-pass wall time the collective timeline overlaps with;
        ``datapath`` defaults to the paper's 5-stage 512-bit
        :class:`~repro.sim.FlitPipeline`.  Returns a
        :class:`~repro.sim.SimReport` — per-bucket start/end times,
        exposed-vs-hidden datapath time, link utilization, and the
        critical path; ``report.telemetry(step, loss)`` adapts the
        simulated step time into the controller Telemetry channel.
        """
        from ..sim import simulate_layout
        layout = self.layout_for(params_like, plan, pspecs=pspecs)
        return simulate_layout(layout, self.num_workers, topology=topology,
                               datapath=datapath,
                               overlap_fraction=overlap_fraction,
                               compute_time_s=compute_time_s,
                               ready_times=ready_times, **topology_kwargs)

    # -- plan autotuning ------------------------------------------------

    def autotune(self, params_like: Any, space: Any | None = None, *,
                 topology: str = "ici_ring", strategy: Any = "grid",
                 shortlist: int = 8, objective: Any | None = None,
                 compute_time_s: float = 0.0,
                 overlap_fraction: float = 1.0,
                 pspecs: Any | None = None, name: str | None = None,
                 error_feedback: bool = False, **topology_kwargs):
        """Search a plan space for this session's best configuration.

        Thin session entry point over :func:`repro.tune.autotune` (the
        tune package is imported lazily — fabric does not depend on it
        at module load).  ``params_like`` may be abstract
        ShapeDtypeStructs; ``space`` defaults to
        :func:`repro.tune.default_space` (all presets + generated
        low-bit axes, classifier head pinned to FP32).  Returns a
        :class:`repro.tune.TunedPlan`; ``tuned.apply(self)`` adopts its
        bucket budget and ``tuned.install()`` registers it as a named
        preset.
        """
        from ..tune import autotune as _autotune
        return _autotune(self, params_like, space, topology=topology,
                         strategy=strategy, shortlist=shortlist,
                         objective=objective,
                         compute_time_s=compute_time_s,
                         overlap_fraction=overlap_fraction, pspecs=pspecs,
                         name=name, error_feedback=error_feedback,
                         **topology_kwargs)

    # -- step builder ---------------------------------------------------

    def build_step(self, cfg, optimizer, plan: AdmissionPlan,
                   params_like: Any, *,
                   with_diagnostics: bool = False,
                   loss: Callable | None = None,
                   zero1: bool = True,
                   grad_accum: int = 1,
                   donate: bool = True,
                   fused: bool | None = None) -> CompiledStep:
        """Compile one train step for a given admission plan.

        ``params_like``: a concrete or abstract (ShapeDtypeStruct) params
        tree — used only for structure/paths.  ``grad_accum`` splits the
        per-device batch into that many sequentially-scanned microbatches
        (activation memory / grad_accum, one aggregation per step —
        communication volume unchanged, overlap-friendly).  ``fused``
        (default: the session's flag) routes aggregation through the
        bucket layout — one collective per 32 MiB bucket; the layout is
        planned here once and cached with the compiled step.
        """
        if self.mesh is None:
            raise ValueError("Fabric.build_step needs a mesh-bound session "
                             "(construct Fabric(mesh, dp_axes))")
        from ..models import loss_fn as model_loss_fn, param_pspecs
        from ..optim import optimizer_state_pspecs
        from ..runtime.shardings import sanitize_pspecs
        from ..core.diagnostics import group_cosines_from_mean

        mesh, dp, w = self.mesh, self.dp_axes, self.num_workers
        ctx = self.context
        pspecs = sanitize_pspecs(param_pspecs(cfg), params_like, mesh)
        policies = self.resolve(params_like, plan, pspecs=pspecs)
        groups = self.groups(params_like)
        ef_specs = self.ef_specs(policies, pspecs)
        lf = loss or (lambda p, b: model_loss_fn(p, cfg, b))
        use_fused = self.fused if fused is None else fused
        layout = (self.layout_for(params_like, policies)
                  if use_fused else None)

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(P(), P(dp), ef_specs),
            out_specs=(P(), P(), ef_specs),
            axis_names=frozenset(dp), check_vma=False)
        def _grad_agg(params, batch, ef):
            if grad_accum > 1:
                micro = _split_microbatches(batch, grad_accum)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    lacc, gacc = carry
                    l, g = jax.value_and_grad(lf)(params, mb)
                    gacc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gacc, g)
                    return (lacc + l, gacc), None

                (lval, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), g0), micro)
                lval = lval / grad_accum
                grads = jax.tree.map(lambda x: x / grad_accum, grads)
            else:
                lval, grads = jax.value_and_grad(lf)(params, batch)
            if use_fused:
                agg, new_ef = aggregate_tree_bucketed(
                    ctx, grads, policies, ef_states=ef, layout=layout)
            else:
                agg, new_ef = aggregate_tree(ctx, grads, policies,
                                             ef_states=ef)
            lval = jax.lax.pmean(lval, dp)
            return lval, agg, new_ef

        def step_fn(state: TrainState, batch):
            lval, agg, new_ef = _grad_agg(state.params, batch, state.ef)
            metrics = {"loss": lval}
            if with_diagnostics:
                cos = group_cosines_from_mean(agg, groups)
                for g, d in sorted(cos.items()):
                    metrics[f"cos/{g}/gbinary"] = d["gbinary"]
                    metrics[f"cos/{g}/gternary"] = d["gternary"]
            gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                              for x in jax.tree.leaves(agg)))
            metrics["agg_norm"] = gn
            new_params, new_opt = optimizer.apply(state.params, agg, state.opt)
            return (TrainState(params=new_params, opt=new_opt, ef=new_ef,
                               step=state.step + 1), metrics)

        # shardings for explicit jit I/O (also consumed by the dry-run)
        param_sh = _named(mesh, pspecs)
        opt_specs = optimizer_state_pspecs(pspecs, params_like, dp_axes=dp,
                                           dp_size=w, zero1=zero1)
        mu_sh = _named(mesh, opt_specs)
        state_shardings = TrainState(
            params=param_sh,
            opt=_opt_shardings(optimizer, mu_sh, mesh),
            ef=_named(mesh, ef_specs),
            step=NamedSharding(mesh, P()))
        batch_sharding = NamedSharding(mesh, P(dp))

        jitted = jax.jit(
            step_fn,
            in_shardings=(state_shardings, batch_sharding),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else ())
        aux = {"policies": policies, "groups": groups, "num_workers": w,
               "ef_specs": ef_specs, "pspecs": pspecs, "layout": layout,
               "num_launches": (layout.num_launches if layout is not None
                                else len(jax.tree.leaves(params_like)))}
        return CompiledStep(jitted, state_shardings, batch_sharding, aux)

    # -- per-plan-signature jit cache -----------------------------------

    def step_for(self, cfg, optimizer, plan: AdmissionPlan,
                 params_like: Any, *,
                 with_diagnostics: bool = False,
                 loss: Callable | None = None,
                 zero1: bool = True,
                 grad_accum: int = 1,
                 fused: bool | None = None) -> CompiledStep:
        """Cached :meth:`build_step` — one compiled step per plan
        signature (the XLA analogue of the controller mode latch).

        The key also covers ``cfg``/``optimizer``/``loss`` (hashable
        frozen dataclasses / callables), so several Trainers may safely
        share one session without cross-model cache hits.
        """
        use_fused = self.fused if fused is None else fused
        # num_workers + membership epoch: a step compiled for one worker
        # view must never be served after an elastic re-plan, even when
        # the rejoined view happens to have the same worker count
        # fused_kernels + the plan modes' kernel signatures: a step
        # compiled against one kernel set must never be served after a
        # codec (or its kernels) is swapped under the same name
        kern_sig = tuple(sorted(
            (codec_name(m), _codec_kernel_sig(m)) for m in plan_modes(plan)))
        key = (plan.signature(), with_diagnostics, zero1, grad_accum,
               cfg, optimizer, loss, use_fused, self.fused_kernels,
               kern_sig, self.num_workers, self.membership_epoch)
        if key not in self._compiled:
            self._compiled[key] = self.build_step(
                cfg, optimizer, plan, params_like,
                with_diagnostics=with_diagnostics, loss=loss, zero1=zero1,
                grad_accum=grad_accum, fused=use_fused)
        return self._compiled[key]

    def clear_cache(self) -> None:
        self._compiled.clear()
        self._layouts.clear()

    def __repr__(self) -> str:
        return (f"Fabric(dp_axes={self.dp_axes}, "
                f"num_workers={self.num_workers}, "
                f"mesh={'set' if self.mesh is not None else None})")
