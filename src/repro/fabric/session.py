"""The Fabric session: one control surface over the aggregation fabric.

A :class:`Fabric` is constructed once from ``(mesh, dp_axes, rules,
interpret)`` and owns everything the old free-function API made every
caller re-thread by hand: the worker count, group assignment, policy
resolution, error-feedback state init/specs, per-leaf schedule dispatch
(via the backend registry), and the per-plan-signature jit cache for
compiled train steps.  It is the seam later scaling work (new
collectives, async overlap, multi-backend) plugs into — swap or add a
registered :class:`~repro.fabric.registry.ScheduleBackend` and every
layer above (Trainer, dry-run, benchmarks) picks it up.

Layering: ``fabric`` sits above ``core`` (math + policy vocabulary) and
below ``runtime`` (Trainer control loop); model/optimizer specifics are
imported lazily inside :meth:`Fabric.build_step` so the session stays
usable for host-local aggregation without the full model stack.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.aggregate import init_ef_states
from ..core.buckets import (AdmissionPlan, GroupRules, assign_groups,
                            group_sizes, resolve_policies)
from ..core.modes import wire_schedule
from .registry import AggregationContext, get_schedule

Axes = Sequence[str] | str

_is_policy = lambda x: hasattr(x, "mode") and hasattr(x, "schedule")


# ---------------------------------------------------------------------------
# leaf- and tree-level aggregation (registry-dispatched)
# ---------------------------------------------------------------------------

def aggregate_leaf(ctx: AggregationContext, g: jax.Array, policy,
                   ef: jax.Array | None = None):
    """Aggregate one gradient leaf under its admitted policy.

    Pure registry dispatch: the wire schedule (FP32/IDENTITY always ride
    psum) names the backend; the backend interprets the rest of the
    policy.  Returns ``(aggregate, new_ef)``.
    """
    backend = get_schedule(wire_schedule(policy.mode, policy.schedule))
    return backend.aggregate(ctx, g, policy, ef)


def aggregate_tree(ctx: AggregationContext, grads: Any, policies: Any,
                   ef_states: Any | None = None):
    """Aggregate a gradient pytree leaf-by-leaf under resolved policies.

    Runs inside a shard_map whose manual axes are ``ctx.dp_axes``.
    Error-feedback leaves hold a ``(1, *shape)`` local residual (globally
    ``(W, *shape)`` sharded over the DP axes); disabled leaves hold a
    scalar sentinel so the tree structure stays static across plans.
    Returns ``(aggregates, new_ef_states)`` mirroring the sentinel
    structure.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    p_leaves = treedef.flatten_up_to(policies)
    if ef_states is None:
        e_leaves = [None] * len(g_leaves)
    else:
        e_leaves = treedef.flatten_up_to(ef_states)

    agg, new_ef = [], []
    for g, pol, e in zip(g_leaves, p_leaves, e_leaves):
        use_ef = pol.error_feedback and e is not None and e.ndim > 0
        ef_in = e[0] if use_ef else None
        u, ef_out = aggregate_leaf(ctx, g, pol, ef=ef_in)
        agg.append(u)
        if e is None:
            new_ef.append(None)
        elif use_ef:
            new_ef.append(ef_out[None])
        else:
            new_ef.append(e)
    aggregates = jax.tree_util.tree_unflatten(treedef, agg)
    if ef_states is None:
        return aggregates, None
    return aggregates, jax.tree_util.tree_unflatten(treedef, new_ef)


# ---------------------------------------------------------------------------
# train-step state (owned here; re-exported by repro.runtime)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    ef: Any                    # error-feedback residuals (sentinel tree)
    step: jax.Array


class CompiledStep(NamedTuple):
    """One compiled train step and its I/O contracts.

    Tuple-compatible with the legacy ``build_train_step`` return value
    ``(jitted, state_shardings, batch_sharding, aux)``.
    """
    step_fn: Callable
    state_shardings: Any
    batch_sharding: Any
    aux: dict

    def __call__(self, state, batch):
        return self.step_fn(state, batch)


def dp_num_workers(mesh, dp_axes: Axes) -> int:
    axes = (dp_axes,) if isinstance(dp_axes, str) else dp_axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def _opt_shardings(optimizer, mu_sh, mesh):
    """OptState(step, mu, nu) sharding tree matching optimizer kind."""
    from ..optim.optimizers import OptState
    scalar = NamedSharding(mesh, P())
    has_nu = type(optimizer).__name__ == "AdamW"
    return OptState(step=scalar, mu=mu_sh, nu=mu_sh if has_nu else None)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class Fabric:
    """Aggregation-fabric session bound to one mesh and DP axis set.

    ``mesh=None`` gives a host-local session (virtual workers /
    single-process experiments); ``num_workers`` then defaults to 1 or
    may be forced (e.g. for abstract spec construction).
    """

    def __init__(self, mesh=None, dp_axes: Axes | None = None, *,
                 rules: GroupRules | None = None,
                 interpret: bool | None = None,
                 num_workers: int | None = None):
        self.mesh = mesh
        if dp_axes is None:
            dp_axes = ("data",) if mesh is not None else ()
        self.dp_axes = ((dp_axes,) if isinstance(dp_axes, str)
                        else tuple(dp_axes))
        self.rules = rules or GroupRules()
        self.interpret = interpret
        if num_workers is not None:
            self.num_workers = int(num_workers)
        elif mesh is not None:
            self.num_workers = dp_num_workers(mesh, self.dp_axes)
        else:
            self.num_workers = 1
        self._compiled: dict[tuple, CompiledStep] = {}

    # -- context / policy resolution ------------------------------------

    @property
    def context(self) -> AggregationContext:
        return AggregationContext(dp_axes=self.dp_axes,
                                  num_workers=self.num_workers,
                                  interpret=self.interpret, mesh=self.mesh)

    def resolve(self, params_like: Any, plan: AdmissionPlan,
                pspecs: Any | None = None) -> Any:
        """Params (+ optional PartitionSpec tree) -> LeafPolicy pytree."""
        return resolve_policies(params_like, plan, pspecs=pspecs,
                                rules=self.rules)

    def groups(self, params_like: Any) -> Any:
        return assign_groups(params_like, self.rules)

    def group_sizes(self, params_like: Any) -> dict[str, int]:
        return group_sizes(params_like, self.rules)

    # -- error-feedback state -------------------------------------------

    def init_ef(self, params: Any, policies: Any, dtype=jnp.float32) -> Any:
        """Global EF tree: ``(W, *shape)`` zeros where EF is on, scalar 0
        sentinel elsewhere (W = this session's worker count)."""
        local = init_ef_states(params, policies, dtype)
        w = self.num_workers
        return jax.tree.map(
            lambda e: (jnp.broadcast_to(e, (w,) + e.shape[1:])
                       if e.ndim > 0 else e), local)

    def ef_specs(self, policies: Any, pspecs: Any) -> Any:
        """PartitionSpecs for the EF tree (leading dim sharded over DP).

        The single implementation — both the step builder and external
        spec construction (launch/specs) derive EF shardings here.
        """
        pol_leaves, pol_def = jax.tree_util.tree_flatten(
            policies, is_leaf=_is_policy)
        spec_leaves = pol_def.flatten_up_to(pspecs)
        leaves = [
            P(self.dp_axes, *tuple(sp or P())) if pol.error_feedback else P()
            for pol, sp in zip(pol_leaves, spec_leaves)]
        return jax.tree_util.tree_unflatten(pol_def, leaves)

    # -- aggregation ----------------------------------------------------

    def aggregate(self, grads: Any, plan: AdmissionPlan | Any,
                  ef: Any | None = None, *, pspecs: Any | None = None):
        """Aggregate a gradient pytree under a plan (or resolved policies).

        Runs inside a shard_map whose manual axes are this session's
        ``dp_axes`` (the train step's gradient context); with
        ``dp_axes=()`` it is the host-local/virtual-worker path.  ``plan``
        may be an :class:`AdmissionPlan` (resolved against ``grads`` with
        this session's rules) or an already-resolved LeafPolicy pytree.
        Returns ``(aggregates, new_ef)``.
        """
        if isinstance(plan, AdmissionPlan):
            policies = self.resolve(grads, plan, pspecs=pspecs)
        else:
            policies = plan
        return aggregate_tree(self.context, grads, policies, ef_states=ef)

    # -- step builder ---------------------------------------------------

    def build_step(self, cfg, optimizer, plan: AdmissionPlan,
                   params_like: Any, *,
                   with_diagnostics: bool = False,
                   loss: Callable | None = None,
                   zero1: bool = True,
                   grad_accum: int = 1,
                   donate: bool = True) -> CompiledStep:
        """Compile one train step for a given admission plan.

        ``params_like``: a concrete or abstract (ShapeDtypeStruct) params
        tree — used only for structure/paths.  ``grad_accum`` splits the
        per-device batch into that many sequentially-scanned microbatches
        (activation memory / grad_accum, one aggregation per step —
        communication volume unchanged, overlap-friendly).
        """
        if self.mesh is None:
            raise ValueError("Fabric.build_step needs a mesh-bound session "
                             "(construct Fabric(mesh, dp_axes))")
        from ..models import loss_fn as model_loss_fn, param_pspecs
        from ..optim import optimizer_state_pspecs
        from ..runtime.shardings import sanitize_pspecs
        from ..core.diagnostics import group_cosines_from_mean

        mesh, dp, w = self.mesh, self.dp_axes, self.num_workers
        ctx = self.context
        pspecs = sanitize_pspecs(param_pspecs(cfg), params_like, mesh)
        policies = self.resolve(params_like, plan, pspecs=pspecs)
        groups = self.groups(params_like)
        ef_specs = self.ef_specs(policies, pspecs)
        lf = loss or (lambda p, b: model_loss_fn(p, cfg, b))

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(P(), P(dp), ef_specs),
            out_specs=(P(), P(), ef_specs),
            axis_names=frozenset(dp), check_vma=False)
        def _grad_agg(params, batch, ef):
            if grad_accum > 1:
                micro = jax.tree.map(
                    lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                        + x.shape[1:]), batch)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    lacc, gacc = carry
                    l, g = jax.value_and_grad(lf)(params, mb)
                    gacc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gacc, g)
                    return (lacc + l, gacc), None

                (lval, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), g0), micro)
                lval = lval / grad_accum
                grads = jax.tree.map(lambda x: x / grad_accum, grads)
            else:
                lval, grads = jax.value_and_grad(lf)(params, batch)
            agg, new_ef = aggregate_tree(ctx, grads, policies, ef_states=ef)
            lval = jax.lax.pmean(lval, dp)
            return lval, agg, new_ef

        def step_fn(state: TrainState, batch):
            lval, agg, new_ef = _grad_agg(state.params, batch, state.ef)
            metrics = {"loss": lval}
            if with_diagnostics:
                cos = group_cosines_from_mean(agg, groups)
                for g, d in sorted(cos.items()):
                    metrics[f"cos/{g}/gbinary"] = d["gbinary"]
                    metrics[f"cos/{g}/gternary"] = d["gternary"]
            gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                              for x in jax.tree.leaves(agg)))
            metrics["agg_norm"] = gn
            new_params, new_opt = optimizer.apply(state.params, agg, state.opt)
            return (TrainState(params=new_params, opt=new_opt, ef=new_ef,
                               step=state.step + 1), metrics)

        # shardings for explicit jit I/O (also consumed by the dry-run)
        param_sh = _named(mesh, pspecs)
        opt_specs = optimizer_state_pspecs(pspecs, params_like, dp_axes=dp,
                                           dp_size=w, zero1=zero1)
        mu_sh = _named(mesh, opt_specs)
        state_shardings = TrainState(
            params=param_sh,
            opt=_opt_shardings(optimizer, mu_sh, mesh),
            ef=_named(mesh, ef_specs),
            step=NamedSharding(mesh, P()))
        batch_sharding = NamedSharding(mesh, P(dp))

        jitted = jax.jit(
            step_fn,
            in_shardings=(state_shardings, batch_sharding),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else ())
        aux = {"policies": policies, "groups": groups, "num_workers": w,
               "ef_specs": ef_specs, "pspecs": pspecs}
        return CompiledStep(jitted, state_shardings, batch_sharding, aux)

    # -- per-plan-signature jit cache -----------------------------------

    def step_for(self, cfg, optimizer, plan: AdmissionPlan,
                 params_like: Any, *,
                 with_diagnostics: bool = False,
                 loss: Callable | None = None,
                 zero1: bool = True,
                 grad_accum: int = 1) -> CompiledStep:
        """Cached :meth:`build_step` — one compiled step per plan
        signature (the XLA analogue of the controller mode latch).

        The key also covers ``cfg``/``optimizer``/``loss`` (hashable
        frozen dataclasses / callables), so several Trainers may safely
        share one session without cross-model cache hits.
        """
        key = (plan.signature(), with_diagnostics, zero1, grad_accum,
               cfg, optimizer, loss)
        if key not in self._compiled:
            self._compiled[key] = self.build_step(
                cfg, optimizer, plan, params_like,
                with_diagnostics=with_diagnostics, loss=loss, zero1=zero1,
                grad_accum=grad_accum)
        return self._compiled[key]

    def clear_cache(self) -> None:
        self._compiled.clear()

    def __repr__(self) -> str:
        return (f"Fabric(dp_axes={self.dp_axes}, "
                f"num_workers={self.num_workers}, "
                f"mesh={'set' if self.mesh is not None else None})")
