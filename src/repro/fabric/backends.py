"""Built-in schedule backends: the paper's collectives behind the registry.

Each backend wraps one of the core collectives (:mod:`repro.core.lowbit`)
in the uniform ``aggregate(ctx, g, policy, ef)`` signature.  The Section-9
baselines (MajoritySignSGD, SignOfMean) are registered too, so experiment
plans can select them by name exactly like the production schedules.

Backends are *codec-parametric*: the transport never branches on a mode
enum — it resolves the policy's codec (:mod:`repro.fabric.codecs`) and
asks it for encode/decode (mean transports), the zero gate (vote
transports), and the payload bytes (wire accounting).  A registered
codec therefore rides every compatible transport without any edit here.

All built-ins are **fusable**: they additionally implement
``aggregate_flat(ctx, flat, codec, gate=...)`` over a 1-D bucket
payload, which is what the bucketed aggregation path
(:func:`repro.fabric.session.aggregate_tree_bucketed`) calls — one
collective launch per 32 MiB bucket instead of one per gradient leaf.
``threads_ef`` marks the transports able to carry error feedback (the
codec's own ``threads_ef`` flag must agree); the bucket layer
injects/updates EF residuals per leaf around the fused collective so EF
semantics stay bit-identical to the per-leaf path.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.lowbit import (fp32_allreduce, lowbit_packed_a2a,
                           lowbit_vote_psum, sign_of_mean)
from ..core.modes import Schedule
from .codecs import get_codec, resolve_leaf_gate_mask, ring_wire_bytes
from .registry import AggregationContext, register_schedule


@register_schedule(Schedule.PSUM, "fp32")
class Fp32AllreduceBackend:
    """Mean transport via XLA psum — the paper's bypass / calibration path.

    Mean-reduction codecs plug in around the collective: the per-worker
    payload is ``codec.encode(g)``, the psum averages it, and
    ``codec.decode`` runs on the mean (both identity for the FP32 and
    IDENTITY codecs, hence bit-identical to the pre-codec path).
    """

    name = "psum"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        u = codec.decode(ctx, fp32_allreduce(codec.encode(ctx, g),
                                             ctx.dp_axes))
        return u, ef

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        return codec.decode(ctx, fp32_allreduce(codec.encode(ctx, flat),
                                                ctx.dp_axes))

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # ring reduce-scatter + all-gather of the codec's wire payload
        return ring_wire_bytes(get_codec(mode).payload_bytes(n_elements),
                               num_workers)


@register_schedule(Schedule.VOTE_PSUM, "majority_sign_sgd")
class VotePsumBackend:
    """Dense int8 sign votes + one psum (works on any sharding).

    Registered under ``majority_sign_sgd`` too: the software baseline is
    update-rule-identical to G-Binary on this schedule (paper Section 9).
    The codec contributes the majority-stage gate: ``codec.gated``
    selects the ternary leg, and ``codec.leaf_gate_mask`` may supply an
    explicit keep pattern overriding the built-in 2-of-3 one.
    """

    name = "vote_psum"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        mask = resolve_leaf_gate_mask(codec, g.shape, policy.gate_phase)
        gate = None if mask is None else \
            jnp.asarray(mask, g.dtype).reshape(g.shape)
        return lowbit_vote_psum(
            g, ctx.dp_axes, ctx.num_workers, ternary=codec.gated,
            gate_phase=policy.gate_phase, gate=gate, ef=ef)

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        # gate.vector builds the concatenated per-leaf pattern on device
        # (iota + mod), avoiding a bucket-sized host constant per step
        gv = None if gate is None else gate.vector(jnp.float32)
        u, _ = lowbit_vote_psum(flat, ctx.dp_axes, ctx.num_workers,
                                ternary=codec.gated, gate=gv)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        """Models the paper's logical 1-byte vote payload.

        The XLA realization widens the psum operand to int32 to keep the
        vote margin exact for W >= 128 (see ``lowbit_vote_psum``), so
        bytes actually crossing ICI under this software schedule are
        4x this figure; a controller-side popcount (or a staged int8
        reduce) moves the modeled amount.
        """
        return ring_wire_bytes(1.0 * n_elements, num_workers)


@register_schedule(Schedule.PACKED_A2A)
class PackedA2ABackend:
    """The controller schedule: pack -> all_to_all -> PopCount -> gather."""

    name = "packed_a2a"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        # a custom leaf gate packs into gate words exactly like the fused
        # path, so both vote transports zero the same elements (the
        # packed path needs a fully local payload for gate masks)
        return lowbit_packed_a2a(
            g, ctx.dp_axes, ctx.num_workers,
            model_spec=getattr(policy, "model_spec", None),
            ternary=codec.gated, gate_phase=policy.gate_phase,
            gate_mask=resolve_leaf_gate_mask(codec, g.shape,
                                             policy.gate_phase),
            ef=ef, interpret=ctx.interpret)

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        # the packed schedule needs the host mask to pack gate words
        # (1 bit/element once packed — see gate_words_from_mask)
        mask = None if gate is None else gate.mask()
        u, _ = lowbit_packed_a2a(flat, ctx.dp_axes, ctx.num_workers,
                                 ternary=codec.gated, gate_mask=mask,
                                 interpret=ctx.interpret)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # all_to_all of packed signs + all-gather of sign+mask words
        return (ring_wire_bytes(n_elements / 8.0, num_workers, trips=1.0)
                + ring_wire_bytes(n_elements / 4.0, num_workers, trips=1.0))


@register_schedule("sign_of_mean")
class SignOfMeanBackend:
    """Sign *after* the FP32 mean — optimizer reference, FP32 wire cost."""

    name = "sign_of_mean"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return sign_of_mean(g, ctx.dp_axes), ef

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        return sign_of_mean(flat, ctx.dp_axes)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # the full-precision reduction has already happened: FP32 wire
        # cost regardless of the nominal codec (paper Section 9) —
        # priced like the psum transport's fp32 payload, ignoring the
        # legacy dtype_bytes knob for the same reason it does
        return ring_wire_bytes(get_codec("fp32").payload_bytes(n_elements),
                               num_workers)
