"""Built-in schedule backends: the paper's collectives behind the registry.

Each backend wraps one of the core collectives (:mod:`repro.core.lowbit`)
in the uniform ``aggregate(ctx, g, policy, ef)`` signature.  The Section-9
baselines (MajoritySignSGD, SignOfMean) are registered too, so experiment
plans can select them by name exactly like the production schedules.

All built-ins are **fusable**: they additionally implement
``aggregate_flat(ctx, flat, ternary=..., gate=...)`` over a 1-D
bucket payload, which is what the bucketed aggregation path
(:func:`repro.fabric.session.aggregate_tree_bucketed`) calls — one
collective launch per 32 MiB bucket instead of one per gradient leaf.
``threads_ef`` marks the backends that consume error feedback; the bucket
layer injects/updates EF residuals per leaf around the fused collective
so EF semantics stay bit-identical to the per-leaf path.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.lowbit import (fp32_allreduce, lowbit_packed_a2a,
                           lowbit_vote_psum, sign_of_mean)
from ..core.modes import AggregationMode, Schedule
from .registry import AggregationContext, register_schedule


def _ternary(policy) -> bool:
    return AggregationMode(policy.mode) == AggregationMode.G_TERNARY


@register_schedule(Schedule.PSUM, "fp32")
class Fp32AllreduceBackend:
    """FP32 mean via XLA psum — the paper's bypass / calibration path."""

    name = "psum"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return fp32_allreduce(g, ctx.dp_axes), ef

    def aggregate_flat(self, ctx: AggregationContext, flat, *,
                       ternary: bool = False, gate=None):
        return fp32_allreduce(flat, ctx.dp_axes)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return 2.0 * f * dtype_bytes * n_elements


@register_schedule(Schedule.VOTE_PSUM, "majority_sign_sgd")
class VotePsumBackend:
    """Dense int8 sign votes + one psum (works on any sharding).

    Registered under ``majority_sign_sgd`` too: the software baseline is
    update-rule-identical to G-Binary on this schedule (paper Section 9).
    """

    name = "vote_psum"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return lowbit_vote_psum(
            g, ctx.dp_axes, ctx.num_workers, ternary=_ternary(policy),
            gate_phase=policy.gate_phase, ef=ef)

    def aggregate_flat(self, ctx: AggregationContext, flat, *,
                       ternary: bool = False, gate=None):
        # gate.vector builds the concatenated per-leaf pattern on device
        # (iota + mod), avoiding a bucket-sized host constant per step
        gv = None if gate is None else gate.vector(jnp.float32)
        u, _ = lowbit_vote_psum(flat, ctx.dp_axes, ctx.num_workers,
                                ternary=ternary, gate=gv)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        """Models the paper's logical 1-byte vote payload.

        The XLA realization widens the psum operand to int32 to keep the
        vote margin exact for W >= 128 (see ``lowbit_vote_psum``), so
        bytes actually crossing ICI under this software schedule are
        4x this figure; a controller-side popcount (or a staged int8
        reduce) moves the modeled amount.
        """
        f = (num_workers - 1) / num_workers
        return 2.0 * f * 1.0 * n_elements


@register_schedule(Schedule.PACKED_A2A)
class PackedA2ABackend:
    """The controller schedule: pack -> all_to_all -> PopCount -> gather."""

    name = "packed_a2a"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return lowbit_packed_a2a(
            g, ctx.dp_axes, ctx.num_workers,
            model_spec=getattr(policy, "model_spec", None),
            ternary=_ternary(policy), gate_phase=policy.gate_phase, ef=ef,
            interpret=ctx.interpret)

    def aggregate_flat(self, ctx: AggregationContext, flat, *,
                       ternary: bool = False, gate=None):
        # the packed schedule needs the host mask to pack gate words
        # (1 bit/element once packed — see gate_words_from_mask)
        mask = None if gate is None else gate.mask()
        u, _ = lowbit_packed_a2a(flat, ctx.dp_axes, ctx.num_workers,
                                 ternary=ternary, gate_mask=mask,
                                 interpret=ctx.interpret)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return f * (n_elements / 8.0) + f * (n_elements / 4.0)


@register_schedule("sign_of_mean")
class SignOfMeanBackend:
    """Sign *after* the FP32 mean — optimizer reference, FP32 wire cost."""

    name = "sign_of_mean"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return sign_of_mean(g, ctx.dp_axes), ef

    def aggregate_flat(self, ctx: AggregationContext, flat, *,
                       ternary: bool = False, gate=None):
        return sign_of_mean(flat, ctx.dp_axes)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return 2.0 * f * dtype_bytes * n_elements
