"""Built-in schedule backends: the paper's collectives behind the registry.

Each backend wraps one of the core collectives (:mod:`repro.core.lowbit`)
in the uniform ``aggregate(ctx, g, policy, ef)`` signature.  The Section-9
baselines (MajoritySignSGD, SignOfMean) are registered too, so experiment
plans can select them by name exactly like the production schedules.
"""
from __future__ import annotations

from ..core.lowbit import (fp32_allreduce, lowbit_packed_a2a,
                           lowbit_vote_psum, sign_of_mean)
from ..core.modes import AggregationMode, Schedule
from .registry import AggregationContext, register_schedule


def _ternary(policy) -> bool:
    return AggregationMode(policy.mode) == AggregationMode.G_TERNARY


@register_schedule(Schedule.PSUM, "fp32")
class Fp32AllreduceBackend:
    """FP32 mean via XLA psum — the paper's bypass / calibration path."""

    name = "psum"

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return fp32_allreduce(g, ctx.dp_axes), ef

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return 2.0 * f * dtype_bytes * n_elements


@register_schedule(Schedule.VOTE_PSUM, "majority_sign_sgd")
class VotePsumBackend:
    """Dense int8 sign votes + one psum (works on any sharding).

    Registered under ``majority_sign_sgd`` too: the software baseline is
    update-rule-identical to G-Binary on this schedule (paper Section 9).
    """

    name = "vote_psum"

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return lowbit_vote_psum(
            g, ctx.dp_axes, ctx.num_workers, ternary=_ternary(policy),
            gate_phase=policy.gate_phase, ef=ef)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return 2.0 * f * 1.0 * n_elements


@register_schedule(Schedule.PACKED_A2A)
class PackedA2ABackend:
    """The controller schedule: pack -> all_to_all -> PopCount -> gather."""

    name = "packed_a2a"

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return lowbit_packed_a2a(
            g, ctx.dp_axes, ctx.num_workers,
            model_spec=getattr(policy, "model_spec", None),
            ternary=_ternary(policy), gate_phase=policy.gate_phase, ef=ef,
            interpret=ctx.interpret)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return f * (n_elements / 8.0) + f * (n_elements / 4.0)


@register_schedule("sign_of_mean")
class SignOfMeanBackend:
    """Sign *after* the FP32 mean — optimizer reference, FP32 wire cost."""

    name = "sign_of_mean"

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return sign_of_mean(g, ctx.dp_axes), ef

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        f = (num_workers - 1) / num_workers
        return 2.0 * f * dtype_bytes * n_elements
