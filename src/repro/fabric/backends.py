"""Built-in schedule backends: the paper's collectives behind the registry.

Each backend wraps one of the core collectives (:mod:`repro.core.lowbit`)
in the uniform ``aggregate(ctx, g, policy, ef)`` signature.  The Section-9
baselines (MajoritySignSGD, SignOfMean) are registered too, so experiment
plans can select them by name exactly like the production schedules.

Backends are *codec-parametric*: the transport never branches on a mode
enum — it resolves the policy's codec (:mod:`repro.fabric.codecs`) and
asks it for encode/decode (mean transports), the zero gate (vote
transports), and the payload bytes (wire accounting).  A registered
codec therefore rides every compatible transport without any edit here.

All built-ins are **fusable**: they additionally implement
``aggregate_flat(ctx, flat, codec, gate=...)`` over a 1-D bucket
payload, which is what the bucketed aggregation path
(:func:`repro.fabric.session.aggregate_tree_bucketed`) calls — one
collective launch per 32 MiB bucket instead of one per gradient leaf.
``threads_ef`` marks the transports able to carry error feedback (the
codec's own ``threads_ef`` flag must agree); the bucket layer
injects/updates EF residuals per leaf around the fused collective so EF
semantics stay bit-identical to the per-leaf path.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.lowbit import (LeafPolicy, _ef_inject, _ef_update,
                           fp32_allreduce, lowbit_packed_a2a,
                           lowbit_vote_psum, sign_of_mean)
from ..core.modes import Schedule, wire_schedule
from .codecs import get_codec, resolve_leaf_gate_mask, ring_wire_bytes
from .registry import AggregationContext, get_schedule, register_schedule


def _codec_kernels(ctx: AggregationContext, codec):
    """The codec's fused kernel set, honoring the session opt-out.

    Returns None when the session pinned the staged path
    (``fused_kernels=False``) or the codec brings no kernels — both
    bit-identical to the fused realization by the KernelSet contract.
    """
    if not getattr(ctx, "fused_kernels", True):
        return None
    hook = getattr(codec, "pallas_kernels", None)
    return None if hook is None else hook()


@register_schedule(Schedule.PSUM, "fp32")
class Fp32AllreduceBackend:
    """Mean transport via XLA psum — the paper's bypass / calibration path.

    Mean-reduction codecs plug in around the collective: the per-worker
    payload is ``codec.encode(g)``, the psum averages it, and
    ``codec.decode`` runs on the mean (both identity for the FP32 and
    IDENTITY codecs, hence bit-identical to the pre-codec path).
    """

    name = "psum"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        ks = _codec_kernels(ctx, codec)
        if ks is not None and ks.means:
            # fused encode kernel on the flat payload (bit-identical to
            # codec.encode — the KernelSet contract), decode on the mean
            flat = g.reshape(-1)
            enc = ks.encode_flat(flat, interpret=ctx.interpret)
            u = fp32_allreduce(enc.reshape(g.shape), ctx.dp_axes)
            u = ks.decode_apply(u.reshape(-1), interpret=ctx.interpret)
            return codec.decode(ctx, u.reshape(g.shape)), ef
        u = codec.decode(ctx, fp32_allreduce(codec.encode(ctx, g),
                                             ctx.dp_axes))
        return u, ef

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        ks = _codec_kernels(ctx, codec)
        if ks is not None and ks.means:
            enc = ks.encode_flat(flat, interpret=ctx.interpret)
            u = ks.decode_apply(fp32_allreduce(enc, ctx.dp_axes),
                                interpret=ctx.interpret)
            return codec.decode(ctx, u)
        return codec.decode(ctx, fp32_allreduce(codec.encode(ctx, flat),
                                                ctx.dp_axes))

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # ring reduce-scatter + all-gather of the codec's wire payload
        return ring_wire_bytes(get_codec(mode).payload_bytes(n_elements),
                               num_workers)


@register_schedule(Schedule.VOTE_PSUM, "majority_sign_sgd")
class VotePsumBackend:
    """Dense int8 sign votes + one psum (works on any sharding).

    Registered under ``majority_sign_sgd`` too: the software baseline is
    update-rule-identical to G-Binary on this schedule (paper Section 9).
    The codec contributes the majority-stage gate: ``codec.gated``
    selects the ternary leg, and ``codec.leaf_gate_mask`` may supply an
    explicit keep pattern overriding the built-in 2-of-3 one.

    This transport deliberately ignores codec kernel sets: its dense
    int8 votes have no packed word-plane representation to fuse — the
    psum *is* the combine, and XLA already fuses the elementwise
    vote/majority stages around it.  (``fused_kernels`` is therefore a
    no-op here, trivially bit-identical.)
    """

    name = "vote_psum"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        mask = resolve_leaf_gate_mask(codec, g.shape, policy.gate_phase)
        gate = None if mask is None else \
            jnp.asarray(mask, g.dtype).reshape(g.shape)
        return lowbit_vote_psum(
            g, ctx.dp_axes, ctx.num_workers, ternary=codec.gated,
            gate_phase=policy.gate_phase, gate=gate, ef=ef)

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        # gate.vector builds the concatenated per-leaf pattern on device
        # (iota + mod), avoiding a bucket-sized host constant per step
        gv = None if gate is None else gate.vector(jnp.float32)
        u, _ = lowbit_vote_psum(flat, ctx.dp_axes, ctx.num_workers,
                                ternary=codec.gated, gate=gv)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        """Models the paper's logical 1-byte vote payload.

        The XLA realization widens the psum operand to int32 to keep the
        vote margin exact for W >= 128 (see ``lowbit_vote_psum``), so
        bytes actually crossing ICI under this software schedule are
        4x this figure; a controller-side popcount (or a staged int8
        reduce) moves the modeled amount.
        """
        return ring_wire_bytes(1.0 * n_elements, num_workers)


@register_schedule(Schedule.PACKED_A2A)
class PackedA2ABackend:
    """The controller schedule: pack -> all_to_all -> PopCount -> gather."""

    name = "packed_a2a"
    fusable = True
    threads_ef = True

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        ks = _codec_kernels(ctx, codec)
        # a custom leaf gate packs into gate words exactly like the fused
        # path, so both vote transports zero the same elements (the
        # packed path needs a fully local payload for gate masks)
        return lowbit_packed_a2a(
            g, ctx.dp_axes, ctx.num_workers,
            model_spec=getattr(policy, "model_spec", None),
            ternary=codec.gated, gate_phase=policy.gate_phase,
            gate_mask=resolve_leaf_gate_mask(codec, g.shape,
                                             policy.gate_phase),
            ef=ef, interpret=ctx.interpret,
            kernels=ks if ks is not None and ks.votes else None)

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        # the packed schedule needs the host mask to pack gate words
        # (1 bit/element once packed — see gate_words_from_mask)
        mask = None if gate is None else gate.mask()
        ks = _codec_kernels(ctx, codec)
        u, _ = lowbit_packed_a2a(flat, ctx.dp_axes, ctx.num_workers,
                                 ternary=codec.gated, gate_mask=mask,
                                 interpret=ctx.interpret,
                                 kernels=ks if ks is not None and ks.votes
                                 else None)
        return u

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # all_to_all of packed signs + all-gather of sign+mask words
        return (ring_wire_bytes(n_elements / 8.0, num_workers, trips=1.0)
                + ring_wire_bytes(n_elements / 4.0, num_workers, trips=1.0))


@register_schedule("sign_of_mean")
class SignOfMeanBackend:
    """Sign *after* the FP32 mean — optimizer reference, FP32 wire cost."""

    name = "sign_of_mean"
    fusable = True
    threads_ef = False

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        return sign_of_mean(g, ctx.dp_axes), ef

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        return sign_of_mean(flat, ctx.dp_axes)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        # the full-precision reduction has already happened: FP32 wire
        # cost regardless of the nominal codec (paper Section 9) —
        # priced like the psum transport's fp32 payload, ignoring the
        # legacy dtype_bytes knob for the same reason it does
        return ring_wire_bytes(get_codec("fp32").payload_bytes(n_elements),
                               num_workers)


def _resolve_hop(hop):
    """(backend, codec, wire-schedule name) for one HopSpec leg."""
    hcodec = get_codec(hop.codec)
    sched = wire_schedule(hop.codec,
                          hop.schedule or hcodec.default_schedule)
    return get_schedule(sched), hcodec, sched


@register_schedule("hierarchical")
class HierarchicalBackend:
    """Per-hop-recompressing route: compose the hops' own transports.

    The policy's codec must be a :class:`~repro.fabric.hierarchy.
    HierarchicalCodec` (it carries the :class:`HopPlan`); each hop leg
    dispatches to the hop codec's registered transport over that hop's
    worker group only, so the gradient is re-encoded at every hop —
    intra-node FP32 mean first, then the compressed inter-node vote on
    the already-averaged values (DynamiQ's per-hop recompression shape).

    Hop 0 runs over the *innermost* worker group.  With one
    data-parallel axis, only 1-hop plans are runnable and the backend is
    bit-identical to the flat backend of the plan's single codec; with
    one axis per hop (``dp_axes=("outer", "inner")``), hop ``i`` reduces
    over axis ``-1 - i``.

    EF is threaded *around* the whole route (inject before hop 0, update
    the residual from the injected gradient after the last hop) — the
    exact external pattern the bucket layer uses, so per-leaf, fused,
    and flat-backend EF all stay bit-identical.

    Fused kernels resolve *per hop*: each leg dispatches through its hop
    codec's own transport with a context that preserves the session's
    ``fused_kernels`` flag (``dataclasses.replace`` below), so e.g. a
    packed gbinary backbone hop runs the fused vote chain while the
    intra-node fp32 hop stays on plain psum — no extra wiring here.
    """

    name = "hierarchical"
    fusable = True
    threads_ef = True

    @staticmethod
    def _plan_of(codec):
        plan = getattr(codec, "plan", None)
        if plan is None:
            raise TypeError(
                f"codec {codec.name!r} rides the hierarchical schedule but "
                f"carries no HopPlan; register it via "
                f"repro.fabric.register_hop_plan")
        return plan

    @staticmethod
    def _hop_contexts(ctx: AggregationContext, plan):
        sizes = plan.group_sizes(ctx.num_workers)
        h = len(plan.hops)
        if not ctx.dp_axes:
            axes = [()] * h
        elif h == 1:
            axes = [tuple(ctx.dp_axes)]
        elif h == len(ctx.dp_axes):
            # hop 0 = innermost (last) mesh axis, hop i = axis -1 - i
            axes = [(ctx.dp_axes[-1 - i],) for i in range(h)]
        else:
            raise ValueError(
                f"hop plan {plan.name!r} has {h} hops but the session has "
                f"{len(ctx.dp_axes)} data-parallel axes "
                f"({ctx.dp_axes!r}); map one axis per hop (innermost "
                f"axis = hop 0) or use a 1-hop plan")
        return [dataclasses.replace(ctx, dp_axes=a, num_workers=s)
                for a, s in zip(axes, sizes)]

    def aggregate(self, ctx: AggregationContext, g, policy, ef=None):
        codec = get_codec(policy.mode)
        plan = self._plan_of(codec)
        use_ef = (ef is not None and policy.error_feedback
                  and codec.threads_ef)
        g_eff, _ = _ef_inject(g, ef if use_ef else None)
        u = g_eff
        for hop, hop_ctx in zip(plan.hops, self._hop_contexts(ctx, plan)):
            backend, _, sched = _resolve_hop(hop)
            hop_policy = LeafPolicy(
                mode=hop.codec, schedule=sched,
                model_spec=getattr(policy, "model_spec", None),
                gate_phase=policy.gate_phase, error_feedback=False)
            u, _ = backend.aggregate(hop_ctx, u, hop_policy, None)
        new_ef = _ef_update(g_eff, ef) if use_ef else ef
        return u, new_ef

    def aggregate_flat(self, ctx: AggregationContext, flat, codec, *,
                       gate=None):
        plan = self._plan_of(codec)
        for hop, hop_ctx in zip(plan.hops, self._hop_contexts(ctx, plan)):
            backend, hcodec, _ = _resolve_hop(hop)
            # the zero gate belongs to the gated hop's majority stage;
            # ungated hops (e.g. the intra-node fp32 mean) never see it
            flat = backend.aggregate_flat(
                hop_ctx, flat, hcodec,
                gate=gate if hcodec.gated else None)
        return flat

    def hop_wire_bytes_per_device(self, n_elements: int, mode,
                                  num_workers: int,
                                  dtype_bytes: int = 4) -> tuple:
        """Per-leg wire bytes: each hop's own model at its group size."""
        codec = get_codec(mode)
        plan = self._plan_of(codec)
        legs = []
        for hop, size in zip(plan.hops, plan.group_sizes(num_workers)):
            backend, _, _ = _resolve_hop(hop)
            legs.append(backend.wire_bytes_per_device(
                n_elements, hop.codec, size, dtype_bytes=dtype_bytes))
        return tuple(legs)

    def wire_bytes_per_device(self, n_elements: int, mode, num_workers: int,
                              dtype_bytes: int = 4) -> float:
        return float(sum(self.hop_wire_bytes_per_device(
            n_elements, mode, num_workers, dtype_bytes=dtype_bytes)))
