"""NEURON-Fabric session API: one control surface over aggregation.

  * :mod:`codecs`   — :class:`Codec` protocol + the ``@register_codec``
    registry: *what bits go on the wire* (built-ins ``identity``,
    ``fp32``, ``gbinary``, ``gternary``; :mod:`extra_codecs` adds
    ``int4`` and ``topk`` through the same public seam);
  * :mod:`registry` — :class:`ScheduleBackend` protocol + the
    ``@register_schedule`` registry: *how the bytes move* (the
    extension seam for new collectives);
  * :mod:`backends` — built-in codec-parametric transports:
    ``psum``/``fp32``, ``vote_psum``, ``packed_a2a``, the
    per-hop-recompressing ``hierarchical`` route, plus the Section-9
    baselines;
  * :mod:`hierarchy` — :class:`HopPlan`/:class:`HopSpec` hop routes and
    ``register_hop_plan`` (built-ins ``hier_fp32_gbinary`` /
    ``hier_fp32_gternary`` / ``hier_fp32_int4``);
  * :mod:`session`  — the :class:`Fabric` session object owning worker
    count, policy resolution, EF state, registry dispatch, and the
    per-plan jit cache;
  * :mod:`control`  — the admission-control plane: :class:`Controller`
    protocol + ``@register_controller`` registry (built-ins ``"paper"``,
    ``"static"``, ``"fp32"``), the typed :class:`Telemetry` record, and
    the :class:`PolicyProgram` phase machine.

Quick use::

    fabric = Fabric(mesh, dp_axes=("data",))
    fabric.attach_controller("paper", warmup_steps=50)     # admission policy
    step = fabric.step_for(cfg, optimizer, plan, params)   # cached jit
    agg, ef = fabric.aggregate(grads, plan, ef)            # in shard_map
"""
from .codecs import (Codec, CodecLane, GradientCodec, MaskGate,
                     available_codecs, get_codec, register_codec,
                     resolve_leaf_gate_mask, ring_wire_bytes,
                     unregister_codec)
from .registry import (AggregationContext, ScheduleBackend,
                       available_schedules, get_schedule, register_schedule,
                       unregister_schedule)
from . import backends as _backends          # registers the built-ins
from . import extra_codecs as _extra_codecs  # registers int4 / topk
from .hierarchy import (HierarchicalCodec, HopPlan, HopSpec,
                        register_hop_plan, unregister_hop_plan)
from .session import (CompiledStep, Fabric, TrainState, aggregate_leaf,
                      aggregate_tree, aggregate_tree_bucketed,
                      dp_num_workers, layout_kernel_stats)
from .control import (Controller, ControlEvent, FP32Controller,
                      PaperController, Phase, PolicyProgram,
                      StaticController, Telemetry, available_controllers,
                      get_controller, make_controller, plan_from_jsonable,
                      plan_presets, plan_to_jsonable, register_controller,
                      unregister_controller)

__all__ = [
    "Codec", "CodecLane", "GradientCodec", "MaskGate", "available_codecs",
    "get_codec", "register_codec", "resolve_leaf_gate_mask",
    "ring_wire_bytes", "unregister_codec",
    "AggregationContext", "ScheduleBackend", "available_schedules",
    "get_schedule", "register_schedule", "unregister_schedule",
    "HierarchicalCodec", "HopPlan", "HopSpec", "register_hop_plan",
    "unregister_hop_plan",
    "CompiledStep", "Fabric", "TrainState", "aggregate_leaf",
    "aggregate_tree", "aggregate_tree_bucketed", "dp_num_workers",
    "layout_kernel_stats",
    "Controller", "ControlEvent", "FP32Controller", "PaperController",
    "Phase", "PolicyProgram", "StaticController", "Telemetry",
    "available_controllers", "get_controller", "make_controller",
    "plan_from_jsonable", "plan_presets", "plan_to_jsonable",
    "register_controller", "unregister_controller",
]
