"""Schedule-backend registry: the Fabric extension seam.

A *schedule backend* owns the wire-level algorithm that realizes an
aggregation mode on the mesh (how bytes move: psum, packed all_to_all,
a future DynamiQ-style multi-hop compressed all-reduce, a CXL-CCL-style
pooled-memory collective, ...).  Backends register under a string name
and are resolved by :func:`get_schedule`; core dispatch never hardcodes
a schedule, so new collectives plug in without editing core files:

    from repro.fabric import register_schedule

    @register_schedule("my_sched")
    class MySched:
        name = "my_sched"
        def aggregate(self, ctx, g, policy, ef=None):
            return my_collective(g, ctx.dp_axes), ef

    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule="my_sched")

Every backend sees one uniform signature: ``aggregate(ctx, g, policy,
ef)`` where ``ctx`` (:class:`AggregationContext`) carries the session
facts (dp_axes / num_workers / interpret) that the old free functions
each re-threaded by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, runtime_checkable

from ..core.modes import schedule_name
from ..core.registry import Registry

Axes = Sequence[str] | str


@dataclasses.dataclass(frozen=True)
class AggregationContext:
    """Session facts a backend needs to run its collective.

    ``dp_axes``     — manual mesh axes the aggregation reduces over;
    ``num_workers`` — product of the dp-axis sizes (the paper's W);
    ``interpret``   — Pallas interpret-mode override for kernel backends;
    ``mesh``        — the owning mesh, when a backend needs topology
                      (None for host-local / virtual-worker use);
    ``fused_kernels`` — consult codecs' fused ``pallas_kernels()`` sets
                      (the session's ``fused_kernels=False`` opt-out
                      pins the staged pipeline; results are
                      bit-identical either way).
    """
    dp_axes: Any = ()
    num_workers: int = 1
    interpret: bool | None = None
    mesh: Any = None
    fused_kernels: bool = True


@runtime_checkable
class ScheduleBackend(Protocol):
    """Protocol every registered schedule backend implements.

    ``aggregate`` runs *inside* a shard_map whose manual axes are
    ``ctx.dp_axes`` and returns ``(aggregate, new_ef)``; backends that do
    not thread error feedback return ``ef`` unchanged.  Backends may
    additionally expose ``wire_bytes_per_device(n_elements, mode,
    num_workers, dtype_bytes)`` to participate in the traffic model.

    **Codecs.**  Backends are transport-only: the payload contract
    (encode/decode, reduction kind, gate, bits/element) lives on the
    policy's *codec* (:mod:`repro.fabric.codecs`).  Resolve it with
    ``get_codec(policy.mode)`` and consult its hooks instead of
    branching on a mode enum.

    **Bucket fusion (opt-in).**  A backend that sets ``fusable = True``
    must also implement

        aggregate_flat(ctx, flat, codec, *, gate=None)

    over a 1-D bucket payload (the concatenation of compatible leaves)
    and return the 1-D aggregate.  ``codec`` is the bucket's resolved
    :class:`~repro.fabric.codecs.Codec`; ``gate`` is the codec's bucket
    zero gate (e.g. :class:`~repro.core.buckets.BucketGate` carrying
    the concatenated per-leaf ternary gates; None for ungated codecs) —
    call ``gate.vector(dtype)`` for an on-device keep vector or
    ``gate.mask()`` for the host boolean array (packed-word schedules).
    ``threads_ef = True`` declares that the per-leaf ``aggregate``
    consumes error feedback; the bucket layer then injects/updates EF
    residuals per leaf around the fused collective — but only for
    codecs whose own ``threads_ef`` flag agrees (the backend's
    ``aggregate_flat`` never sees EF).  Backends without ``fusable``
    simply stay on the per-leaf path.
    """

    name: str

    def aggregate(self, ctx: AggregationContext, g: Any, policy: Any,
                  ef: Any | None = None) -> tuple[Any, Any | None]: ...


def _prepare_schedule(obj: Any, keys) -> ScheduleBackend:
    return obj() if isinstance(obj, type) else obj


#: backed by the shared generic :class:`repro.core.registry.Registry`
#: (one implementation of keys / duplicate check / override alias sweep
#: for every extension seam).
_REGISTRY = Registry("schedule backend", key_fn=schedule_name,
                     prepare=_prepare_schedule,
                     register_hint="@register_schedule({key!r})")


def register_schedule(name: Any, *aliases: Any, override: bool = False):
    """Class/instance decorator registering a backend under ``name``.

    Accepts a backend class (instantiated with no arguments) or a ready
    instance.  ``aliases`` register the same backend under extra names;
    re-registering an existing name raises unless ``override=True``,
    which replaces the named keys *and* removes any other aliases still
    bound to the replaced instances (a plan naming a stale alias must
    never silently resolve the old backend).
    """
    return _REGISTRY.register(name, *aliases, override=override)


def unregister_schedule(name: Any) -> None:
    """Remove a backend and every alias bound to the same instance
    (primarily for tests tearing down toy schedules)."""
    _REGISTRY.unregister(name)


def get_schedule(name: Any) -> ScheduleBackend:
    """Resolve a schedule name (str or Schedule enum) to its backend."""
    return _REGISTRY.get(name)


def available_schedules() -> tuple[str, ...]:
    return _REGISTRY.available()
