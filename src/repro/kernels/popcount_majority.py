"""Pallas TPU kernels: the NEURON-Fabric "controller datapath".

Two stages mirror the paper's five-cycle 512-bit aggregation pipeline
(Section 3, "Datapath"):

  * ``popcount_stack``  — sign unpacking/alignment + per-element PopCount
    across W workers' packed payloads (the XNOR/PopCount tree).
  * ``majority_decode`` — vote margin a = 2c - W, majority / ternary gating,
    and re-packing of the returned aggregate as a ternary packed pair
    (sign_words, mask_words).

The zero gate is an explicit packed operand so the same kernel serves
G-Binary (gate = all ones; zeros only on vote ties) and G-Ternary
(gate = the fixed 2-of-3 pattern from Section 2, or any policy mask).

TPU mapping notes: counts are int32, so any worker-group width W fits
(the int8 accumulator the datapath originally used capped groups at
W <= 127 and silently wrapped beyond); all tiles are (8k, 128) VREG-aligned;
the word <-> value fan-out of 32 is expressed as a sublane reduction /
broadcast so no Mosaic-unfriendly reshape crosses the lane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LANE, PACK
from .sign_pack import _pick_word_block


# ---------------------------------------------------------------------------
# popcount across workers
# ---------------------------------------------------------------------------

def _popcount_stack_kernel(packed_ref, out_ref, *, num_workers: int,
                           words_per_block: int):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        acc = jnp.zeros((PACK, LANE), jnp.int32)
        for w in range(num_workers):
            word = packed_ref[w, r:r + 1, :]                     # (1, LANE)
            bits = (jnp.broadcast_to(word, (PACK, LANE)) >> shifts) & jnp.uint32(1)
            acc = acc + bits.astype(jnp.int32)
        out_ref[r * PACK:(r + 1) * PACK, :] = acc


@functools.partial(jax.jit, static_argnames=("interpret", "block_words"))
def popcount_stack(packed: jax.Array, *, interpret: bool = False,
                   block_words: int | None = None) -> jax.Array:
    """(W, R, LANE) uint32 packed sign words -> (32 R, LANE) int32 vote counts."""
    w, r, lane = packed.shape
    assert lane == LANE
    wb = block_words or _pick_word_block(r, max_words=8)
    grid = (r // wb,)
    return pl.pallas_call(
        functools.partial(_popcount_stack_kernel, num_workers=w,
                          words_per_block=wb),
        out_shape=jax.ShapeDtypeStruct((r * PACK, LANE), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((w, wb, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(packed)


# ---------------------------------------------------------------------------
# majority / ternary decode of vote counts
# ---------------------------------------------------------------------------

def _majority_decode_kernel(counts_ref, gate_ref, sign_ref, mask_ref, *,
                            num_workers: int, words_per_block: int):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        c = counts_ref[r * PACK:(r + 1) * PACK, :].astype(jnp.int32)  # (32, LANE)
        a = 2 * c - num_workers                                        # vote margin
        sign_bits = (a > 0).astype(jnp.uint32)
        nz_bits = (a != 0).astype(jnp.uint32)
        sign_word = jnp.sum(sign_bits << shifts, axis=0, keepdims=True)
        mask_word = jnp.sum(nz_bits << shifts, axis=0, keepdims=True)
        gate = gate_ref[r:r + 1, :]
        sign_ref[r:r + 1, :] = sign_word.astype(jnp.uint32)
        mask_ref[r:r + 1, :] = (mask_word & gate).astype(jnp.uint32)


@functools.partial(jax.jit,
                   static_argnames=("num_workers", "interpret", "block_words"))
def majority_decode(counts: jax.Array, gate_words: jax.Array, *,
                    num_workers: int, interpret: bool = False,
                    block_words: int | None = None):
    """Vote counts (M, LANE) + packed gate (M//32, LANE) -> ternary packed pair.

    Returns (sign_words, mask_words), each (M // 32, LANE) uint32.
    mask bit = (2c != W) AND gate bit; sign bit = (2c > W).
    """
    m, lane = counts.shape
    assert lane == LANE and m % PACK == 0
    num_words = m // PACK
    assert gate_words.shape == (num_words, LANE)
    wb = block_words or _pick_word_block(num_words, max_words=8)
    grid = (num_words // wb,)
    out_shape = (jax.ShapeDtypeStruct((num_words, LANE), jnp.uint32),
                 jax.ShapeDtypeStruct((num_words, LANE), jnp.uint32))
    return pl.pallas_call(
        functools.partial(_majority_decode_kernel, num_workers=num_workers,
                          words_per_block=wb),
        out_shape=out_shape,
        grid=grid,
        in_specs=[pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((wb, LANE), lambda i: (i, 0))),
        interpret=interpret,
    )(counts, gate_words)
