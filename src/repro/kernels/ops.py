"""Public jit'd wrappers for the NEURON-Fabric controller-datapath kernels.

On TPU the Pallas kernels lower to Mosaic; on CPU (this container, and any
unit-test environment) they execute in ``interpret=True`` mode, which runs
the kernel body element-for-element and therefore validates the exact packed
semantics the hardware path would produce.

The wrappers also own the *canonical bucket layout* plumbing: arbitrary
flat buckets are zero-padded and reshaped to (M, 128) value planes before
the kernels see them (see kernels/ref.py for the layout contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .ref import LANE, PACK, padded_len, to_plane, from_plane
from .sign_pack import sign_pack as _sign_pack_pallas
from .popcount_majority import (popcount_stack as _popcount_pallas,
                                majority_decode as _majority_pallas)
from .apply_update import (unpack_ternary as _unpack_pallas,
                           apply_sign_update as _apply_pallas)

__all__ = [
    "interpret_default", "pack_signs", "popcount_stack", "majority_decode",
    "unpack_ternary", "apply_sign_update", "ternary_gate_words",
    "gate_words_from_mask", "to_plane", "from_plane", "padded_len",
    "LANE", "PACK",
]


@functools.cache
def interpret_default() -> bool:
    """Pallas interpret mode: True off-TPU (kernels are TPU-targeted)."""
    return jax.default_backend() != "tpu"


def _mode(interpret) -> str:
    """Dispatch: 'pallas' (TPU / interpret=False), 'interp' (interpret=True),
    'ref' (interpret=None off-TPU — pure-jnp oracle, identical bits, clean
    HLO for the dry-run analyses)."""
    if interpret is True:
        return "interp"
    if interpret is False:
        return "pallas"
    return "pallas" if not interpret_default() else "ref"


def pack_signs(plane: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Value plane (M, LANE) -> packed sign words (M // 32, LANE) uint32."""
    m = _mode(interpret)
    if m == "ref":
        return ref.sign_pack(plane)
    return _sign_pack_pallas(plane, interpret=(m == "interp"))


def popcount_stack(packed: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """(W, R, LANE) packed sign words -> (32 R, LANE) int32 vote counts."""
    m = _mode(interpret)
    if m == "ref":
        return ref.popcount_stack(packed)
    return _popcount_pallas(packed, interpret=(m == "interp"))


def majority_decode(counts: jax.Array, *, num_workers: int,
                    gate_words: jax.Array | None = None,
                    interpret: bool | None = None):
    """Vote counts -> ternary packed (sign_words, mask_words)."""
    if gate_words is None:
        r = counts.shape[0] // PACK
        gate_words = jnp.full((r, LANE), 0xFFFFFFFF, jnp.uint32)
    m = _mode(interpret)
    if m == "ref":
        return ref.majority_decode(counts, num_workers, gate_words)
    return _majority_pallas(counts, gate_words, num_workers=num_workers,
                            interpret=(m == "interp"))


def unpack_ternary(sign_words: jax.Array, mask_words: jax.Array, *,
                   dtype=jnp.float32, interpret: bool | None = None) -> jax.Array:
    """Ternary packed pair -> {-1, 0, +1} value plane."""
    m = _mode(interpret)
    if m == "ref":
        return ref.unpack_ternary(sign_words, mask_words, dtype=dtype)
    return _unpack_pallas(sign_words, mask_words, dtype=dtype,
                          interpret=(m == "interp"))


def apply_sign_update(param_plane: jax.Array, sign_words: jax.Array,
                      mask_words: jax.Array, scale, *,
                      interpret: bool | None = None) -> jax.Array:
    """Fused ``param - scale * decode(sign, mask)``."""
    m = _mode(interpret)
    if m == "ref":
        return ref.apply_sign_update(param_plane, sign_words, mask_words,
                                     scale)
    return _apply_pallas(param_plane, sign_words, mask_words,
                         jnp.asarray(scale, jnp.float32),
                         interpret=(m == "interp"))


def ternary_gate_words(num_rows: int, phase: int = 0) -> jax.Array:
    """Packed fixed 2-of-3 zero-gate pattern (Section 2 of the paper)."""
    return ref.ternary_gate_words(num_rows, phase)


def gate_words_from_mask(keep, pad_words: int | None = None) -> jax.Array:
    """Arbitrary flat keep mask -> packed gate word plane (host-side)."""
    return ref.gate_words_from_mask(keep, pad_words=pad_words)
