"""Pure-jnp reference oracles for the NEURON-Fabric controller datapath.

These mirror, bit-for-bit, the packed payload layout used by the Pallas
kernels.  All kernels operate on the *canonical bucket layout*:

    flat gradient bucket of N elements
      -> zero-padded to a multiple of LANE * 32
      -> reshaped to (M, LANE) with M a multiple of 32     ("value plane")
      -> sign words of shape (M // 32, LANE), uint32        ("word plane")

Bit ``b`` of word ``w[r, l]`` holds the sign of value ``v[32 * r + b, l]``
(1 = strictly positive, 0 = non-positive).  This is the TPU adaptation of
the paper's 512-bit CXL cache-line payload: one (8, 128) VREG row of uint32
words covers 8 * 128 * 32 = 32768 sign bits.

The paper's aggregation semantics (Section 2):

    b_{k,i} = 1{ sgn(g_{k,i}) > 0 }
    c_i     = PopCount(b_{0,i}, ..., b_{W-1,i})
    a_i     = 2 * c_i - W
    u_bin   = sgn(a_i)                  in {-1, 0, +1}
    u_ter   = m_i * u_bin               with zero gate m_i in {0, 1}

The returned aggregate is represented as a *ternary packed pair*
``(sign_words, mask_words)``: ``mask`` bit 0 means the element decodes to 0
(vote tie, or gated off); otherwise the ``sign`` bit selects +1 / -1.
G-Binary is the special case where the only zeros are vote ties.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LANE = 128          # TPU vector lane count; canonical last dim
PACK = 32           # sign bits per uint32 word
TILE = LANE * PACK  # elements covered by one word row


def padded_len(n: int) -> int:
    """Canonical padded length for an N-element bucket."""
    return ((n + TILE - 1) // TILE) * TILE


def to_plane(flat: jax.Array) -> jax.Array:
    """Flat (N,) -> canonical value plane (M, LANE), zero padded."""
    n = flat.shape[0]
    p = padded_len(n)
    if p != n:
        flat = jnp.pad(flat, (0, p - n))
    return flat.reshape(p // LANE, LANE)


def from_plane(plane: jax.Array, n: int) -> jax.Array:
    """Canonical value plane -> flat (N,), dropping padding."""
    return plane.reshape(-1)[:n]


def _shifts32(dtype=jnp.uint32) -> jax.Array:
    return jnp.arange(PACK, dtype=dtype)


# ---------------------------------------------------------------------------
# sign packing
# ---------------------------------------------------------------------------

def sign_pack(plane: jax.Array) -> jax.Array:
    """Value plane (M, LANE) -> sign word plane (M//32, LANE) uint32.

    Bit b of word [r, l] = 1 iff plane[32*r + b, l] > 0.
    """
    m, lane = plane.shape
    assert m % PACK == 0, f"rows {m} not a multiple of {PACK}"
    bits = (plane > 0).astype(jnp.uint32).reshape(m // PACK, PACK, lane)
    return jnp.sum(bits << _shifts32().reshape(1, PACK, 1), axis=1).astype(jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    """Sign word plane (R, LANE) -> bit plane (32R, LANE) uint32 in {0,1}."""
    r, lane = words.shape
    bits = (words[:, None, :] >> _shifts32().reshape(1, PACK, 1)) & jnp.uint32(1)
    return bits.reshape(r * PACK, lane)


# ---------------------------------------------------------------------------
# popcount across workers ("the controller's PopCount tree")
# ---------------------------------------------------------------------------

def popcount_stack(packed: jax.Array) -> jax.Array:
    """(W, R, LANE) packed sign words -> per-element vote counts (32R, LANE) int32.

    counts[i] = c_i = PopCount over the W workers' sign bits.
    """
    w, r, lane = packed.shape
    bits = (packed[:, :, None, :] >> _shifts32().reshape(1, 1, PACK, 1)) & jnp.uint32(1)
    counts = jnp.sum(bits.astype(jnp.int32), axis=0)          # (R, 32, LANE)
    return counts.reshape(r * PACK, lane)


# ---------------------------------------------------------------------------
# majority decode (vote margin -> ternary packed aggregate)
# ---------------------------------------------------------------------------

def majority_decode(counts: jax.Array, num_workers: int,
                    gate_words: jax.Array | None = None):
    """Vote counts (M, LANE) -> ternary packed pair ((R, LANE) u32, (R, LANE) u32).

    a_i = 2 * c_i - W; sign bit = a_i > 0; mask bit = a_i != 0.
    If ``gate_words`` is given (packed zero-gate), mask &= gate.
    """
    m, lane = counts.shape
    a = 2 * counts.astype(jnp.int32) - num_workers
    sign_words = sign_pack(a.astype(jnp.float32))
    nz = (a != 0).astype(jnp.uint32).reshape(m // PACK, PACK, lane)
    mask_words = jnp.sum(nz << _shifts32().reshape(1, PACK, 1), axis=1).astype(jnp.uint32)
    if gate_words is not None:
        mask_words = mask_words & gate_words
    return sign_words, mask_words


# ---------------------------------------------------------------------------
# ternary zero gate (paper: fixed 2-of-3 pattern over flattened elements)
# ---------------------------------------------------------------------------

def ternary_gate_words(num_rows: int, phase: int = 0) -> jax.Array:
    """Packed 2-of-3 zero-gate pattern for a (num_rows, LANE) value plane.

    Element index i (row-major over the value plane) is gated to zero when
    (i + phase) % 3 == 2 — i.e. two consecutive elements keep the G-Binary
    update and the third returns zero, per Section 2 of the paper.
    """
    assert num_rows % PACK == 0
    keep = ((np.arange(num_rows * LANE, dtype=np.int64) + phase) % 3) != 2
    return gate_words_from_mask(keep)


def gate_words_from_mask(keep: np.ndarray,
                         pad_words: int | None = None) -> jax.Array:
    """Arbitrary flat keep mask (N,) -> packed gate word plane.

    Generalizes :func:`ternary_gate_words` to any host-side boolean
    pattern — the fused bucket path uses it to pack the concatenation of
    per-leaf 2-of-3 gates into one bucket-wide gate.  Elements beyond N
    (canonical padding) keep = 1; ``pad_words`` optionally right-pads the
    word plane with all-ones rows to a given row count (the all_to_all
    row padding of the packed schedule — padding is dropped on unpack,
    so its gate value is irrelevant).
    """
    keep = np.asarray(keep, bool).reshape(-1)
    n = keep.shape[0]
    full = np.ones(padded_len(n), np.uint32)
    full[:n] = keep.astype(np.uint32)
    rows = full.shape[0] // LANE
    full = full.reshape(rows, LANE).reshape(rows // PACK, PACK, LANE)
    words = np.sum(full << np.arange(PACK, dtype=np.uint32).reshape(1, PACK, 1),
                   axis=1, dtype=np.uint64).astype(np.uint32)
    if pad_words is not None and pad_words > words.shape[0]:
        pad = np.full((pad_words - words.shape[0], LANE), 0xFFFFFFFF,
                      np.uint32)
        words = np.concatenate([words, pad], axis=0)
    return jnp.asarray(words)


# ---------------------------------------------------------------------------
# unpack ternary aggregate to values
# ---------------------------------------------------------------------------

def unpack_ternary(sign_words: jax.Array, mask_words: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    """Ternary packed pair -> value plane (M, LANE) of {-1, 0, +1}."""
    s = unpack_bits(sign_words).astype(jnp.int32)   # {0, 1}
    m = unpack_bits(mask_words).astype(jnp.int32)   # {0, 1}
    return ((2 * s - 1) * m).astype(dtype)


# ---------------------------------------------------------------------------
# fused apply: param update from packed aggregate
# ---------------------------------------------------------------------------

def apply_sign_update(param_plane: jax.Array, sign_words: jax.Array,
                      mask_words: jax.Array, scale) -> jax.Array:
    """param - scale * u, with u decoded from the ternary packed pair."""
    u = unpack_ternary(sign_words, mask_words, dtype=jnp.float32)
    out = param_plane.astype(jnp.float32) - jnp.asarray(scale, jnp.float32) * u
    return out.astype(param_plane.dtype)


# ---------------------------------------------------------------------------
# fused-stage references (oracles for repro.kernels.fused)
# ---------------------------------------------------------------------------
#
# Each function below is the pure-jnp composition the corresponding fused
# Pallas kernel must reproduce bit-for-bit.  They are deliberately written
# as compositions of the staged references above wherever one exists, so
# "fused == ref" transitively proves "fused == staged pipeline".

def encode_pack_ef(g_plane: jax.Array, e_plane: jax.Array):
    """EF inject + sign pack in one step: (words, g_eff plane).

    g_eff = g + e (the error-feedback inject); the words are the packed
    signs of g_eff.  Reference for the fused encode kernel.
    """
    g_eff = g_plane + e_plane
    return sign_pack(g_eff), g_eff


def vote_combine(routed: jax.Array, num_workers: int,
                 gate_words: jax.Array):
    """(W, R, LANE) routed sign words -> ternary packed pair, one step.

    Composition of :func:`popcount_stack` and :func:`majority_decode` —
    the fused combine kernel skips the (M, LANE) int32 counts
    materialization between them.
    """
    counts = popcount_stack(routed)
    return majority_decode(counts, num_workers, gate_words=gate_words)


def vote_pipeline_dense(stack: jax.Array, num_workers: int,
                        gate_words: jax.Array) -> jax.Array:
    """(W, M, LANE) value planes -> decoded ternary value plane (M, LANE).

    The whole local (no-collective) vote datapath in one step:
    encode -> popcount -> majority -> decode, never leaving registers in
    the fused kernel.  Reference composition of the staged kernels.
    """
    packed = jnp.stack([sign_pack(stack[w]) for w in range(stack.shape[0])])
    sw, mw = vote_combine(packed, num_workers, gate_words)
    return unpack_ternary(sw, mw, dtype=jnp.float32)


def ef_residual(plane: jax.Array, beta) -> jax.Array:
    """EF residual update on a value plane: x - beta * sgn(x).

    Reference for the fused residual kernel; elementwise-identical to
    the unfused ``g_eff - beta * jnp.sign(g_eff)`` on the leaf shape.
    """
    b = jnp.asarray(beta, plane.dtype)
    return plane - b * jnp.sign(plane)


def int4_quant_plane(plane: jax.Array, levels: float = 7.0) -> jax.Array:
    """Absmax-scaled int4 fake-quant of a float32 value plane.

    Same math as ``Int4Codec.encode``: one global absmax scale over the
    plane, round-to-nearest into [-levels, levels], dequantize.  The
    canonical zero padding never changes the absmax, so quantizing the
    padded plane is bit-identical to quantizing the flat bucket.
    """
    scale = jnp.max(jnp.abs(plane)) / levels
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(plane / safe), -levels, levels)
    return q * safe


def threshold_mask_plane(plane: jax.Array, thresh) -> jax.Array:
    """Magnitude sparsification: keep x where |x| >= thresh, else 0.

    Same comparison as ``TopKCodec.encode`` (threshold precomputed from
    the top-k magnitude); reference for the fused top-k mask kernel.
    """
    t = jnp.asarray(thresh, plane.dtype)
    return jnp.where(jnp.abs(plane) >= t, plane, jnp.zeros((), plane.dtype))


# ---------------------------------------------------------------------------
# end-to-end oracle (paper Section 2, all workers -> aggregate values)
# ---------------------------------------------------------------------------

def gbinary_aggregate_dense(grads: jax.Array) -> jax.Array:
    """(W, N) worker gradients -> (N,) G-Binary aggregate in {-1, 0, +1}.

    Direct (unpacked) evaluation of the Section 2 equations; used as the
    semantic oracle for the whole packed pipeline.
    """
    w = grads.shape[0]
    b = (grads > 0).astype(jnp.int32)
    c = jnp.sum(b, axis=0)
    a = 2 * c - w
    return jnp.sign(a).astype(jnp.float32)


def gternary_aggregate_dense(grads: jax.Array, phase: int = 0) -> jax.Array:
    """(W, N) worker gradients -> (N,) G-Ternary aggregate (2-of-3 gate)."""
    u = gbinary_aggregate_dense(grads)
    n = grads.shape[1]
    gate = (((jnp.arange(n) + phase) % 3) != 2).astype(jnp.float32)
    return u * gate
