"""Pallas TPU kernel: pack gradient signs into uint32 word planes.

This is the write-side payload materialization of the paper (Section 3,
"Write-side payload materialization"): the runtime derives a packed sign
payload from ordinary FP32/BF16 gradients *before* the communication step.

Layout contract (shared with ref.py): value plane (M, 128) -> word plane
(M // 32, 128) uint32, bit b of word [r, l] = sign of value [32 r + b, l].

TPU mapping: each block holds ``32 * RB`` value rows in VMEM; the kernel
statically unrolls RB word rows, each formed by a sublane reduction of
``bit << row_index`` over a (32, 128) VREG tile — the direct analogue of the
paper's 512-bit sign-packing stage, eight VREGs at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LANE, PACK


def _sign_pack_kernel(x_ref, out_ref, *, words_per_block: int):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        rows = x_ref[r * PACK:(r + 1) * PACK, :]                # (32, LANE)
        bits = (rows > 0).astype(jnp.uint32)
        word = jnp.sum(bits << shifts, axis=0, keepdims=True)    # (1, LANE)
        out_ref[r:r + 1, :] = word.astype(jnp.uint32)


def _pick_word_block(num_words: int, max_words: int = 16) -> int:
    for wb in range(min(max_words, num_words), 0, -1):
        if num_words % wb == 0:
            return wb
    return 1


@functools.partial(jax.jit, static_argnames=("interpret", "block_words"))
def sign_pack(plane: jax.Array, *, interpret: bool = False,
              block_words: int | None = None) -> jax.Array:
    """Value plane (M, LANE) -> packed sign word plane (M // 32, LANE) uint32."""
    m, lane = plane.shape
    assert lane == LANE, f"lane dim must be {LANE}, got {lane}"
    assert m % PACK == 0, f"rows {m} must be a multiple of {PACK}"
    num_words = m // PACK
    wb = block_words or _pick_word_block(num_words)
    grid = (num_words // wb,)
    return pl.pallas_call(
        functools.partial(_sign_pack_kernel, words_per_block=wb),
        out_shape=jax.ShapeDtypeStruct((num_words, LANE), jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(plane)
