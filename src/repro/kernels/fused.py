"""Codec-owned fused Pallas kernels: one kernel per bucket stage chain.

The staged controller datapath (``sign_pack`` -> ``popcount_stack`` ->
``majority_decode`` -> ``unpack_ternary``) pays an HBM round-trip between
every stage — exactly the overhead the paper's five-cycle near-memory
pipeline exists to avoid.  This module makes fused kernels a *codec
capability*:

  * :class:`KernelSet` — the protocol a codec's ``pallas_kernels()`` hook
    returns: ``encode_flat`` / ``combine`` / ``decode_apply`` entry points
    plus an optional fused error-feedback residual update, and modeled
    launch/HBM accounting so benchmarks price fused vs unfused uniformly.
  * :class:`VoteKernelSet` — the sign-vote chain shared by ``gbinary`` and
    ``gternary``: fused EF-inject+pack encode, a single popcount+majority
    combine (the staged pipeline's (M, LANE) int32 counts plane never
    touches HBM), and — when no collective separates the stages — the
    whole encode -> vote -> decode chain as ONE kernel
    (:func:`vote_pipeline`).
  * :class:`Int4KernelSet` / :class:`TopKKernelSet` — real Pallas kernels
    for the extension codecs (absmax fake-quant as a single two-phase
    kernel; magnitude-threshold sparsify), registered purely through the
    public ``Codec.pallas_kernels`` seam.
  * :func:`fused_packed_vote` — the bucket-level fusion driver: the
    ``packed_a2a`` schedule realized with the fused kernels (3 launches
    distributed, 1 launch when the payload is host-local).

Bit-identity contract: every fused kernel reproduces, bit-for-bit, the
pure-jnp reference composition in :mod:`repro.kernels.ref`
(``vote_combine`` / ``vote_pipeline_dense`` / ``encode_pack_ef`` /
``ef_residual`` / ``int4_quant_plane`` / ``threshold_mask_plane``), which
are themselves compositions of the staged references — so fused == ref
transitively proves fused == the unfused pipeline wherever both run.
The same three-way dispatch as :mod:`repro.kernels.ops` applies:
``interpret=True`` runs the kernel bodies on CPU, ``interpret=None``
off-TPU takes the reference path (identical bits, clean HLO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import LANE, PACK
from .sign_pack import _pick_word_block
from .ops import _mode, pack_signs, unpack_ternary


# ---------------------------------------------------------------------------
# gate-word helpers (shared by the fused AND unfused packed paths, so the
# two pipelines consume byte-identical zero gates by construction)
# ---------------------------------------------------------------------------

def local_gate_words(num_words: int, *, ternary: bool, gate_phase: int = 0,
                     gate_mask=None) -> jax.Array:
    """Packed zero gate for an un-routed (num_words, LANE) word plane."""
    if gate_mask is not None:
        return ref.gate_words_from_mask(gate_mask, pad_words=num_words)
    if ternary:
        return ref.ternary_gate_words(num_words * PACK, phase=gate_phase)
    return jnp.full((num_words, LANE), 0xFFFFFFFF, jnp.uint32)


def shard_gate_words(dp_axes, rows_per_shard: int, *, ternary: bool,
                     gate_phase: int = 0, gate_mask=None,
                     total_rows: int | None = None) -> jax.Array:
    """Packed zero gate for this shard's routed segment of a packed a2a.

    The gate is indexed by the element range this worker owns after the
    all_to_all (``axis_index * rows_per_shard`` word rows into the plane).
    ``gate_mask`` (host-side flat keep vector) overrides the uniform
    flat-index 2-of-3 pattern; ``total_rows`` right-pads the packed mask
    to the collective's row padding (dropped on unpack, gate irrelevant).
    """
    rw = rows_per_shard
    if not ternary:
        return jnp.full((rw, LANE), 0xFFFFFFFF, jnp.uint32)
    my = jax.lax.axis_index(dp_axes)
    if gate_mask is not None:
        full = ref.gate_words_from_mask(gate_mask, pad_words=total_rows)
        return jax.lax.dynamic_slice_in_dim(full, my * rw, rw, axis=0)
    # the 2-of-3 pattern repeats every 3 elements: precompute the three
    # phase rotations and select by this shard's flat element offset
    base = (my * rw * PACK * LANE + gate_phase) % 3
    gates = jnp.stack([ref.ternary_gate_words(rw * PACK, phase=p)
                       for p in range(3)])
    return gates[base]


# ---------------------------------------------------------------------------
# fused kernel bodies
# ---------------------------------------------------------------------------

def _encode_pack_ef_kernel(g_ref, e_ref, words_ref, geff_ref, *,
                           words_per_block: int):
    """EF inject + sign pack fused: the g_eff = g + e plane is packed the
    moment it is formed, so the unfused path's inject-pass write/re-read
    of g_eff before packing never happens."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        x = g_ref[r * PACK:(r + 1) * PACK, :] + e_ref[r * PACK:(r + 1) * PACK, :]
        geff_ref[r * PACK:(r + 1) * PACK, :] = x
        bits = (x > 0).astype(jnp.uint32)
        words_ref[r:r + 1, :] = jnp.sum(bits << shifts, axis=0,
                                        keepdims=True).astype(jnp.uint32)


def _vote_combine_kernel(routed_ref, gate_ref, sign_ref, mask_ref, *,
                         num_workers: int, words_per_block: int):
    """PopCount + majority/ternary decode in one kernel: the (M, LANE)
    int32 counts plane lives only in registers."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        acc = jnp.zeros((PACK, LANE), jnp.int32)
        for w in range(num_workers):
            word = routed_ref[w, r:r + 1, :]                     # (1, LANE)
            bits = (jnp.broadcast_to(word, (PACK, LANE)) >> shifts) & jnp.uint32(1)
            acc = acc + bits.astype(jnp.int32)
        a = 2 * acc - num_workers                                 # vote margin
        sign_word = jnp.sum((a > 0).astype(jnp.uint32) << shifts,
                            axis=0, keepdims=True)
        mask_word = jnp.sum((a != 0).astype(jnp.uint32) << shifts,
                            axis=0, keepdims=True)
        gate = gate_ref[r:r + 1, :]
        sign_ref[r:r + 1, :] = sign_word.astype(jnp.uint32)
        mask_ref[r:r + 1, :] = (mask_word & gate).astype(jnp.uint32)


def _vote_pipeline_kernel(stack_ref, gate_ref, out_ref, *, num_workers: int,
                          words_per_block: int, out_dtype):
    """The whole local vote datapath — encode, popcount, majority, ternary
    gate, decode — as ONE kernel over stacked (W, M, LANE) value planes.
    No packed words, counts, or ternary pair ever reach HBM; counting
    (v > 0) directly is bit-identical to packing the sign bits first."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        counts = jnp.zeros((PACK, LANE), jnp.int32)
        for w in range(num_workers):
            rows = stack_ref[w, r * PACK:(r + 1) * PACK, :]
            counts = counts + (rows > 0).astype(jnp.int32)
        a = 2 * counts - num_workers
        gate = jnp.broadcast_to(gate_ref[r:r + 1, :], (PACK, LANE))
        keep = ((gate >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        s = (a > 0).astype(jnp.int32)
        m = (a != 0).astype(jnp.int32) * keep
        out_ref[r * PACK:(r + 1) * PACK, :] = ((2 * s - 1) * m).astype(out_dtype)


def _ef_residual_kernel(x_ref, beta_ref, out_ref):
    """EF-signSGD residual e' = x - beta * sgn(x) (beta precomputed)."""
    beta = beta_ref[0, 0]
    x = x_ref[...]
    out_ref[...] = x - beta * jnp.sign(x)


def _int4_quant_kernel(x_ref, out_ref, acc_ref, *, levels: float):
    """Two-phase absmax fake-quant: grid (2, nblocks); phase 0 streams the
    plane once accumulating the global absmax in SMEM, phase 1 re-streams
    it quantizing with the now-complete scale.  One launch replaces the
    staged absmax-reduce + quantize-pass pair; the running max visits
    blocks in a fixed order, and max() is order-independent, so the scale
    is bit-identical to ``jnp.max(jnp.abs(plane))``."""
    phase = pl.program_id(0)
    block = pl.program_id(1)

    @pl.when((phase == 0) & (block == 0))
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    @pl.when(phase == 0)
    def _scan():
        acc_ref[0, 0] = jnp.maximum(acc_ref[0, 0],
                                    jnp.max(jnp.abs(x_ref[...])))

    @pl.when(phase == 1)
    def _quant():
        scale = acc_ref[0, 0] / levels
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(x_ref[...] / safe), -levels, levels)
        out_ref[...] = q * safe


def _threshold_mask_kernel(x_ref, t_ref, out_ref):
    """Magnitude sparsify: keep x where |x| >= t (t = k-th magnitude)."""
    t = t_ref[0, 0]
    x = x_ref[...]
    out_ref[...] = jnp.where(jnp.abs(x) >= t, x, jnp.zeros((), x.dtype))


# ---------------------------------------------------------------------------
# jit'd entry points (same 3-way interpret dispatch as kernels.ops)
# ---------------------------------------------------------------------------

def _vote_stack_block(num_words: int, num_workers: int) -> int:
    """Word-block size for kernels holding W stacked planes in VMEM:
    cap the resident block near 2 MiB (w * wb * TILE * 4 bytes)."""
    cap = max(1, min(8, 128 // max(1, num_workers)))
    return _pick_word_block(num_words, max_words=cap)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _encode_pack_ef_call(g_plane, e_plane, *, interpret: bool):
    m, lane = g_plane.shape
    num_words = m // PACK
    wb = _pick_word_block(num_words, max_words=8)
    out_shape = (jax.ShapeDtypeStruct((num_words, LANE), jnp.uint32),
                 jax.ShapeDtypeStruct((m, LANE), g_plane.dtype))
    return pl.pallas_call(
        functools.partial(_encode_pack_ef_kernel, words_per_block=wb),
        out_shape=out_shape,
        grid=(num_words // wb,),
        in_specs=[pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0))),
        interpret=interpret,
    )(g_plane, e_plane)


def encode_pack_ef(g_plane: jax.Array, e_plane: jax.Array, *,
                   interpret: bool | None = None):
    """Fused EF inject + sign pack: -> (sign words, g_eff value plane)."""
    m = _mode(interpret)
    if m == "ref":
        return ref.encode_pack_ef(g_plane, e_plane)
    return _encode_pack_ef_call(g_plane, e_plane, interpret=(m == "interp"))


@functools.partial(jax.jit, static_argnames=("num_workers", "interpret"))
def _vote_combine_call(routed, gate_words, *, num_workers: int,
                       interpret: bool):
    w, r, lane = routed.shape
    wb = _pick_word_block(r, max_words=8)
    out_shape = (jax.ShapeDtypeStruct((r, LANE), jnp.uint32),
                 jax.ShapeDtypeStruct((r, LANE), jnp.uint32))
    return pl.pallas_call(
        functools.partial(_vote_combine_kernel, num_workers=w,
                          words_per_block=wb),
        out_shape=out_shape,
        grid=(r // wb,),
        in_specs=[pl.BlockSpec((w, wb, LANE), lambda i: (0, i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((wb, LANE), lambda i: (i, 0))),
        interpret=interpret,
    )(routed, gate_words)


def vote_combine(routed: jax.Array, gate_words: jax.Array, *,
                 num_workers: int, interpret: bool | None = None):
    """(W, R, LANE) routed sign words + packed gate -> ternary packed pair.

    One kernel for popcount_stack + majority_decode; the int32 counts
    plane (8x the packed payload) never reaches HBM.
    """
    m = _mode(interpret)
    if m == "ref":
        return ref.vote_combine(routed, num_workers, gate_words)
    return _vote_combine_call(routed, gate_words, num_workers=num_workers,
                              interpret=(m == "interp"))


@functools.partial(jax.jit, static_argnames=("num_workers", "dtype",
                                             "interpret"))
def _vote_pipeline_call(stack, gate_words, *, num_workers: int, dtype,
                        interpret: bool):
    w, m, lane = stack.shape
    num_words = m // PACK
    wb = _vote_stack_block(num_words, w)
    return pl.pallas_call(
        functools.partial(_vote_pipeline_kernel, num_workers=w,
                          words_per_block=wb, out_dtype=dtype),
        out_shape=jax.ShapeDtypeStruct((m, LANE), dtype),
        grid=(num_words // wb,),
        in_specs=[pl.BlockSpec((w, wb * PACK, LANE), lambda i: (0, i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(stack, gate_words)


def vote_pipeline(stack: jax.Array, gate_words: jax.Array, *,
                  num_workers: int, dtype=jnp.float32,
                  interpret: bool | None = None) -> jax.Array:
    """(W, M, LANE) stacked value planes -> decoded {-1,0,+1} plane.

    The whole encode -> vote -> decode chain as ONE kernel (the paper's
    single streaming datapath stage) — usable whenever no collective
    separates the stages (host-local payloads, or post-routing stacks).
    """
    m = _mode(interpret)
    if m == "ref":
        return ref.vote_pipeline_dense(stack, num_workers,
                                       gate_words).astype(dtype)
    return _vote_pipeline_call(stack, gate_words, num_workers=num_workers,
                               dtype=dtype, interpret=(m == "interp"))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ef_residual_call(plane, beta, *, interpret: bool):
    m, lane = plane.shape
    rb = _pick_word_block(m // PACK, max_words=8) * PACK
    return pl.pallas_call(
        _ef_residual_kernel,
        out_shape=jax.ShapeDtypeStruct((m, LANE), plane.dtype),
        grid=(m // rb,),
        in_specs=[pl.BlockSpec((rb, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rb, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(plane, jnp.asarray(beta, plane.dtype).reshape(1, 1))


def ef_residual_plane(plane: jax.Array, beta, *,
                      interpret: bool | None = None) -> jax.Array:
    """EF residual e' = x - beta * sgn(x) on a value plane."""
    m = _mode(interpret)
    if m == "ref":
        return ref.ef_residual(plane, beta)
    return _ef_residual_call(plane, beta, interpret=(m == "interp"))


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def _int4_quant_call(plane, *, levels: float, interpret: bool):
    m, lane = plane.shape
    rb = _pick_word_block(m // PACK, max_words=8) * PACK
    nblocks = m // rb
    return pl.pallas_call(
        functools.partial(_int4_quant_kernel, levels=levels),
        out_shape=jax.ShapeDtypeStruct((m, LANE), plane.dtype),
        grid=(2, nblocks),
        in_specs=[pl.BlockSpec((rb, LANE), lambda p, i: (i, 0))],
        out_specs=pl.BlockSpec((rb, LANE), lambda p, i: (i, 0)),
        scratch_shapes=[_smem_scratch()],
        interpret=interpret,
    )(plane)


def _smem_scratch():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.SMEM((1, 1), jnp.float32)


def int4_quant_plane(plane: jax.Array, *, levels: float = 7.0,
                     interpret: bool | None = None) -> jax.Array:
    """Absmax int4 fake-quant of a float32 value plane, one launch."""
    m = _mode(interpret)
    if m == "ref":
        return ref.int4_quant_plane(plane, levels=levels)
    return _int4_quant_call(plane, levels=levels, interpret=(m == "interp"))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _threshold_mask_call(plane, thresh, *, interpret: bool):
    m, lane = plane.shape
    rb = _pick_word_block(m // PACK, max_words=8) * PACK
    return pl.pallas_call(
        _threshold_mask_kernel,
        out_shape=jax.ShapeDtypeStruct((m, LANE), plane.dtype),
        grid=(m // rb,),
        in_specs=[pl.BlockSpec((rb, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rb, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(plane, jnp.asarray(thresh, plane.dtype).reshape(1, 1))


def threshold_mask_plane(plane: jax.Array, thresh, *,
                         interpret: bool | None = None) -> jax.Array:
    """Magnitude-threshold sparsify of a value plane, one launch."""
    m = _mode(interpret)
    if m == "ref":
        return ref.threshold_mask_plane(plane, thresh)
    return _threshold_mask_call(plane, thresh, interpret=(m == "interp"))


def ef_update_fused(g_eff: jax.Array, ef: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Fused-kernel EF residual update, bit-identical to ``_ef_update``.

    beta is the mean |g_eff| over the *leaf-shaped* array (identical to
    the unfused reduction); the elementwise residual runs as one kernel
    on the canonical plane — same per-element ops, so identical bits.
    """
    beta = jnp.mean(jnp.abs(g_eff))
    plane = ref.to_plane(g_eff.reshape(-1))
    resid = ef_residual_plane(plane, beta, interpret=interpret)
    return ref.from_plane(resid, g_eff.size).reshape(g_eff.shape).astype(ef.dtype)


# ---------------------------------------------------------------------------
# bucket-level fusion driver: packed_a2a on the fused kernels
# ---------------------------------------------------------------------------

def fused_packed_vote(g: jax.Array, dp_axes, num_workers: int, *,
                      ternary: bool = False, gate_phase: int = 0,
                      ef: jax.Array | None = None,
                      interpret: bool | None = None, gate_mask=None):
    """The ``packed_a2a`` vote schedule realized with fused kernels.

    Distributed (3 launches vs the staged pipeline's 4): fused
    EF-inject+pack encode -> all_to_all -> fused popcount+majority
    combine -> all_gather -> decode.  Host-local (``dp_axes`` empty, a
    configuration the staged path cannot run at all): the entire chain
    is ONE :func:`vote_pipeline` launch.  Bit-identical to
    ``core.lowbit._packed_a2a_local`` wherever that path runs.

    Returns ``(u, new_ef)`` exactly like the unfused collective.
    """
    w = num_workers
    n = g.size
    if not dp_axes:
        # no collective separates the stages: one kernel per bucket
        g_eff = g if ef is None else g + ef.astype(g.dtype)
        plane = ref.to_plane(g_eff.reshape(-1))
        gate = local_gate_words(plane.shape[0] // PACK, ternary=ternary,
                                gate_phase=gate_phase, gate_mask=gate_mask)
        u_plane = vote_pipeline(plane[None], gate, num_workers=w,
                                dtype=jnp.float32, interpret=interpret)
        u = ref.from_plane(u_plane, n).reshape(g.shape).astype(g.dtype)
        new_ef = None if ef is None else \
            ef_update_fused(g_eff, ef, interpret=interpret)
        return u, new_ef

    if ef is None:
        plane = ref.to_plane(g.reshape(-1))
        words = pack_signs(plane, interpret=interpret)
        g_eff = None
    else:
        g_plane = ref.to_plane(g.reshape(-1))
        e_plane = ref.to_plane(ef.astype(g.dtype).reshape(-1))
        words, geff_plane = encode_pack_ef(g_plane, e_plane,
                                           interpret=interpret)
        g_eff = ref.from_plane(geff_plane, n).reshape(g.shape)
    r = words.shape[0]
    pad_r = (-r) % w
    if pad_r:
        words = jnp.pad(words, ((0, pad_r), (0, 0)))
    rw = (r + pad_r) // w
    routed = jax.lax.all_to_all(words.reshape(w, rw, LANE), dp_axes,
                                split_axis=0, concat_axis=0, tiled=False)
    gate = shard_gate_words(dp_axes, rw, ternary=ternary,
                            gate_phase=gate_phase, gate_mask=gate_mask,
                            total_rows=r + pad_r)
    sw, mw = vote_combine(routed, gate, num_workers=w, interpret=interpret)
    sw_all = jax.lax.all_gather(sw, dp_axes, axis=0, tiled=True)[:r]
    mw_all = jax.lax.all_gather(mw, dp_axes, axis=0, tiled=True)[:r]
    u_plane = unpack_ternary(sw_all, mw_all, dtype=jnp.float32,
                             interpret=interpret)
    u = ref.from_plane(u_plane, n).reshape(g.shape).astype(g.dtype)
    new_ef = None if ef is None else \
        ef_update_fused(g_eff, ef, interpret=interpret)
    return u, new_ef


# ---------------------------------------------------------------------------
# KernelSet protocol + built-in sets
# ---------------------------------------------------------------------------

# modeled HBM bytes per element of a bucket, by representation
_F32 = 4.0          # one float32
_WORDS = 1 / 8.0    # packed sign bits
_PAIR = 1 / 4.0     # ternary packed (sign, mask) pair
_COUNTS = 4.0       # int32 vote counts


class KernelSet:
    """Protocol for a codec's fused Pallas kernels.

    A codec's ``pallas_kernels()`` hook returns one of these (or None to
    keep the reference-jnp path).  Two capability axes:

      * ``votes`` — the set realizes the packed sign-vote chain; the
        ``packed_a2a`` backend calls :meth:`packed_vote` for the whole
        bucket.
      * ``means`` — the set realizes encode/decode around a mean
        collective; the psum backend calls :meth:`encode_flat` /
        :meth:`decode_apply` on the flat payload.

    ``launches`` / ``hbm_bytes`` are the *modeled* accounting (kernel
    launch count, HBM bytes streamed per bucket) that benchmarks and the
    nightly fused-vs-unfused gate consume; they price the algorithmic
    reads/writes each pipeline must perform, not transient compiler
    spills.  ``signature()`` feeds the session step-cache key so swapping
    a codec's kernels invalidates compiled steps.
    """
    name = "kernelset"
    votes = False
    means = False

    def signature(self) -> str:
        return self.name

    def launches(self, *, fused: bool, distributed: bool = True,
                 ef: bool = False) -> int:
        raise NotImplementedError

    def hbm_bytes(self, n: int, *, num_workers: int, fused: bool,
                  distributed: bool = True, ef: bool = False) -> float:
        raise NotImplementedError

    # --- mean-reduction entry points (means=True sets) ---
    def encode_flat(self, flat: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
        raise NotImplementedError

    def decode_apply(self, payload: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
        return payload

    # --- vote-reduction entry point (votes=True sets) ---
    def packed_vote(self, g, dp_axes, num_workers, *, ternary, gate_phase,
                    ef, interpret, gate_mask=None):
        raise NotImplementedError


class VoteKernelSet(KernelSet):
    """Fused sign-vote chain for ``gbinary`` / ``gternary``."""
    name = "vote"
    votes = True

    def signature(self) -> str:
        return "vote:v1"

    def packed_vote(self, g, dp_axes, num_workers, *, ternary, gate_phase,
                    ef, interpret, gate_mask=None):
        return fused_packed_vote(g, dp_axes, num_workers, ternary=ternary,
                                 gate_phase=gate_phase, ef=ef,
                                 interpret=interpret, gate_mask=gate_mask)

    def launches(self, *, fused: bool, distributed: bool = True,
                 ef: bool = False) -> int:
        # staged: pack, popcount, majority, decode (EF inject/residual are
        # XLA elementwise passes either way — not Pallas launches)
        if not fused:
            return 4
        # fused: encode / combine / decode around the collectives —
        # or the whole chain as one kernel when nothing separates stages
        return 3 if distributed else 1

    def hbm_bytes(self, n: int, *, num_workers: int, fused: bool,
                  distributed: bool = True, ef: bool = False) -> float:
        w = num_workers
        if distributed:
            # per worker; the routed segment it owns covers n/W elements,
            # scaled back up here so fused/unfused compare on equal terms
            enc = n * (_F32 + _WORDS)                       # read g, write words
            if ef:
                # unfused: inject pass (read g+e, write g_eff) then pack
                # re-reads g_eff; fused packs g_eff as it is formed
                enc += n * (2 * _F32 + _F32) if not fused else n * (2 * _F32)
            dec = n * (_PAIR + _F32)                        # read pair, write u
            if fused:
                comb = n * (w * _WORDS + _WORDS + _PAIR)    # stack+gate -> pair
                return enc + comb + dec
            pop = n * (w * _WORDS + _COUNTS)                # stack -> counts
            maj = n * (_COUNTS + _WORDS + _PAIR)            # counts+gate -> pair
            return enc + pop + maj + dec
        # host-local: all W planes resident, no collective
        if fused:
            return n * (w * _F32 + _WORDS + _F32)           # stacks+gate -> u
        pack = w * n * (_F32 + _WORDS)
        pop = n * (w * _WORDS + _COUNTS)
        maj = n * (_COUNTS + _WORDS + _PAIR)
        dec = n * (_PAIR + _F32)
        return pack + pop + maj + dec


class Int4KernelSet(KernelSet):
    """Single-launch absmax int4 fake-quant for the ``int4`` codec."""
    name = "int4"
    means = True

    def __init__(self, levels: float = 7.0):
        self.levels = float(levels)

    def signature(self) -> str:
        return f"int4:v1:levels={self.levels:g}"

    def encode_flat(self, flat: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
        n = flat.shape[0]
        plane = ref.to_plane(flat.astype(jnp.float32))
        out = int4_quant_plane(plane, levels=self.levels, interpret=interpret)
        return ref.from_plane(out, n).astype(flat.dtype)

    def launches(self, *, fused: bool, distributed: bool = True,
                 ef: bool = False) -> int:
        # staged: absmax reduce pass + quantize pass; fused: one two-phase
        # kernel carrying the scale across phases in SMEM
        return 1 if fused else 2

    def hbm_bytes(self, n: int, *, num_workers: int, fused: bool,
                  distributed: bool = True, ef: bool = False) -> float:
        # both stream the plane twice (scan + quant) and write it once;
        # fusion folds the launches, not the reads: 12n either way
        return n * (2 * _F32 + _F32)


class TopKKernelSet(KernelSet):
    """Magnitude-threshold sparsify kernel for the ``topk`` codec."""
    name = "topk"
    means = True

    def __init__(self, fraction: float):
        self.fraction = float(fraction)

    def signature(self) -> str:
        return f"topk:v1:f={self.fraction:g}"

    def encode_flat(self, flat: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
        f = jnp.abs(flat.astype(jnp.float32)).reshape(-1)
        k = max(1, int(f.shape[0] * self.fraction))
        thresh = jax.lax.top_k(f, k)[0][-1]
        plane = ref.to_plane(flat)
        out = threshold_mask_plane(plane, thresh.astype(flat.dtype),
                                   interpret=interpret)
        return ref.from_plane(out, flat.shape[0])

    def launches(self, *, fused: bool, distributed: bool = True,
                 ef: bool = False) -> int:
        # staged: |x| materialization, top-k select, masking pass; fused:
        # top-k reads |x| on the fly + one mask kernel
        return 2 if fused else 3

    def hbm_bytes(self, n: int, *, num_workers: int, fused: bool,
                  distributed: bool = True, ef: bool = False) -> float:
        select = n * _F32                                   # top-k scan
        mask = n * (2 * _F32)                               # read x, write out
        if fused:
            return select + mask
        return n * (2 * _F32) + select + mask               # + |x| round trip


@functools.cache
def vote_kernel_set() -> VoteKernelSet:
    """Shared singleton: gbinary/gternary differ only in the gate operand."""
    return VoteKernelSet()
