"""NEURON-Fabric controller-datapath kernels (Pallas TPU + pure-jnp oracles).

The paper's 512-bit five-cycle CXL aggregation datapath maps here to three
VREG-aligned Pallas stages (sign packing, worker PopCount, majority/ternary
decode) plus a beyond-paper fused packed-update kernel.  ``ref`` holds the
bit-exact pure-jnp oracles used by the functional tests (paper Section 6).
``fused`` is the codec-owned kernel-fusion subsystem: :class:`KernelSet`
capabilities a codec exposes through its ``pallas_kernels()`` hook, plus
the one-kernel-per-bucket drivers for the vote chain and the extension
codec quantizers.
"""
from . import ref
from .ops import (LANE, PACK, apply_sign_update, from_plane,
                  gate_words_from_mask, interpret_default, majority_decode,
                  pack_signs, padded_len, popcount_stack, ternary_gate_words,
                  to_plane, unpack_ternary)
from .fused import (Int4KernelSet, KernelSet, TopKKernelSet, VoteKernelSet,
                    fused_packed_vote, vote_kernel_set)

__all__ = [
    "ref", "LANE", "PACK", "apply_sign_update", "from_plane",
    "gate_words_from_mask", "interpret_default", "majority_decode",
    "pack_signs", "padded_len", "popcount_stack", "ternary_gate_words",
    "to_plane", "unpack_ternary",
    "KernelSet", "VoteKernelSet", "Int4KernelSet", "TopKKernelSet",
    "fused_packed_vote", "vote_kernel_set",
]
