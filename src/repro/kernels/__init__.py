"""NEURON-Fabric controller-datapath kernels (Pallas TPU + pure-jnp oracles).

The paper's 512-bit five-cycle CXL aggregation datapath maps here to three
VREG-aligned Pallas stages (sign packing, worker PopCount, majority/ternary
decode) plus a beyond-paper fused packed-update kernel.  ``ref`` holds the
bit-exact pure-jnp oracles used by the functional tests (paper Section 6).
"""
from . import ref
from .ops import (LANE, PACK, apply_sign_update, from_plane, interpret_default,
                  majority_decode, pack_signs, padded_len, popcount_stack,
                  ternary_gate_words, to_plane, unpack_ternary)

__all__ = [
    "ref", "LANE", "PACK", "apply_sign_update", "from_plane",
    "interpret_default", "majority_decode", "pack_signs", "padded_len",
    "popcount_stack", "ternary_gate_words", "to_plane", "unpack_ternary",
]
