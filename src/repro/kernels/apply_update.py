"""Pallas TPU kernels: decode packed aggregates and apply parameter updates.

``unpack_ternary`` decodes a ternary packed pair back to {-1, 0, +1} values
(the read-response payload the requester sees in the paper).

``apply_sign_update`` is a beyond-paper fusion: instead of materializing the
decoded aggregate in HBM and then running the optimizer update, it reads the
parameter plane once, decodes the packed aggregate in VMEM (1/32 the bytes
of a dense gradient), applies ``p - scale * u`` and writes the plane back.
For the sign-SGD update step this turns an HBM-bound 3-pass update
(read grad + read param + write param = 12 bytes/element fp32) into
~8.25 bytes/element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LANE, PACK
from .sign_pack import _pick_word_block


def _unpack_ternary_kernel(sign_ref, mask_ref, out_ref, *,
                           words_per_block: int, out_dtype):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    for r in range(words_per_block):
        sw = jnp.broadcast_to(sign_ref[r:r + 1, :], (PACK, LANE))
        mw = jnp.broadcast_to(mask_ref[r:r + 1, :], (PACK, LANE))
        s = ((sw >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        m = ((mw >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        u = (2 * s - 1) * m
        out_ref[r * PACK:(r + 1) * PACK, :] = u.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret", "block_words"))
def unpack_ternary(sign_words: jax.Array, mask_words: jax.Array, *,
                   dtype=jnp.float32, interpret: bool = False,
                   block_words: int | None = None) -> jax.Array:
    """Ternary packed pair (R, LANE) -> value plane (32 R, LANE) of {-1,0,+1}."""
    r, lane = sign_words.shape
    assert lane == LANE and mask_words.shape == (r, lane)
    wb = block_words or _pick_word_block(r, max_words=8)
    grid = (r // wb,)
    return pl.pallas_call(
        functools.partial(_unpack_ternary_kernel, words_per_block=wb,
                          out_dtype=dtype),
        out_shape=jax.ShapeDtypeStruct((r * PACK, LANE), dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(sign_words, mask_words)


def _apply_sign_update_kernel(param_ref, sign_ref, mask_ref, scale_ref,
                              out_ref, *, words_per_block: int):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (PACK, LANE), 0)
    scale = scale_ref[0, 0]
    for r in range(words_per_block):
        sw = jnp.broadcast_to(sign_ref[r:r + 1, :], (PACK, LANE))
        mw = jnp.broadcast_to(mask_ref[r:r + 1, :], (PACK, LANE))
        s = ((sw >> shifts) & jnp.uint32(1)).astype(jnp.float32)
        m = ((mw >> shifts) & jnp.uint32(1)).astype(jnp.float32)
        u = (2.0 * s - 1.0) * m
        p = param_ref[r * PACK:(r + 1) * PACK, :].astype(jnp.float32)
        out_ref[r * PACK:(r + 1) * PACK, :] = (p - scale * u).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_words"))
def apply_sign_update(param_plane: jax.Array, sign_words: jax.Array,
                      mask_words: jax.Array, scale: jax.Array, *,
                      interpret: bool = False,
                      block_words: int | None = None) -> jax.Array:
    """Fused ``param - scale * decode(sign, mask)`` over a value plane."""
    m, lane = param_plane.shape
    assert lane == LANE and m % PACK == 0
    num_words = m // PACK
    assert sign_words.shape == (num_words, LANE)
    assert mask_words.shape == (num_words, LANE)
    wb = block_words or _pick_word_block(num_words, max_words=8)
    grid = (num_words // wb,)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_apply_sign_update_kernel, words_per_block=wb),
        out_shape=jax.ShapeDtypeStruct((m, LANE), param_plane.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((wb, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((wb * PACK, LANE), lambda i: (i, 0)),
        interpret=interpret,
    )(param_plane, sign_words, mask_words, scale_arr)
