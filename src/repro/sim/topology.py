"""Pluggable interconnect topologies for the fabric simulator.

A topology maps one collective *launch* (wire bytes per device, worker
count) to a :class:`Route`: an ordered tuple of :class:`Hop` link
occupancies plus a fixed (non-serialized) latency.  The trace driver
walks the hops through shared :class:`~repro.sim.engine.Resource`
objects, so two launches routed over the same link name queue — the
behaviour the closed-form :class:`repro.core.traffic.IciModel` cannot
express.

Topologies register under a string name with :func:`register_topology`
(the same extension idiom as ``repro.fabric.register_schedule`` and
``register_controller``).  Built-ins:

  * ``"cxl_direct"``   — each step's launches share one direct-attach
    CXL link to the fabric memory device (the paper's baseline).
  * ``"cxl_switched"`` — host uplink -> switch crossbar -> device, a
    CXL shared-memory pool as in CXL-CCL (arXiv 2602.22457).
  * ``"ici_ring"``     — TPU ICI ring collectives; constants come from
    :class:`repro.core.traffic.IciModel`, so on a single queue-free
    launch the simulated collective time equals
    ``IciModel.collective_time`` exactly.
  * ``"multihop"``     — h-hop hierarchical all-reduce with per-hop
    payload compression, as in DynamiQ (arXiv 2602.08923).
"""
from __future__ import annotations

import dataclasses

from ..core.registry import Registry
from ..core.traffic import IciModel


@dataclasses.dataclass(frozen=True)
class Hop:
    """One serialized occupancy of a named link."""
    link: str
    hold_s: float          # serialization time (bytes / link bandwidth)
    bytes: float = 0.0     # payload crossing this link (reporting only)


@dataclasses.dataclass(frozen=True)
class Route:
    """A launch's path through the fabric: hops + fixed latency."""
    hops: tuple            # tuple[Hop], traversed in order
    latency_s: float = 0.0  # propagation / dispatch time, never queued

    @property
    def service_s(self) -> float:
        """Total link-serialization time (the bandwidth term)."""
        return sum(h.hold_s for h in self.hops)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: backed by the shared generic :class:`repro.core.registry.Registry`
#: (same helper as the codec/schedule/controller seams), which brings
#: this registry the alias + ``override=True`` sweep semantics the
#: hand-rolled version lacked.  Stores *factories*: :func:`get_topology`
#: calls the registered object with its kwargs.
_TOPOLOGIES = Registry("topology", key_fn=str,
                       describe=lambda f: getattr(f, "__name__",
                                                  type(f).__name__),
                       format_available=", ".join)


def register_topology(name: str, *aliases: str, override: bool = False):
    """Class/factory decorator: register a topology under ``name``.

    The registered object is called with the ``get_topology`` kwargs and
    must return an instance exposing
    ``route(wire_bytes, num_workers, index) -> Route``.  ``aliases``
    register the same factory under extra names; re-registering raises
    unless ``override=True`` (which also sweeps stale aliases of the
    replaced factory).
    """
    return _TOPOLOGIES.register(name, *aliases, override=override)


def unregister_topology(name: str) -> None:
    """Remove a topology factory and all its aliases."""
    _TOPOLOGIES.unregister(name)


def available_topologies() -> tuple[str, ...]:
    return _TOPOLOGIES.available()


def get_topology(name_or_topology, **kwargs):
    """Resolve a topology by registered name (or pass one through)."""
    if not isinstance(name_or_topology, str):
        if kwargs:
            raise TypeError("factory kwargs are only valid with a "
                            "registered topology name")
        return name_or_topology
    return _TOPOLOGIES.get(name_or_topology)(**kwargs)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

@register_topology("cxl_direct")
@dataclasses.dataclass(frozen=True)
class CxlDirect:
    """Direct-attach CXL: every launch crosses one shared device link.

    The fixed latency is two memory round-trips (gradient write +
    aggregate read-back — the paper's fixed CXL access latency) plus the
    launch dispatch overhead.
    """
    name: str = "cxl_direct"
    link_bytes_per_s: float = 64e9       # CXL 3.x x8-ish payload rate
    mem_access_latency_s: float = 400e-9
    launch_overhead_s: float = 2e-6

    def route(self, wire_bytes: float, num_workers: int,
              index: int = 0) -> Route:
        return Route(
            hops=(Hop("cxl", wire_bytes / self.link_bytes_per_s,
                      bytes=wire_bytes),),
            latency_s=2 * self.mem_access_latency_s + self.launch_overhead_s)


@register_topology("cxl_switched")
@dataclasses.dataclass(frozen=True)
class CxlSwitched:
    """CXL shared-memory pool behind a switch (CXL-CCL-style).

    Launches serialize twice — host uplink, then the switch crossbar to
    the pooled device — and pay the switch traversal both ways.
    """
    name: str = "cxl_switched"
    uplink_bytes_per_s: float = 64e9
    crossbar_bytes_per_s: float = 128e9  # switch fabric is wider
    switch_latency_s: float = 250e-9
    mem_access_latency_s: float = 400e-9
    launch_overhead_s: float = 2e-6

    def route(self, wire_bytes: float, num_workers: int,
              index: int = 0) -> Route:
        return Route(
            hops=(Hop("cxl_up", wire_bytes / self.uplink_bytes_per_s,
                      bytes=wire_bytes),
                  Hop("xbar", wire_bytes / self.crossbar_bytes_per_s,
                      bytes=wire_bytes)),
            latency_s=(2 * (self.switch_latency_s
                            + self.mem_access_latency_s)
                       + self.launch_overhead_s))


@register_topology("ici_ring")
@dataclasses.dataclass(frozen=True)
class IciRing:
    """TPU ICI ring collectives, constants from :class:`IciModel`.

    One launch holds the shared ``ici`` link for the bandwidth term and
    pays ``2(W-1)`` ring-stage hops plus dispatch as fixed latency —
    term-for-term :meth:`IciModel.collective_time`, so the queue-free
    single-launch simulation matches the analytic model exactly.
    """
    name: str = "ici_ring"
    ici: IciModel = dataclasses.field(default_factory=IciModel)

    def route(self, wire_bytes: float, num_workers: int,
              index: int = 0) -> Route:
        bw = self.ici.link_bytes_per_s * self.ici.links_per_chip
        steps = max(2 * (num_workers - 1), 1)
        return Route(
            hops=(Hop("ici", wire_bytes / bw, bytes=wire_bytes),),
            latency_s=(steps * self.ici.hop_latency_s
                       + self.ici.launch_overhead_s))


@register_topology("multihop")
@dataclasses.dataclass(frozen=True)
class MultiHop:
    """Hierarchical h-hop all-reduce with progressive compression.

    DynamiQ-style: each hop re-quantizes, shrinking the payload by
    ``compression`` before the next stage.  Hops serialize on distinct
    per-stage links (``hop0 .. hop{h-1}``), so concurrent launches
    pipeline across stages while same-stage transfers queue.
    """
    name: str = "multihop"
    hops: int = 4
    link_bytes_per_s: float = 25e9
    hop_latency_s: float = 2e-6
    launch_overhead_s: float = 5e-6
    compression: float = 0.5

    def route(self, wire_bytes: float, num_workers: int,
              index: int = 0) -> Route:
        hops = []
        b = float(wire_bytes)
        for k in range(max(1, self.hops)):
            hops.append(Hop(f"hop{k}", b / self.link_bytes_per_s, bytes=b))
            b *= self.compression
        return Route(hops=tuple(hops),
                     latency_s=(len(hops) * self.hop_latency_s
                                + self.launch_overhead_s))

    def route_hops(self, hop_bytes, num_workers: int,
                   index: int = 0) -> Route:
        """Route a launch whose per-leg bytes are already known.

        Hierarchical launches (a :class:`~repro.fabric.hierarchy.HopPlan`
        codec) carry their own per-hop wire bytes — each hop's codec
        fixes its leg's payload — so the topology's geometric
        ``compression``/``hops`` defaults are bypassed and the legs map
        onto the per-stage links directly.  Term for term this is
        :meth:`repro.core.traffic.MultiHopModel.route_time`, so the
        queue-free single-launch simulation matches the analytic per-hop
        model exactly.
        """
        hops = tuple(
            Hop(f"hop{k}", float(b) / self.link_bytes_per_s, bytes=float(b))
            for k, b in enumerate(hop_bytes))
        return Route(hops=hops,
                     latency_s=(len(hops) * self.hop_latency_s
                                + self.launch_overhead_s))
