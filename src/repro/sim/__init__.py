"""repro.sim — cycle-level discrete-event simulator for the fabric.

Turns the analytic point models (:class:`repro.core.exposure
.ExposureModel`, :class:`repro.core.traffic.IciModel`) into a scenario
engine: replay any :class:`~repro.core.buckets.BucketLayout` /
``AdmissionPlan`` against pluggable interconnect topologies with real
queueing, per-bucket pipelining, and compute/collective overlap.

  * :mod:`engine`   — event heap + FIFO clocked resources;
  * :mod:`datapath` — the paper's 5-stage 512-bit flit pipeline
    (sign-count / ternary-gated / FP32-bypass lanes);
  * :mod:`topology` — ``@register_topology`` registry with built-ins
    ``cxl_direct``, ``cxl_switched``, ``ici_ring``, ``multihop``;
  * :mod:`trace`    — bucket layout -> launch timeline ->
    :class:`SimReport`;
  * :mod:`scenarios` — the paper's operating points as executable
    configurations.

Validation contract (asserted in ``tests/test_sim.py``): on degenerate
single-launch / queue-free configs the simulator agrees with
``ExposureModel.exposed`` and ``IciModel.collective_time`` to within
1%; on the paper's operating points it reproduces the <= 1.67%-exposed
full-miss regime and the fully-hidden bandwidth-pressure regime.

Quick use::

    report = fabric.simulate(params, plan, topology="cxl_switched",
                             compute_time_s=1e-3)
    print(report.exposed_pct, report.link_utilization)
"""
from .datapath import (FLIT_BITS, PIPELINE_STAGES, FlitPipeline, LaneSpec,
                       datapath_time)
from .engine import Engine, Resource, ResourcePool, ResourceStats
from .scenarios import (PAPER_EXPOSED_BOUND_PCT, bandwidth_pressure_report,
                        full_miss_report, paper_operating_points)
from .topology import (CxlDirect, CxlSwitched, Hop, IciRing, MultiHop,
                       Route, available_topologies, get_topology,
                       register_topology, unregister_topology)
from .trace import (LaunchRecord, LaunchSpec, SimReport,
                    layout_launch_specs, simulate_launches, simulate_layout,
                    timeline_launch_specs)

__all__ = [
    "FLIT_BITS", "PIPELINE_STAGES", "FlitPipeline", "LaneSpec",
    "datapath_time",
    "Engine", "Resource", "ResourcePool", "ResourceStats",
    "PAPER_EXPOSED_BOUND_PCT", "bandwidth_pressure_report",
    "full_miss_report", "paper_operating_points",
    "CxlDirect", "CxlSwitched", "Hop", "IciRing", "MultiHop", "Route",
    "available_topologies", "get_topology", "register_topology",
    "unregister_topology",
    "LaunchRecord", "LaunchSpec", "SimReport", "layout_launch_specs",
    "simulate_launches", "simulate_layout", "timeline_launch_specs",
]
