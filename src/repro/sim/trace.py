"""Trace driver: compile a bucket layout into a simulated step timeline.

This is the top of the simulator stack: it turns a
:class:`~repro.core.buckets.BucketLayout` (or a hand-built list of
:class:`LaunchSpec`) plus a compute-time profile into per-launch events
on a :class:`~repro.sim.engine.Engine`, routes every launch through the
chosen topology, models the fabric datapath occupancy, and returns a
typed :class:`SimReport`.

Semantics per launch:

  * the launch becomes *ready* when backward compute emits its bucket
    (``ready_times``, default: evenly spaced across ``compute_time_s``);
  * its route's hops are traversed store-and-forward through shared
    FIFO link resources — concurrent launches queue, which is the
    behaviour the closed-form models cannot express;
  * the fabric datapath (a shared resource) processes the launch's
    flits for ``t_agg`` seconds starting when its first hop starts
    transmitting; up to ``overlap_fraction`` of the launch's own
    service path (transfer window plus fixed route latency) hides
    datapath time, and the remainder is *exposed* — on a queue-free
    single launch this reduces exactly to ``ExposureModel``'s
    ``max(0, t_agg - overlap * t_service)`` with the route latency as
    ``extra_service_s``.

The step finishes when compute and every launch (delivery + exposed
datapath tail) have finished.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from ..core.buckets import BucketLayout
from ..core.modes import AggregationMode, codec_name, schedule_name
from ..core.traffic import hop_wire_bytes_per_device
from .datapath import FlitPipeline, datapath_time
from .engine import Engine, ResourcePool
from .topology import get_topology


@dataclasses.dataclass(frozen=True)
class LaunchSpec:
    """One collective launch to simulate (a fused bucket or a leaf).

    ``mode`` is a codec name (built-in enum member or any registered
    codec) — the datapath resolves its lane/flit timing from the codec.
    ``hop_bytes`` carries the per-leg wire bytes of a hierarchical
    launch (None for flat single-hop launches); topologies exposing
    ``route_hops`` (e.g. ``multihop``) replay those legs on their
    per-stage links instead of applying their own payload profile.
    """
    name: str
    mode: AggregationMode | str
    schedule: str
    n_elements: int
    wire_bytes: float
    ready_s: float = 0.0
    hop_bytes: tuple | None = None


@dataclasses.dataclass
class LaunchRecord:
    """Simulated timeline of one launch."""
    index: int
    name: str
    mode: str
    schedule: str
    n_elements: int
    wire_bytes: float
    ready_s: float
    start_s: float = 0.0        # first link grant
    queue_delay_s: float = 0.0  # summed FIFO wait across hops
    service_s: float = 0.0      # summed link serialization (bandwidth term)
    latency_s: float = 0.0      # fixed route latency (hops + dispatch)
    t_agg_s: float = 0.0        # datapath occupancy for this launch
    dp_start_s: float = 0.0
    dp_end_s: float = 0.0
    exposed_s: float = 0.0      # datapath time beyond the hidden window
    end_s: float = 0.0          # delivery + exposed tail
    links: tuple = ()

    @property
    def hidden_s(self) -> float:
        """Datapath time absorbed by the transfer window."""
        return self.t_agg_s - self.exposed_s

    @property
    def collective_s(self) -> float:
        """Launch-local collective completion time (ready -> delivered)."""
        return self.end_s - self.ready_s

    def to_jsonable(self) -> dict:
        d = dataclasses.asdict(self)
        d["links"] = list(self.links)
        d["hidden_s"] = self.hidden_s
        d["collective_s"] = self.collective_s
        return d


@dataclasses.dataclass(frozen=True)
class SimReport:
    """Typed result of one simulated training step."""
    topology: str
    num_workers: int
    overlap_fraction: float
    compute_time_s: float
    launches: tuple            # tuple[LaunchRecord]
    step_time_s: float
    exposed_s: float
    exposed_pct: float         # of step time — the paper's reporting basis
    hidden: bool
    link_utilization: dict
    critical_path: tuple       # ((segment, seconds), ...) of the last launch
    events_processed: int

    @property
    def num_launches(self) -> int:
        return len(self.launches)

    @property
    def comm_time_s(self) -> float:
        """Span from the first launch start to the last delivery."""
        if not self.launches:
            return 0.0
        return (max(l.end_s for l in self.launches)
                - min(l.start_s for l in self.launches))

    @property
    def wire_bytes_total(self) -> float:
        """Per-device bytes crossing links, summed over all launches."""
        return float(sum(l.wire_bytes for l in self.launches))

    def telemetry(self, step: int, loss: float, **kwargs):
        """Adapt this report into a runtime Telemetry record.

        The simulated step time rides the same ``step_time_s`` channel a
        wall-clock-measured step would, so controllers (and their CUSUM
        statistics) can be exercised against simulated scenarios.
        """
        from ..fabric.control import Telemetry
        return Telemetry(step=int(step), loss=float(loss),
                         step_time_s=self.step_time_s, **kwargs)

    def to_jsonable(self) -> dict:
        return {
            "topology": self.topology,
            "num_workers": self.num_workers,
            "overlap_fraction": self.overlap_fraction,
            "compute_time_s": self.compute_time_s,
            "num_launches": self.num_launches,
            "step_time_s": self.step_time_s,
            "comm_time_s": self.comm_time_s,
            "wire_bytes_total": self.wire_bytes_total,
            "exposed_s": self.exposed_s,
            "exposed_pct": self.exposed_pct,
            "hidden": self.hidden,
            "link_utilization": dict(self.link_utilization),
            "critical_path": [list(seg) for seg in self.critical_path],
            "events_processed": self.events_processed,
            "launches": [l.to_jsonable() for l in self.launches],
        }

    def summary(self) -> dict:
        """Compact scalars for dry-run reports / benchmark JSON."""
        d = self.to_jsonable()
        d.pop("launches")
        return d


def simulate_launches(specs: Sequence[LaunchSpec], num_workers: int, *,
                      topology: Any = "ici_ring",
                      datapath: Any | None = None,
                      overlap_fraction: float = 1.0,
                      compute_time_s: float = 0.0,
                      **topology_kwargs) -> SimReport:
    """Run the discrete-event simulation for an explicit launch list.

    ``topology`` is a registered name (resolved with ``topology_kwargs``)
    or an instance; ``datapath`` is any ``t_agg`` model (default: the
    5-stage :class:`FlitPipeline`), or None to simulate pure transport
    with a zero-cost datapath.
    """
    topo = get_topology(topology, **topology_kwargs)
    topo_name = getattr(topo, "name", type(topo).__name__)
    engine = Engine()
    links = ResourcePool(engine)
    dp_resource = links["datapath"] if datapath is not None else None

    def make_launch(rec: LaunchRecord, route, t_agg: float):
        """Per-launch closure: hop chain -> finish, no shared loop state."""

        def begin(start: float) -> None:
            rec.start_s = start
            if dp_resource is not None:
                dps, dpe = dp_resource.request(start, t_agg,
                                               lambda s, e: None)
                rec.dp_start_s, rec.dp_end_s = dps, dpe

        def start_hop(k: int, t_arrive: float) -> None:
            if not route.hops:        # pure-latency route (custom topology)
                begin(t_arrive)
                finish(t_arrive)
                return
            if k >= len(route.hops):
                finish(t_arrive)
                return
            hop = route.hops[k]

            def granted(start: float, end: float, k=k) -> None:
                if k == 0:
                    begin(start)
                engine.at(end, lambda: start_hop(k + 1, end))

            # FIFO wait at this hop is observable from the grant window
            start, _end = links[hop.link].request(t_arrive, hop.hold_s,
                                                 granted)
            rec.queue_delay_s += start - t_arrive

        def finish(t_service_end: float) -> None:
            # the hideable window is the launch's own service path —
            # transfer (incl. inter-hop waits) plus the fixed route
            # latency — times overlap_fraction, mirroring
            # ExposureModel.exposed(..., extra_service_s=latency)
            transfer = max(0.0, t_service_end - rec.start_s)
            hide_end = rec.start_s + overlap_fraction * (transfer
                                                         + rec.latency_s)
            if rec.t_agg_s > 0.0:
                rec.exposed_s = max(0.0, rec.dp_end_s - hide_end)
            rec.end_s = t_service_end + rec.latency_s + rec.exposed_s

        return start_hop

    records: list[LaunchRecord] = []
    for i, spec in enumerate(specs):
        route_hops = getattr(topo, "route_hops", None)
        if spec.hop_bytes is not None and route_hops is not None:
            route = route_hops(spec.hop_bytes, num_workers, i)
        else:
            route = topo.route(spec.wire_bytes, num_workers, i)
        t_agg = (0.0 if datapath is None else
                 datapath_time(datapath, spec.n_elements, num_workers,
                               spec.mode))
        rec = LaunchRecord(
            index=i, name=spec.name, mode=codec_name(spec.mode),
            schedule=schedule_name(spec.schedule),
            n_elements=int(spec.n_elements),
            wire_bytes=float(spec.wire_bytes), ready_s=float(spec.ready_s),
            latency_s=route.latency_s, service_s=route.service_s,
            t_agg_s=t_agg, links=tuple(h.link for h in route.hops))
        records.append(rec)
        start_hop = make_launch(rec, route, t_agg)
        engine.at(spec.ready_s,
                  lambda t=spec.ready_s, fn=start_hop: fn(0, t))

    engine.run()

    last_end = max((r.end_s for r in records), default=0.0)
    step_time = max(float(compute_time_s), last_end)
    exposed = sum(r.exposed_s for r in records)
    crit: tuple = ()
    if records:
        tail = max(records, key=lambda r: r.end_s)
        crit = (("compute_until_ready", tail.ready_s),
                ("queue", tail.queue_delay_s),
                ("service", tail.service_s),
                ("latency", tail.latency_s),
                ("exposed_datapath", tail.exposed_s))
    return SimReport(
        topology=topo_name, num_workers=int(num_workers),
        overlap_fraction=float(overlap_fraction),
        compute_time_s=float(compute_time_s),
        launches=tuple(records), step_time_s=step_time,
        exposed_s=exposed,
        exposed_pct=(100.0 * exposed / step_time if step_time > 0 else 0.0),
        hidden=exposed == 0.0,
        link_utilization=links.utilization(step_time),
        critical_path=crit,
        events_processed=engine.events_processed)


def layout_launch_specs(layout: BucketLayout, num_workers: int, *,
                        compute_time_s: float = 0.0,
                        ready_times: Sequence[float] | None = None,
                        ) -> list[LaunchSpec]:
    """BucketLayout -> simulatable launch list (wire bytes per launch).

    Launches appear in layout order (fused buckets first, then unfused
    leaves); ``ready_times`` overrides the default evenly-spaced
    emission of buckets across the backward pass (``compute_time_s``).
    """
    entries = [(f"bucket:{i}:{codec_name(b.key.mode)}", b.key, b.size)
               for i, b in enumerate(layout.buckets)]
    entries += [(f"leaf:{u.name}", u.key, u.size) for u in layout.unfused]
    n = len(entries)
    if ready_times is None:
        ready_times = [compute_time_s * (i + 1) / n for i in range(n)] \
            if n else []
    if len(ready_times) != n:
        raise ValueError(
            f"{len(ready_times)} ready times for {n} launches (the layout "
            f"implies {len(layout.buckets)} fused buckets plus "
            f"{len(layout.unfused)} unfused leaves)")
    specs = []
    for (name, key, size), ready in zip(entries, ready_times):
        legs = hop_wire_bytes_per_device(size, key.mode, key.schedule,
                                         num_workers)
        specs.append(LaunchSpec(
            name=name, mode=key.mode, schedule=key.schedule,
            n_elements=size, wire_bytes=float(sum(legs)),
            ready_s=float(ready),
            # only hierarchical (multi-leg) launches pin their own route
            # legs; flat launches keep the topology's payload profile
            hop_bytes=legs if len(legs) > 1 else None))
    return specs


def timeline_launch_specs(steps: Sequence[Any], *,
                          step_compute_s: float = 0.0,
                          mode: Any = "fp32",
                          schedule: str = "paged_kv") -> list[LaunchSpec]:
    """Per-step traffic records -> simulatable launch list.

    The serving-side counterpart of :func:`layout_launch_specs`: instead
    of a backward pass emitting buckets, a decode loop emits one fabric
    transaction per engine step (KV gather + scatter + spill traffic).
    Each entry of ``steps`` is a mapping with ``wire_bytes`` plus
    optional ``name`` / ``mode`` / ``schedule`` / ``n_elements`` /
    ``ready_s`` overrides; step ``i`` defaults to becoming ready at
    ``i * step_compute_s`` (the model-forward time separating decode
    steps).
    """
    specs = []
    for i, entry in enumerate(steps):
        d = dict(entry)
        specs.append(LaunchSpec(
            name=str(d.get("name", f"step:{i}")),
            mode=d.get("mode", mode),
            schedule=str(d.get("schedule", schedule)),
            n_elements=int(d.get("n_elements", 0)),
            wire_bytes=float(d["wire_bytes"]),
            ready_s=float(d.get("ready_s", i * step_compute_s))))
    return specs


def simulate_layout(layout: BucketLayout, num_workers: int, *,
                    topology: Any = "ici_ring",
                    datapath: Any | None = None,
                    overlap_fraction: float = 1.0,
                    compute_time_s: float = 0.0,
                    ready_times: Sequence[float] | None = None,
                    **topology_kwargs) -> SimReport:
    """Simulate one aggregation pass of a bucket layout.

    The scenario engine entry point: replay any PR-2/PR-3
    ``BucketLayout`` (hence any ``AdmissionPlan``) against any
    registered topology.  ``datapath`` defaults to the 5-stage
    :class:`FlitPipeline`.
    """
    if datapath is None:
        datapath = FlitPipeline()
    specs = layout_launch_specs(layout, num_workers,
                                compute_time_s=compute_time_s,
                                ready_times=ready_times)
    return simulate_launches(specs, num_workers, topology=topology,
                             datapath=datapath,
                             overlap_fraction=overlap_fraction,
                             compute_time_s=compute_time_s,
                             **topology_kwargs)
