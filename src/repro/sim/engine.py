"""Discrete-event simulation engine: event heap + clocked resources.

The engine is deliberately tiny and generic — a time-ordered event heap
(:class:`Engine`) plus FIFO single-occupancy :class:`Resource` objects
with occupancy / queue-delay statistics.  Everything fabric-specific
(the datapath pipeline, topologies, the launch timeline) lives in the
sibling modules and drives this engine through ``Engine.at`` and
``Resource.request``.

Times are seconds as floats.  Determinism: events at the same timestamp
fire in scheduling order (a monotone sequence number breaks ties), and
resources grant requests strictly in request order, so a simulation is
a pure function of its inputs.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable


class Engine:
    """Time-ordered event loop.

    ``at(t, fn)`` schedules ``fn()`` at absolute time ``t`` (clamped to
    the current time — events cannot fire in the past); ``run()`` drains
    the heap.  ``now`` is the current simulation time and ``horizon``
    the largest time any event has fired at.
    """

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self.now = 0.0
        self.horizon = 0.0
        self.events_processed = 0

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn()`` at absolute time ``t`` (>= now)."""
        heapq.heappush(self._heap, (max(float(t), self.now), self._seq, fn))
        self._seq += 1

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn()`` ``delay`` seconds from now."""
        self.at(self.now + max(0.0, float(delay)), fn)

    def run(self, until: float | None = None) -> float:
        """Drain the event heap (optionally stopping at ``until``)."""
        while self._heap:
            if until is not None and self._heap[0][0] > until:
                break
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            self.horizon = max(self.horizon, t)
            self.events_processed += 1
            fn()
        return self.horizon


@dataclasses.dataclass
class ResourceStats:
    """Aggregate occupancy statistics for one resource."""
    grants: int = 0
    busy_s: float = 0.0
    queue_delay_s: float = 0.0
    max_queue_delay_s: float = 0.0
    last_free_s: float = 0.0

    def utilization(self, horizon_s: float) -> float:
        """Busy fraction of the simulated interval ``[0, horizon_s]``."""
        return self.busy_s / horizon_s if horizon_s > 0 else 0.0


class Resource:
    """A FIFO, single-occupancy clocked resource (a link, the datapath).

    ``request(t_ready, hold_s, cb)`` asks to occupy the resource for
    ``hold_s`` seconds no earlier than ``t_ready``; the callback fires
    *at the grant time* as ``cb(start_s, end_s)``.  Grants are strictly
    in request order (FIFO), so contention shows up as queue delay —
    exactly the term the closed-form models cannot express.
    """

    def __init__(self, name: str, engine: Engine) -> None:
        self.name = name
        self.engine = engine
        self._free_at = 0.0
        self.stats = ResourceStats()

    def request(self, t_ready: float, hold_s: float,
                cb: Callable[[float, float], None]) -> tuple[float, float]:
        """Reserve ``[start, start + hold_s)``; returns the window."""
        t_ready = max(0.0, float(t_ready))
        hold_s = max(0.0, float(hold_s))
        start = max(t_ready, self._free_at)
        end = start + hold_s
        self._free_at = end
        delay = start - t_ready
        st = self.stats
        st.grants += 1
        st.busy_s += hold_s
        st.queue_delay_s += delay
        st.max_queue_delay_s = max(st.max_queue_delay_s, delay)
        st.last_free_s = end
        self.engine.at(start, lambda: cb(start, end))
        return start, end


class ResourcePool:
    """Lazy name -> :class:`Resource` map for one simulation run."""

    def __init__(self, engine: Engine) -> None:
        self.engine = engine
        self._resources: dict[str, Resource] = {}

    def __getitem__(self, name: str) -> Resource:
        if name not in self._resources:
            self._resources[name] = Resource(name, self.engine)
        return self._resources[name]

    def items(self):
        return self._resources.items()

    def utilization(self, horizon_s: float) -> dict[str, float]:
        return {n: r.stats.utilization(horizon_s)
                for n, r in sorted(self._resources.items())}
