"""Named simulator scenarios, including the paper's operating points.

The two headline regimes from the paper's Section 5 evaluation are kept
here as executable scenarios so tests, benchmarks, and the dry-run all
replay the same configurations:

  * **full_miss** — the full LLC-miss regime: every flit's operand
    fetch stalls the 5-stage pipeline for one memory round-trip
    (``miss_stall_cycles=1``) and only half of the transfer window can
    hide datapath time.  The datapath is *exposed*, but by <= 1.67% of
    the step.
  * **bandwidth_pressure** — large packed buckets on the thin ring:
    the transfer window dwarfs the datapath, which hides entirely
    (0% exposed).

Both use the paper's 8M-element G-Binary bucket over 32 workers with a
1 ms backward pass.
"""
from __future__ import annotations

from ..core.modes import AggregationMode
from .datapath import FlitPipeline
from .trace import LaunchSpec, SimReport, simulate_launches

#: The paper's reference bucket: 8M gradient elements, 32 DP workers.
PAPER_N_ELEMENTS = 8 << 20
PAPER_NUM_WORKERS = 32
PAPER_COMPUTE_S = 1e-3

#: The paper's exposure bound in the full LLC-miss regime (percent).
PAPER_EXPOSED_BOUND_PCT = 1.67


def full_miss_report() -> SimReport:
    """Full LLC-miss regime on direct-attach CXL: exposed, but bounded.

    The wire payload is the raw 1-bit/element sign stream each worker
    writes over its CXL link (the paper's write path — not one of the
    registered TPU collective schedules), hence the ``cxl_write``
    schedule label.
    """
    n, w = PAPER_N_ELEMENTS, PAPER_NUM_WORKERS
    spec = LaunchSpec(name="bucket:0:gbinary",
                      mode=AggregationMode.G_BINARY, schedule="cxl_write",
                      n_elements=n, wire_bytes=n / 8,    # 1 bit/element
                      ready_s=PAPER_COMPUTE_S)
    return simulate_launches(
        [spec], w, topology="cxl_direct",
        datapath=FlitPipeline(miss_stall_cycles=1.0),
        overlap_fraction=0.5, compute_time_s=PAPER_COMPUTE_S)


def bandwidth_pressure_report() -> SimReport:
    """Packed buckets under ICI bandwidth pressure: fully hidden."""
    from ..core.traffic import wire_bytes_per_device
    n, w = PAPER_N_ELEMENTS, PAPER_NUM_WORKERS
    wb = wire_bytes_per_device(n, AggregationMode.G_BINARY, "packed_a2a", w)
    spec = LaunchSpec(name="bucket:0:gbinary",
                      mode=AggregationMode.G_BINARY, schedule="packed_a2a",
                      n_elements=n, wire_bytes=wb,
                      ready_s=PAPER_COMPUTE_S)
    return simulate_launches(
        [spec], w, topology="ici_ring", datapath=FlitPipeline(),
        overlap_fraction=1.0, compute_time_s=PAPER_COMPUTE_S)


def paper_operating_points() -> dict[str, SimReport]:
    """Both regimes, keyed by scenario name."""
    return {"full_miss": full_miss_report(),
            "bandwidth_pressure": bandwidth_pressure_report()}
