"""Cycle-level model of the paper's 5-stage 512-bit aggregation datapath.

The paper's fabric controller aggregates gradients as 512-bit flits
streaming through a five-stage pipeline (decode -> align -> combine ->
majority/gate -> writeback).  Three lanes share the pipeline:

  * **G-Binary sign-count** — 1 wire bit/element; per-flit popcount of
    worker sign votes into the running count.
  * **G-Ternary gated**     — sign + zero-mask bits; the 2-of-3 zero
    gate adds a gate-word fetch per flit (modelled as stall cycles).
  * **FP32 bypass**         — 32 bits/element forwarded around the
    majority stage (warm-up / head traffic); no reduction work but the
    full 32x flit count.

:class:`FlitPipeline` turns an (n_elements, mode, num_workers) launch
into cycles — pipeline fill + one initiation interval per flit + stall
cycles — and seconds at the fabric clock.  ``miss_stall_cycles`` models
the full LLC-miss regime (paper Section 5): every flit's operand fetch
misses the fabric-side cache and stalls the pipeline for the memory
round-trip, which is how the paper's "<= 1.67% exposed in the full-miss
regime" scenario is reproduced by the simulator.

Any object with a ``t_agg(n_elements, num_workers)`` method (e.g. the
analytic :class:`repro.core.exposure.TpuDatapathModel`) can stand in
for the pipeline in the trace driver — that substitution is exactly how
sim-vs-analytic validation closes the loop.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.modes import AggregationMode, bits_per_element
from ..fabric.codecs import CodecLane

#: Datapath flit width (bits) — the paper's 512-bit CXL-side datapath.
FLIT_BITS = 512

#: Pipeline depth — the paper's five-cycle datapath.
PIPELINE_STAGES = 5

#: Per-codec lane behaviour inside the shared flit pipeline — one
#: dataclass serves both layers: codecs declare their lane as a
#: :class:`~repro.fabric.codecs.CodecLane` and the pipeline consumes it
#: directly; ``LaneSpec`` is the sim-side name for the same type.
LaneSpec = CodecLane


@dataclasses.dataclass(frozen=True)
class FlitPipeline:
    """The 5-stage 512-bit flit pipeline, in cycles and seconds.

    ``worker_ports`` is how many workers' flits the combine stage merges
    per cycle; with ``num_workers > worker_ports`` the initiation
    interval grows by ``ceil(W / worker_ports)`` (the vote fan-in is
    serialized over the ports).  ``miss_stall_cycles`` adds a fixed
    per-flit stall for the full LLC-miss regime.
    """
    clock_hz: float = 1.5e9
    flit_bits: int = FLIT_BITS
    stages: int = PIPELINE_STAGES
    worker_ports: int = 64
    miss_stall_cycles: float = 0.0
    #: pipeline fills charged to a lane whose codec does *not* fuse its
    #: encode -> combine -> decode chain into one kernel
    #: (``CodecLane.fused=False``): each staged pass re-fills the
    #: pipeline.  Every built-in lane is fused, so the default model is
    #: unchanged; only a deliberately-unfused custom lane pays it.
    unfused_passes: int = 4

    def lane(self, mode: AggregationMode | str) -> LaneSpec:
        """Lane descriptor for a codec name — from the codec registry.

        A registered codec's :class:`~repro.fabric.codecs.CodecLane`
        rides the pipeline directly (so new codecs time correctly with
        no edits here).  Unregistered names raise the registry's
        canonical KeyError — the same error :meth:`flits` hits through
        ``bits_per_element`` — rather than silently timing on a
        fallback lane.
        """
        from ..fabric.codecs import get_codec
        return get_codec(mode).lane

    def flits(self, n_elements: int, mode: AggregationMode | str) -> int:
        """512-bit flits needed for one launch's wire payload."""
        bits = n_elements * bits_per_element(mode)
        return max(1, math.ceil(bits / self.flit_bits))

    def cycles(self, n_elements: int, num_workers: int,
               mode: AggregationMode | str = AggregationMode.G_BINARY,
               ) -> dict[str, float]:
        """Cycle breakdown: fill + steady-state issue + stalls."""
        lane = self.lane(mode)
        flits = self.flits(n_elements, mode)
        fanin = max(1, math.ceil(num_workers / self.worker_ports))
        ii = lane.initiation_interval * fanin
        stall = (lane.stall_cycles_per_flit + self.miss_stall_cycles)
        fills = 1 if lane.fused else self.unfused_passes
        return {
            "flits": float(flits),
            "fill_cycles": float(self.stages * fills),
            "issue_cycles": (flits - 1) * ii + 1.0,
            "stall_cycles": flits * stall,
            "initiation_interval": ii,
        }

    def t_agg(self, n_elements: int, num_workers: int,
              mode: AggregationMode | str = AggregationMode.G_BINARY,
              ) -> float:
        """Seconds of datapath time for one launch of ``n_elements``."""
        c = self.cycles(n_elements, num_workers, mode)
        total = c["fill_cycles"] + c["issue_cycles"] + c["stall_cycles"]
        return total / self.clock_hz

    def throughput_bytes_per_s(self, mode=AggregationMode.G_BINARY,
                               num_workers: int = 1) -> float:
        """Steady-state wire-payload drain rate of the pipeline."""
        lane = self.lane(mode)
        fanin = max(1, math.ceil(num_workers / self.worker_ports))
        cycles_per_flit = (lane.initiation_interval * fanin
                           + lane.stall_cycles_per_flit
                           + self.miss_stall_cycles)
        return (self.flit_bits / 8) * self.clock_hz / cycles_per_flit


def datapath_time(datapath, n_elements: int, num_workers: int,
                  mode: AggregationMode | str) -> float:
    """``t_agg`` of any datapath model, mode-aware when supported.

    :class:`FlitPipeline` takes the mode (its lanes differ);
    analytic stand-ins like
    :class:`repro.core.exposure.TpuDatapathModel` only see
    ``(n_elements, num_workers)`` — exactly the substitution the
    sim-vs-analytic validation tests rely on.
    """
    try:
        return float(datapath.t_agg(n_elements, num_workers, mode))
    except TypeError:
        return float(datapath.t_agg(n_elements, num_workers))
