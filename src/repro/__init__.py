"""NEURON-Fabric on TPU: low-bit gradient aggregation for distributed training.

JAX (+ Pallas) implementation of Wang, Huang & Lung, "NEURON-Fabric:
CXL-Side Low-Bit Gradient Aggregation for Distributed Training"
(CS.DC 2026), adapted to the TPU ICI collective path.  See DESIGN.md.
"""

__version__ = "1.0.0"
