"""NEURON-Fabric on TPU: low-bit gradient aggregation for distributed training.

JAX (+ Pallas) implementation of Wang, Huang & Lung, "NEURON-Fabric:
CXL-Side Low-Bit Gradient Aggregation for Distributed Training"
(CS.DC 2026), adapted to the TPU ICI collective path.  See DESIGN.md.

The central API is the :class:`repro.fabric.Fabric` session — one
control surface over aggregation, backed by a pluggable schedule-backend
registry (``repro.fabric.register_schedule``).
"""

__version__ = "1.1.0"
