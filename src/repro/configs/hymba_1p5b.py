"""hymba-1.5b [hybrid]: parallel attention + Mamba-style SSM heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676].  Every layer fuses a GQA path and a selective-SSM
path on the same input (outputs averaged); attention is sliding-window
except the first / middle / last layers (global), per the Hymba recipe.
Meta-tokens are omitted (noted in DESIGN.md).
"""
from ..models import ModelConfig, SsmConfig

FULL = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    hybrid_parallel=True,
    ssm=SsmConfig(state_size=16, variant="mamba_head"),
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    sliding_window=16,
    hybrid_parallel=True,
    ssm=SsmConfig(state_size=4, variant="mamba_head"),
    dtype="float32",
    remat=False,
    full_size=False,
)
