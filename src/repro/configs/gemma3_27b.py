"""gemma3-27b [dense]: 5:1 local:global interleaved attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, head_dim=128
[hf:google/gemma-3; unverified].  Sliding window 1024 on local layers;
every 6th layer is global.  qk-norm per gemma3.  Eligible for the
long_500k cell: local layers are O(window), global layers use the
KV-sharded flash-decode path (DESIGN.md §Arch-applicability).
"""
from ..models import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,
    sliding_window=1024,
    global_every=6,
    rope_theta=1000000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    sliding_window=8,
    global_every=3,
    dtype="float32",
    remat=False,
    full_size=False,
)
