"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP patch-embed stub.

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct].  The modality frontend is a
STUB per the assignment: ``input_specs()`` provides precomputed CLIP-like
patch features (B, 576, 1024); the trained projector maps them into the
token stream (prepended), the transformer backbone is exact.
"""
from ..models import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    mlp_variant="swiglu",
    vision_patches=576,
    vision_feat_dim=1024,
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="phi-3-vision-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    vision_patches=8,
    vision_feat_dim=32,
    dtype="float32",
    remat=False,
    full_size=False,
)
