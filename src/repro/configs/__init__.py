"""Assigned-architecture configs (``--arch <id>``) + smoke reductions.

Each ``<id>.py`` module defines ``FULL`` (the exact published configuration
from the assignment table) and ``SMOKE`` (a reduced same-family config for
CPU tests).  ``get_config(arch_id, smoke=...)`` is the registry entry point
used by the launcher, the dry-run, and the tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "phi3_vision_4p2b",
    "llama4_scout_17b_a16e",
    "deepseek_moe_16b",
    "whisper_tiny",
    "hymba_1p5b",
    "qwen3_0p6b",
    "gemma3_27b",
    "qwen2p5_14b",
    "starcoder2_15b",
    "xlstm_125m",
)

#: assignment-table ids -> module names
ALIASES = {
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1p5b",
    "qwen3-0.6b": "qwen3_0p6b",
    "gemma3-27b": "gemma3_27b",
    "qwen2.5-14b": "qwen2p5_14b",
    "starcoder2-15b": "starcoder2_15b",
    "xlstm-125m": "xlstm_125m",
}


def get_config(arch_id: str, smoke: bool = False):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f".{name}", __package__)
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
