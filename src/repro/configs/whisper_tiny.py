"""whisper-tiny [audio]: encoder-decoder with conv frontend STUB.

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356].
Per the assignment the conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model); a learned frame projector
stands in for the two conv layers.  Decoder/encoder depths are both 4.
RoPE replaces Whisper's learned absolute positions (TPU-idiomatic;
documented deviation, see DESIGN.md).
"""
from ..models import ModelConfig

FULL = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_variant="gelu",
    tie_embeddings=True,
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    encoder_seq=16,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mlp_variant="gelu",
    dtype="float32",
    remat=False,
    full_size=False,
)
