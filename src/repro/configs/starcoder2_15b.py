"""starcoder2-15b [dense]: GQA kv=4, RoPE, GeLU MLP.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152 [arXiv:2402.19173].
"""
from ..models import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu",
    qkv_bias=True,
    rope_theta=100000.0,
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp_variant="gelu",
    qkv_bias=True,
    dtype="float32",
    remat=False,
    full_size=False,
)
