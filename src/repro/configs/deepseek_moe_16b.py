"""deepseek-moe-16b [moe]: fine-grained 64-expert top-6 + 2 shared experts.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400 [arXiv:2401.06066].
d_ff = 1408 is the *expert* hidden size; layer 0 keeps a dense FFN
(first_dense=1, per the DeepSeekMoE architecture).
"""
from ..models import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=102400,
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_dense=1, capacity_factor=1.25, group_size=1024),
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=2,
                  first_dense=1, capacity_factor=1.25, group_size=32),
    dtype="float32",
    remat=False,
    full_size=False,
)
