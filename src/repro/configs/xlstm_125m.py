"""xlstm-125m [ssm]: sLSTM + mLSTM blocks (attention-free).

12L d_model=768 4H d_ff=0 vocab=50304 [arXiv:2405.04517].  Blocks follow
an xLSTM[7:1]-style mix: every 4th block is sLSTM (scalar memory with
recurrent gating), the rest are mLSTM (matrix memory, exponential gating,
pf=2 up-projection).  O(1)-state decode makes this the canonical
long_500k architecture.
"""
from ..models import ModelConfig, SsmConfig

FULL = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SsmConfig(state_size=16, variant="mlstm", slstm_every=4,
                  proj_factor=2.0),
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    ssm=SsmConfig(state_size=4, variant="mlstm", slstm_every=4,
                  proj_factor=2.0),
    dtype="float32",
    remat=False,
    full_size=False,
)
