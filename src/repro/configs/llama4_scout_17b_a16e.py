"""llama4-scout-17b-a16e [moe]: 16-expert top-1 MoE with shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Every layer is MoE
(top-1 routed + 1 shared expert, Llama-4 style); early fusion is a no-op
here because the assigned shape set is text-only.
"""
from ..models import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=202048,
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, top_k=1, d_expert=8192, num_shared=1,
                  capacity_factor=2.0, group_size=1024),
    dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=1, d_expert=64, num_shared=1,
                  capacity_factor=2.0, group_size=32),
    dtype="float32",
    remat=False,
    full_size=False,
)
