"""Deterministic synthetic data pipelines.

Production shape without external datasets (the container is offline):

  * :class:`MarkovLM` — a *learnable* token stream: sequences sampled from a
    fixed random first-order Markov chain.  A model that learns the
    transition matrix drives CE loss toward the chain's entropy, so the
    end-to-end train drivers show real convergence, not noise-fitting.
  * :class:`SyntheticLMStream` — per-host sharded, step-seeded batches
    (restart-safe: batch at step k is a pure function of (seed, k, host)).
  * :class:`Prefetcher` — background-thread prefetch queue (overlaps host
    batch synthesis with device compute).
  * :func:`make_cluster_task` — Gaussian-cluster classification tasks for
    the paper's convergence-boundary experiments: the "easy" (CIFAR-10-like)
    and "hard" (CIFAR-100-like fine-grained) regimes are a single knob.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


class MarkovLM:
    """First-order Markov chain over ``vocab`` tokens, peaked transitions."""

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.5,
                 topk: int = 16):
        rng = np.random.RandomState(seed)
        k = min(topk, vocab)
        self.vocab = vocab
        # sparse transition structure: each token has k successors
        self.succ = np.argsort(rng.rand(vocab, vocab), axis=1)[:, :k]
        logits = rng.gumbel(size=(vocab, k)) / concentration
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.probs = p / p.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.RandomState, batch: int, seq: int
               ) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        out[:, 0] = rng.randint(0, self.vocab, batch)
        for t in range(seq):
            cur = out[:, t]
            # vectorized categorical draw over the k successors of each token
            cdf = np.cumsum(self.probs[cur], axis=1)
            u = rng.rand(batch, 1)
            idx = (u > cdf).sum(axis=1)
            out[:, t + 1] = self.succ[cur, idx]
        return out


@dataclasses.dataclass
class SyntheticLMStream:
    """Step-seeded LM batches: {'tokens': (B,S), 'labels': (B,S)}.

    ``batch`` is the *per-host* batch.  Deterministic per (seed, step,
    host_index): restart from a checkpoint at step k reproduces the exact
    remaining stream, which the checkpoint-resume tests rely on.
    """
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    start_step: int = 0
    learnable: bool = True

    def __post_init__(self):
        self._chain = MarkovLM(self.vocab, seed=self.seed) if self.learnable else None

    def batch_at(self, step: int) -> dict:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 97 + self.host_index) % (2**31 - 1))
        if self._chain is not None:
            toks = self._chain.sample(rng, self.batch, self.seq_len)
        else:
            toks = rng.randint(0, self.vocab,
                               (self.batch, self.seq_len + 1)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = self.start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(StopIteration)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is StopIteration:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


@dataclasses.dataclass
class ClassificationTask:
    """Gaussian-cluster classification with controllable difficulty."""
    num_classes: int
    dim: int
    centers: np.ndarray          # (C, dim)
    noise: float
    seed: int

    def sample(self, rng: np.random.RandomState, n: int):
        y = rng.randint(0, self.num_classes, n)
        x = self.centers[y] + rng.randn(n, self.dim) * self.noise
        return x.astype(np.float32), y.astype(np.int32)

    def batches(self, batch: int, seed_offset: int = 0):
        step = 0
        while True:
            rng = np.random.RandomState(self.seed + seed_offset + step)
            yield self.sample(rng, batch)
            step += 1


def make_cluster_task(num_classes: int, dim: int = 64, *,
                      hard: bool = False, seed: int = 0) -> ClassificationTask:
    """Easy regime: well-separated clusters (the CIFAR-10 analogue).
    Hard regime: fine-grained hierarchical clusters — superclass centers
    with tightly packed subclasses (the CIFAR-100 analogue), where the
    classifier head must resolve small-margin distinctions and sign-only
    updates lose the needed magnitude information.
    """
    rng = np.random.RandomState(seed)
    if not hard:
        centers = rng.randn(num_classes, dim) * 2.0
        return ClassificationTask(num_classes, dim, centers, noise=1.0,
                                  seed=seed)
    n_super = max(num_classes // 10, 1)
    supers = rng.randn(n_super, dim) * 2.0
    centers = np.stack([supers[i % n_super] + rng.randn(dim) * 0.35
                        for i in range(num_classes)])
    return ClassificationTask(num_classes, dim, centers, noise=0.55, seed=seed)
