"""Deterministic synthetic data pipelines (LM streams + classification tasks)."""
from .pipeline import (ClassificationTask, MarkovLM, Prefetcher,
                       SyntheticLMStream, make_cluster_task)

__all__ = ["ClassificationTask", "MarkovLM", "Prefetcher",
           "SyntheticLMStream", "make_cluster_task"]
