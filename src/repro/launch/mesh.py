"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run must set XLA_FLAGS
before anything initializes the backend.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.
    The 'pod' axis is outer data-parallelism across the (thin) inter-pod
    links — exactly the boundary where NEURON-Fabric's low-bit gradient
    aggregation buys the most (DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def dp_axes_of(mesh) -> tuple:
    """The data-parallel (gradient aggregation) axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a != "model")
