"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape_cell)`` returns the exact abstract inputs the
dry-run lowers against, per architecture family and evaluation cell:

  * train / prefill — token batches (+ patch features for the VLM stub,
    + frame embeddings for the audio stub);
  * decode — one new token, a KV/state cache sized to ``seq_len``, and the
    position scalar.

Weak-type-correct, shardable, and allocation-free by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import ModelConfig, ShapeCell, init_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    if cfg.family == "vlm":
        p = cfg.vision_patches
        text = s - p
        return {"tokens": sds((b, text), jnp.int32),
                "labels": sds((b, text), jnp.int32),
                "patch_feats": sds((b, p, cfg.vision_feat_dim), jnp.bfloat16)}
    if cfg.family == "encdec":
        return {"tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32),
                "frames": sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)}
    return {"tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"token": sds((b, 1), jnp.int32),
            "cache": cache,
            "position": sds((), jnp.int32)}


def state_specs(cfg: ModelConfig, optimizer, plan, rules=None, dp_size: int = 1):
    """Abstract TrainState via eval_shape (params + opt + EF sentinels)."""
    from ..fabric import Fabric, TrainState
    from ..models import init_params, param_pspecs

    fabric = Fabric(rules=rules, num_workers=dp_size)
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: optimizer.init(params))
    policies = fabric.resolve(params, plan, pspecs=param_pspecs(cfg))
    ef = jax.eval_shape(lambda: fabric.init_ef(params, policies))
    return TrainState(params=params, opt=opt, ef=ef,
                      step=sds((), jnp.int32))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """All model inputs for one evaluation cell (the assignment's API)."""
    if cell.kind in ("train", "prefill"):
        return train_batch_specs(cfg, cell)
    return decode_input_specs(cfg, cell)
