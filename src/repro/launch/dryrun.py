import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
# cell against 512 placeholder host devices, record memory_analysis(),
# cost_analysis(), and the parsed collective inventory for the roofline.
# The two lines above MUST run before any other import (JAX locks the device
# count at first init).  Usage:
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#       --mesh both --plans fp32,gbin_vote --out results/dryrun
#
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..fabric.control import plan_presets
from ..models import SHAPES, SHAPES_BY_NAME, init_cache
from ..optim import AdamW
from .hlo_analysis import (parse_collectives, roofline_terms,
                           summarize_collectives)
from .hlo_walk import walk
from .mesh import dp_axes_of, make_production_mesh
from .specs import input_specs, state_specs, train_batch_specs

#: one source of named plans for every launcher (repro.fabric.control);
#: the dry-run compiles any subset of them per (arch x shape x mesh) cell
PLANS = plan_presets()


def cell_skipped(cfg, cell) -> str | None:
    """Assignment skip rules (documented in DESIGN.md §Arch-applicability)."""
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return "long_500k skipped: pure full-attention architecture"
    return None


#: crude per-device compute estimate for the simulated timeline: v5e-ish
#: bf16 peak, derated to a realistic MFU.
V5E_PEAK_FLOPS = 197e12
SIM_MFU = 0.4
#: topologies the dry-run's simulated-timeline section replays per cell
SIM_TOPOLOGIES = ("ici_ring", "cxl_switched", "multihop")


def run_train_cell(cfg, cell, mesh, plan_name: str,
                   grad_accum: int = 1) -> dict:
    from ..fabric import Fabric
    plan = PLANS[plan_name]
    fabric = Fabric(mesh, dp_axes_of(mesh))
    optimizer = AdamW(peak_lr=1e-4)
    state = state_specs(cfg, optimizer, plan, dp_size=fabric.num_workers)
    batch = train_batch_specs(cfg, cell)
    step = fabric.build_step(cfg, optimizer, plan, state.params,
                             grad_accum=grad_accum, donate=False)
    t0 = time.time()
    lowered = step.step_fn.lower(state, batch)
    compiled = lowered.compile()
    result = analyze(compiled, mesh, t0, cfg, cell, extra={
        "plan": plan_name, "num_workers": step.aux["num_workers"]})
    # simulated collective timeline (repro.sim): the cell's bucket layout
    # replayed per topology against an MFU-derated compute estimate
    compute_s = (model_flops_per_device(cfg, cell, mesh.devices.size)
                 / (V5E_PEAK_FLOPS * SIM_MFU))
    result["sim"] = {
        topo: fabric.simulate(state.params, plan, topology=topo,
                              compute_time_s=compute_s).summary()
        for topo in SIM_TOPOLOGIES}
    return result


def run_decode_cell(cfg, cell, mesh) -> dict:
    from ..runtime.serve import build_serve_step
    dp = dp_axes_of(mesh)
    spec = input_specs(cfg, cell)
    jitted, sh = build_serve_step(cfg, mesh, batch=cell.global_batch,
                                  max_seq=cell.seq_len, dp_axes=dp,
                                  donate=False)
    from ..models import init_params
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    t0 = time.time()
    lowered = jitted.lower(params, spec["token"], spec["cache"],
                           spec["position"])
    compiled = lowered.compile()
    return analyze(compiled, mesh, t0, cfg, cell, extra={
        "plan": "serve", "shard_seq": bool(sh["shard_seq"])})


def run_prefill_cell(cfg, cell, mesh) -> dict:
    from ..runtime.serve import build_prefill
    dp = dp_axes_of(mesh)
    batch = train_batch_specs(cfg, cell)
    batch.pop("labels")
    jitted = build_prefill(cfg, mesh, dp_axes=dp)
    from ..models import init_params
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    t0 = time.time()
    lowered = jitted.lower(params, batch)
    compiled = lowered.compile()
    return analyze(compiled, mesh, t0, cfg, cell, extra={"plan": "prefill"})


def model_flops_per_device(cfg, cell, num_devices: int) -> float:
    """MODEL_FLOPS: 6*N_active*D (train) / 2*N_active*tokens (inference)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens / num_devices
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens / num_devices
    return 2.0 * n * cell.global_batch / num_devices   # decode: 1 new token


def analyze(compiled, mesh, t0: float, cfg, cell, extra: dict) -> dict:
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    num_devices = mesh.devices.size
    pod_size = (num_devices // mesh.shape["pod"]
                if "pod" in mesh.axis_names else 0)
    # while-aware walk: correct flops/bytes/wire for scanned layer stacks
    wk = walk(hlo, pod_size=pod_size)
    colls = parse_collectives(hlo, pod_size=pod_size)   # static inventory
    csum = summarize_collectives(colls)
    csum["total_wire_bytes"] = wk["wire_bytes"]         # loop-corrected
    csum["pod_crossing_wire_bytes"] = wk["pod_wire_bytes"]
    csum["wire_breakdown_top"] = dict(
        list(wk["wire_breakdown"].items())[:10])
    flops = wk["flops"]
    hbm_bytes = wk["hbm_bytes"]
    roof = roofline_terms(flops, hbm_bytes, wk["wire_bytes"])
    mflops = model_flops_per_device(cfg, cell, num_devices)
    roof["model_flops_per_device"] = mflops
    roof["useful_flop_ratio"] = mflops / flops if flops else 0.0
    return {
        **extra,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a])
                                           for a in mesh.axis_names])),
        "num_devices": int(num_devices),
        "compile_s": compile_s,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.temp_size_in_bytes),
        },
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "loop_trip_counts": wk["loops"],
        "collectives": csum,
        "roofline": roof,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, plan: str,
             out_dir: str, force: bool = False,
             grad_accum: int = 1, tag_suffix: str = "",
             moe_cf: float = 0.0, remat_policy: str = "") -> dict | None:
    import dataclasses
    cfg = get_config(arch)
    if moe_cf and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=moe_cf))
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    cell = SHAPES_BY_NAME[shape]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tag = (plan if cell.is_train else cell.kind) + tag_suffix
    path = os.path.join(out_dir, mesh_name, arch, f"{shape}.{tag}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {mesh_name}/{arch}/{shape}.{tag}")
        with open(path) as f:
            return json.load(f)

    skip = cell_skipped(cfg, cell)
    if skip:
        result = {"skipped": skip}
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            with jax.set_mesh(mesh):
                if cell.kind == "train":
                    result = run_train_cell(cfg, cell, mesh, plan,
                                            grad_accum=grad_accum)
                elif cell.kind == "prefill":
                    result = run_prefill_cell(cfg, cell, mesh)
                else:
                    result = run_decode_cell(cfg, cell, mesh)
        except Exception as e:  # record failures; they are bugs to fix
            result = {"error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-4000:]}
    result.update({"arch": arch, "shape": shape, "mesh_name": mesh_name})
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    status = ("SKIP" if "skipped" in result
              else "FAIL" if "error" in result else
              f"ok {result['compile_s']:.0f}s dom={result['roofline']['dominant']}")
    print(f"[{mesh_name}] {arch} {shape} ({tag}): {status}", flush=True)
    if "error" in result:
        print(result["error"], flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--plans", default="gbin_vote",
                    help="comma-separated train plans (fp32,gbin_vote,...)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--moe-cf", type=float, default=0.0)
    ap.add_argument("--remat-policy", default="")
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    plans = args.plans.split(",")

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                cell = SHAPES_BY_NAME[shape]
                cell_plans = plans if cell.is_train else ["serve"]
                for plan in cell_plans:
                    r = run_cell(arch, shape, mp, plan, args.out,
                                 force=args.force,
                                 grad_accum=args.grad_accum,
                                 moe_cf=args.moe_cf,
                                 remat_policy=args.remat_policy,
                                 tag_suffix=args.tag_suffix)
                    if r and "error" in r:
                        failures += 1
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
