"""Production training launcher.

Single-host entry point (multi-host: same binary under your cluster
scheduler with jax.distributed.initialize — the Trainer, checkpoint, and
data layers are already host-indexed).  Examples:

  # 8 simulated devices, qwen3 smoke config, G-Binary backbone:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0p6b --smoke \\
      --mesh 4,2 --steps 100 --plan gbin_backbone

  # adaptive control plane (warm-up -> calibrate -> admit -> guarded):
  ... --plan adaptive          # equivalent: --controller paper

Plan names resolve through ``repro.fabric.control.plan_presets`` (the
same table the dry-run uses); ``--controller`` accepts any name in the
``@register_controller`` registry.
"""
import argparse
import logging
import os

#: preset names, hardcoded so --help works without importing jax;
#: validated against plan_presets() at startup
_PLAN_CHOICES = ["fp32", "gbin_backbone", "gbin_vote", "gbin_packed",
                 "gter_backbone", "gter_vote", "lowbit_all",
                 "gbin_packed_all", "gbin_packed_embed",
                 "int4_backbone", "topk_backbone", "adaptive"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model (or pod,data,model) mesh shape")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--plan", default="gbin_backbone", choices=_PLAN_CHOICES)
    ap.add_argument("--controller", default=None,
                    help="registered admission controller driving the run "
                         "(e.g. paper, static, fp32); overrides --plan")
    ap.add_argument("--warmup-steps", type=int, default=20,
                    help="FP32 calibration window of the paper controller")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device-count", type=int, default=0,
                    help="force host platform device count (CPU sim)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.device_count}").strip()

    import jax
    from jax.sharding import AxisType

    from ..configs import get_config
    from ..data import SyntheticLMStream
    from ..fabric import Fabric
    from ..fabric.control import plan_presets
    from ..optim import AdamW, SgdMomentum
    from ..runtime import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))
    dp_axes = tuple(a for a in axes if a != "model")

    cfg = get_config(args.arch, smoke=args.smoke)
    data = SyntheticLMStream(vocab=cfg.vocab_size, seq_len=args.seq_len,
                             batch=args.global_batch, seed=args.seed)

    opt_cls = AdamW if args.optimizer == "adamw" else SgdMomentum
    optimizer = opt_cls(peak_lr=args.lr, total_steps=args.steps)

    plans = plan_presets(error_feedback=args.error_feedback)
    assert set(_PLAN_CHOICES) == set(plans) | {"adaptive"}, \
        "launcher plan choices drifted from plan_presets()"

    fabric = Fabric(mesh, dp_axes)
    plan = None
    controller_name = args.controller or (
        "paper" if args.plan == "adaptive" else None)
    if controller_name in ("paper", "adaptive"):
        fabric.attach_controller(controller_name,
                                 warmup_steps=args.warmup_steps)
    elif controller_name == "static":
        if args.plan == "adaptive":
            ap.error("--controller static needs a concrete --plan preset")
        fabric.attach_controller("static", plan=plans[args.plan])
    elif controller_name is not None:
        fabric.attach_controller(controller_name)
    else:
        plan = plans[args.plan]

    trainer = Trainer(
        cfg, mesh, optimizer, data, plan=plan, fabric=fabric,
        tcfg=TrainerConfig(dp_axes=dp_axes,
                           checkpoint_interval=args.ckpt_interval),
        ckpt_dir=args.ckpt_dir, seed=args.seed)
    history = trainer.run(args.steps)
    last = history[-1]
    print(f"final: step={last['step']} loss={last['loss']:.4f} "
          f"traffic={last['traffic_ratio']:.4f} "
          f"restarts={trainer.restarts} "
          f"stragglers={len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
