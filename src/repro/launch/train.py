"""Production training launcher.

Single-host entry point (multi-host: same binary under your cluster
scheduler with jax.distributed.initialize — the Trainer, checkpoint, and
data layers are already host-indexed).  Examples:

  # 8 simulated devices, qwen3 smoke config, G-Binary backbone:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0p6b --smoke \\
      --mesh 4,2 --steps 100 --plan gbin_backbone

  # adaptive control plane (warm-up -> calibrate -> admit -> guarded):
  ... --plan adaptive
"""
import argparse
import logging
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model (or pod,data,model) mesh shape")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--plan", default="gbin_backbone",
                    choices=["fp32", "gbin_backbone", "gbin_packed",
                             "gter_backbone", "lowbit_all", "adaptive"])
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device-count", type=int, default=0,
                    help="force host platform device count (CPU sim)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.device_count}").strip()

    import jax
    from jax.sharding import AxisType

    from ..configs import get_config
    from ..core import (AdmissionPlan, AggregationMode, Commander,
                        ControlPlane, Schedule, Supervisor)
    from ..data import SyntheticLMStream
    from ..fabric import Fabric
    from ..optim import AdamW, SgdMomentum
    from ..runtime import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))
    dp_axes = tuple(a for a in axes if a != "model")

    cfg = get_config(args.arch, smoke=args.smoke)
    data = SyntheticLMStream(vocab=cfg.vocab_size, seq_len=args.seq_len,
                             batch=args.global_batch, seed=args.seed)

    opt_cls = AdamW if args.optimizer == "adamw" else SgdMomentum
    optimizer = opt_cls(peak_lr=args.lr, total_steps=args.steps)

    ef = args.error_feedback
    plans = {
        "fp32": AdmissionPlan.fp32_all(),
        "gbin_backbone": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, error_feedback=ef),
        "gbin_packed": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, schedule=Schedule.PACKED_A2A,
            error_feedback=ef),
        "gter_backbone": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_TERNARY, error_feedback=ef),
        "lowbit_all": AdmissionPlan.lowbit_all(
            AggregationMode.G_BINARY, error_feedback=ef),
    }
    control = plan = None
    if args.plan == "adaptive":
        control = ControlPlane(commander=Commander(),
                               supervisor=Supervisor(), warmup_steps=20)
    else:
        plan = plans[args.plan]

    fabric = Fabric(mesh, dp_axes)
    trainer = Trainer(
        cfg, mesh, optimizer, data, plan=plan, control=control,
        fabric=fabric,
        tcfg=TrainerConfig(dp_axes=dp_axes,
                           checkpoint_interval=args.ckpt_interval),
        ckpt_dir=args.ckpt_dir, seed=args.seed)
    history = trainer.run(args.steps)
    last = history[-1]
    print(f"final: step={last['step']} loss={last['loss']:.4f} "
          f"traffic={last['traffic_ratio']:.4f} "
          f"restarts={trainer.restarts} "
          f"stragglers={len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
