"""Production training launcher.

Single-host entry point (multi-host: same binary under your cluster
scheduler with jax.distributed.initialize — the Trainer, checkpoint, and
data layers are already host-indexed).  Examples:

  # 8 simulated devices, qwen3 smoke config, G-Binary backbone:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0p6b --smoke \\
      --mesh 4,2 --steps 100 --plan gbin_backbone

  # adaptive control plane (warm-up -> calibrate -> admit -> guarded):
  ... --plan adaptive          # equivalent: --controller paper

Plan names resolve through ``repro.fabric.control.plan_presets`` (the
same table the dry-run uses); ``--controller`` accepts any name in the
``@register_controller`` registry.
"""
import argparse
import logging
import os

#: preset names, hardcoded so --help works without importing jax;
#: validated against plan_presets() at startup
_PLAN_CHOICES = ["fp32", "gbin_backbone", "gbin_vote", "gbin_packed",
                 "gter_backbone", "gter_vote", "lowbit_all",
                 "gbin_packed_all", "gbin_packed_embed",
                 "int4_backbone", "topk_backbone",
                 "hier_fp32_gbinary", "hier_fp32_gternary",
                 "hier_fp32_int4", "adaptive"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model (or pod,data,model) mesh shape")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--plan", default="gbin_backbone", choices=_PLAN_CHOICES)
    ap.add_argument("--controller", default=None,
                    help="registered admission controller driving the run "
                         "(e.g. paper, static, fp32); overrides --plan")
    ap.add_argument("--autotune", action="store_true",
                    help="search plan_presets + generated low-bit plans "
                         "offline (repro.tune) and train on the winner; "
                         "overrides --plan / --controller")
    ap.add_argument("--autotune-topology", default="ici_ring",
                    help="sim topology the autotuner certifies against")
    ap.add_argument("--autotune-strategy", default="grid",
                    help="registered search strategy (grid, random, "
                         "successive_halving)")
    ap.add_argument("--autotune-out", default=None,
                    help="write the TunedPlan artifact JSON here")
    ap.add_argument("--warmup-steps", type=int, default=20,
                    help="FP32 calibration window of the paper controller")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device-count", type=int, default=0,
                    help="force host platform device count (CPU sim)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.device_count}").strip()

    import jax
    from jax.sharding import AxisType

    from ..configs import get_config
    from ..data import SyntheticLMStream
    from ..fabric import Fabric
    from ..fabric.control import plan_presets
    from ..optim import AdamW, SgdMomentum
    from ..runtime import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))
    dp_axes = tuple(a for a in axes if a != "model")

    cfg = get_config(args.arch, smoke=args.smoke)
    data = SyntheticLMStream(vocab=cfg.vocab_size, seq_len=args.seq_len,
                             batch=args.global_batch, seed=args.seed)

    opt_cls = AdamW if args.optimizer == "adamw" else SgdMomentum
    optimizer = opt_cls(peak_lr=args.lr, total_steps=args.steps)

    plans = plan_presets(error_feedback=args.error_feedback)
    # built-in choices must all resolve; plan_presets may additionally
    # carry runtime-registered extras (register_plan_preset), which the
    # static --help choices deliberately don't enumerate
    assert set(_PLAN_CHOICES) - {"adaptive"} <= set(plans), \
        "launcher plan choices drifted from plan_presets()"

    fabric = Fabric(mesh, dp_axes)
    plan = None
    controller_name = args.controller or (
        "paper" if args.plan == "adaptive" else None)
    if args.autotune:
        from ..models import init_params
        params_like = jax.eval_shape(
            lambda: init_params(jax.random.key(args.seed), cfg))
        tuned = fabric.autotune(params_like,
                                topology=args.autotune_topology,
                                strategy=args.autotune_strategy,
                                error_feedback=args.error_feedback)
        if args.autotune_out:
            tuned.save(args.autotune_out)
        logging.getLogger("repro.launch").info(
            "autotuned plan %s (%s): step=%.1fus, %d runners-up",
            tuned.name, tuned.plan.signature(),
            tuned.score.step_time_s * 1e6, len(tuned.runners_up))
        tuned.apply(fabric)     # adopt the tuned bucket budget
        # the "tuned" controller latches the winner and re-ranks the
        # sim-certified shortlist from live step times
        fabric.attach_controller("tuned", tuned=tuned)
    elif controller_name in ("paper", "adaptive"):
        fabric.attach_controller(controller_name,
                                 warmup_steps=args.warmup_steps)
    elif controller_name == "static":
        if args.plan == "adaptive":
            ap.error("--controller static needs a concrete --plan preset")
        fabric.attach_controller("static", plan=plans[args.plan])
    elif controller_name is not None:
        fabric.attach_controller(controller_name)
    else:
        plan = plans[args.plan]

    trainer = Trainer(
        cfg, mesh, optimizer, data, plan=plan, fabric=fabric,
        tcfg=TrainerConfig(dp_axes=dp_axes,
                           checkpoint_interval=args.ckpt_interval),
        ckpt_dir=args.ckpt_dir, seed=args.seed)
    history = trainer.run(args.steps)
    last = history[-1]
    print(f"final: step={last['step']} loss={last['loss']:.4f} "
          f"traffic={last['traffic_ratio']:.4f} "
          f"restarts={trainer.restarts} "
          f"stragglers={len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
