"""Post-partitioning HLO analysis: collective inventory + roofline terms.

``cost_analysis()`` gives per-device FLOPs and HBM bytes but not collective
traffic, so collective bytes are parsed from the compiled HLO text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op's operand sizes and replica groups, folded through a ring cost model
into per-device wire bytes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> tuple[int, Optional[list[list[int]]]]:
    """Return (group_size, groups or None)."""
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        groups = [[int(x) for x in g.split(",") if x]
                  for g in re.findall(r"\{([^}]*)\}", m.group(1))]
        return (len(groups[0]) if groups else 1), groups
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        src = [int(x) for x in m.group(3).split(",")]
        try:
            import numpy as np
            ids = np.arange(int(np.prod(src))).reshape(src)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(ng, gs).tolist()
        except Exception:
            groups = None
        return gs, groups
    m = _PAIRS_RE.search(line)
    if m:   # collective-permute
        pairs = [tuple(int(x) for x in p.split(","))
                 for p in re.findall(r"\{(\d+,\d+)\}", "{" + m.group(1) + "}")]
        return 2, [list(p) for p in pairs] if pairs else None
    return 1, None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_total: int          # per-device payload size of the op's output
    group_size: int
    wire_bytes: float         # per-device bytes crossing links (ring model)
    crosses_pod: bool
    dtype: str = ""


def _wire_bytes(kind: str, payload: int, g: int) -> float:
    """Ring-model per-device wire bytes for one collective."""
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * f * payload
    if kind == "all-gather":
        return f * payload                 # payload = gathered output
    if kind == "reduce-scatter":
        return (g - 1) * payload           # payload = scattered output
    if kind == "all-to-all":
        return f * payload
    if kind == "collective-permute":
        return float(payload)
    return 0.0


def parse_collectives(hlo: str, pod_size: int = 0) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo.splitlines():
        m = _LINE_RE.search(line)
        if m is None or "-done(" in line:
            continue
        sig, kind = m.group(1), m.group(2)
        payload = _shape_bytes(sig)
        gsize, groups = _parse_groups(line)
        crosses = False
        if pod_size and groups:
            for grp in groups:
                pods = {d // pod_size for d in grp}
                if len(pods) > 1:
                    crosses = True
                    break
        dts = _SHAPE_RE.findall(sig)
        dtype = dts[0][0] if dts else ""
        ops.append(CollectiveOp(
            kind=kind, bytes_total=payload, group_size=gsize,
            wire_bytes=_wire_bytes(kind, payload, gsize),
            crosses_pod=crosses, dtype=dtype))
    return ops


def summarize_collectives(ops: list[CollectiveOp]) -> dict:
    by_kind: dict[str, dict] = {}
    by_dtype: dict[str, float] = {}
    by_group: dict[str, float] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "wire_bytes": 0.0,
                                         "payload_bytes": 0})
        d["count"] += 1
        d["wire_bytes"] += op.wire_bytes
        d["payload_bytes"] += op.bytes_total
        by_dtype[op.dtype] = by_dtype.get(op.dtype, 0.0) + op.wire_bytes
        key = f"g{op.group_size}"
        by_group[key] = by_group.get(key, 0.0) + op.wire_bytes
    total_wire = sum(o.wire_bytes for o in ops)
    pod_wire = sum(o.wire_bytes for o in ops if o.crosses_pod)
    top = sorted(ops, key=lambda o: -o.wire_bytes)[:8]
    return {
        "total_wire_bytes": total_wire,
        "pod_crossing_wire_bytes": pod_wire,
        "num_ops": len(ops),
        "by_kind": by_kind,
        "by_dtype": by_dtype,
        "by_group_size": by_group,
        "top_ops": [{"kind": o.kind, "dtype": o.dtype,
                     "group": o.group_size, "wire_bytes": o.wire_bytes}
                    for o in top],
    }


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one direction)


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   wire_bytes_per_device: float) -> dict:
    t_comp = flops_per_device / PEAK_FLOPS
    t_mem = hbm_bytes_per_device / HBM_BW
    t_coll = wire_bytes_per_device / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    total = t_comp + t_mem + t_coll
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "roofline_fraction": (t_comp / bound) if bound > 0 else 0.0,
        "step_time_lower_bound_s": bound,
    }
