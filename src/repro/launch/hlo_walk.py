"""While-aware HLO cost walk: FLOPs / bytes / collectives with loop trip counts.

``compiled.cost_analysis()`` (xla::HloCostAnalysis) visits each computation
once, so anything under a ``lax.scan`` — our scanned transformer layers, the
flash-attention block loops, the SSM time scans — is counted a single time
instead of ``trip_count`` times.  For a 62-layer scanned model that is a
~60x undercount of compute and collective traffic.

This walker parses the post-optimization HLO text into a computation graph,
extracts each ``while`` loop's trip count from its condition computation
(`compare(induction, constant(N)) direction=LT`), and accumulates:

  * FLOPs: dot / convolution ops (2 * prod(out) * contraction), resolved
    through operand shapes; fused multiply-add convention matches XLA's.
  * HBM bytes: per top-level instruction, operand + output sizes — fusions
    count as single ops (their internals never touch HBM), matching the
    semantics of "bytes accessed".
  * Collectives: payloads folded through the ring model (hlo_analysis).

Everything is multiplied by the product of enclosing loop trip counts.
Validated against an unrolled-vs-scanned compile of the same model (see
tests/test_hlo_walk.py): scanned+walker == unrolled+cost_analysis within a
few percent.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .hlo_analysis import DTYPE_BYTES, _parse_groups, _wire_bytes

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},\s]+?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_WHILE_REFS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_DOT_DNUMS = re.compile(
    r"lhs_contracting_dims=\{([\d,]*)\}.*?rhs_contracting_dims=\{([\d,]*)\}")
_DOT_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(sig: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(sig):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _sig_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _shape_list(sig):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    sig: str
    opcode: str
    rest: str
    out_bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(2), instrs=[],
                                  is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            name, sig, opcode, rest = m.groups()
            cur.instrs.append(Instr(name=name, sig=sig.strip(), opcode=opcode,
                                    rest=rest, out_bytes=_sig_bytes(sig)))
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition (LT-bound heuristic)."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        for c in _CONST_INT.findall(ins.sig + " " + ins.rest):
            best = max(best, int(c))
    return best


def _operand_shapes(ins: Instr, by_name: dict[str, Instr]) -> list[str]:
    """Signatures of this instruction's operands (resolved refs)."""
    ops = []
    for ref in re.findall(r"%([\w\.\-]+)", ins.rest.split(")")[0]):
        if ref in by_name:
            ops.append(by_name[ref].sig)
    return ops


def _dot_flops(ins: Instr, by_name: dict[str, Instr]) -> float:
    """2 * prod(output) * contraction_size for dot/custom matmul."""
    shapes = _shape_list(ins.sig)
    if not shapes:
        return 0.0
    out_elems = 1
    for d in shapes[0][1]:
        out_elems *= d
    ops = _operand_shapes(ins, by_name)
    if not ops:
        return 0.0
    lhs = _shape_list(ops[0])
    if not lhs:
        return 0.0
    m = _DOT_DNUMS.search(ins.rest)
    if m:
        cdims = [int(x) for x in m.group(1).split(",") if x]
        k = 1
        for c in cdims:
            if c < len(lhs[0][1]):
                k *= lhs[0][1][c]
    else:
        k = lhs[0][1][-1] if lhs[0][1] else 1   # assume last-dim contraction
    return 2.0 * out_elems * k


def _fusion_bytes(ins: Instr, by_name: dict, comps: dict) -> float:
    """Bytes for a fusion op, slice-aware.

    Scanned layer stacks reach fusions as full (L, ...) operands that are
    dynamic-sliced *inside* the fused computation — counting the full
    operand per loop iteration overstates HBM traffic by ~L x.  For each
    fusion parameter consumed (directly) by a dynamic-slice, charge the
    slice size; a root dynamic-update-slice charges the update extent
    instead of the full output.
    """
    total = float(ins.out_bytes)
    called = None
    m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    if m and m.group(1) in comps:
        called = comps[m.group(1)]
    if called is None:
        return total + sum(_sig_bytes(s) for s in _operand_shapes(ins, by_name))
    inner_by_name = {i.name: i for i in called.instrs}
    # map param order -> slice-consumption
    params = [i for i in called.instrs if i.opcode == "parameter"]
    sliced_cost: dict[str, float] = {}
    for i in called.instrs:
        if i.opcode in ("dynamic-slice", "slice", "gather"):
            refs = re.findall(r"%([\w\.\-]+)", i.rest.split(")")[0])
            if refs and refs[0] in inner_by_name \
                    and inner_by_name[refs[0]].opcode == "parameter":
                pname = refs[0]
                sliced_cost[pname] = min(
                    sliced_cost.get(pname, float("inf")), float(i.out_bytes))
        if i.opcode == "dynamic-update-slice":
            ops_in = re.findall(r"%([\w\.\-]+)", i.rest.split(")")[0])
            if len(ops_in) > 1 and ops_in[1] in inner_by_name:
                upd = inner_by_name[ops_in[1]].out_bytes
                total = total - ins.out_bytes + 2.0 * upd
    # operand order corresponds to parameter order
    operand_refs = re.findall(r"%([\w\.\-]+)", ins.rest.split(")")[0])
    for idx, ref in enumerate(operand_refs):
        if ref not in by_name:
            continue
        full = float(_sig_bytes(by_name[ref].sig))
        if idx < len(params) and params[idx].name in sliced_cost:
            total += min(full, sliced_cost[params[idx].name])
        else:
            total += full
    return total


def walk(hlo: str, pod_size: int = 0) -> dict:
    comps = parse_module(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "wire_bytes": 0.0,
                "pod_wire_bytes": 0.0, "loops": {}, "wire_breakdown": {}}

    memo: dict[str, tuple] = {}
    loops: dict[str, int] = {}

    def _merge(dst, src, mult=1.0):
        for k, v in src.items():
            dst[k] = dst.get(k, 0.0) + mult * v
        return dst

    def visit(comp: Computation) -> tuple:
        if comp.name in memo:
            return memo[comp.name]
        by_name = {i.name: i for i in comp.instrs}
        flops = bytes_ = wire = pod_wire = 0.0
        breakdown: dict[str, float] = {}
        for ins in comp.instrs:
            op = ins.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            if op == "while":
                m = _WHILE_REFS.search(ins.rest)
                if m and m.group(1) in comps and m.group(2) in comps:
                    trip = _trip_count(comps[m.group(1)])
                    loops[m.group(2)] = trip
                    f, b, w, pw, bd = visit(comps[m.group(2)])
                    flops += trip * f
                    bytes_ += trip * b
                    wire += trip * w
                    pod_wire += trip * pw
                    _merge(breakdown, bd, trip)
                continue
            if op in ("call", "conditional", "async-start"):
                for ref in _CALLS.findall(ins.rest):
                    if ref in comps:
                        f, b, w, pw, bd = visit(comps[ref])
                        flops += f
                        bytes_ += b
                        wire += w
                        pod_wire += pw
                        _merge(breakdown, bd)
                continue
            if op in COLLECTIVES or (op.endswith("-start")
                                     and op[:-6] in COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                payload = ins.out_bytes
                gsize, groups = _parse_groups(ins.rest)
                wb = _wire_bytes(kind, payload, gsize)
                wire += wb
                bytes_ += ins.out_bytes
                dts = _shape_list(ins.sig)
                dt = dts[0][0] if dts else "?"
                breakdown[f"{kind}/{dt}/g{gsize}"] = \
                    breakdown.get(f"{kind}/{dt}/g{gsize}", 0.0) + wb
                if pod_size and groups and any(
                        len({d // pod_size for d in g}) > 1 for g in groups):
                    pod_wire += wb
                continue
            if op == "dynamic-slice":
                # in-place semantics: reads only the slice it produces
                bytes_ += 2 * ins.out_bytes
                continue
            if op == "dynamic-update-slice":
                # in-place: writes only the update operand's extent
                ops_sh = _operand_shapes(ins, by_name)
                upd = _sig_bytes(ops_sh[1]) if len(ops_sh) > 1 else ins.out_bytes
                bytes_ += 2 * upd
                continue
            if op == "fusion":
                bytes_ += _fusion_bytes(ins, by_name, comps)
                flops += ins.out_bytes / 4.0
                continue
            # memory: operands + output
            opb = sum(_sig_bytes(s) for s in _operand_shapes(ins, by_name))
            bytes_ += ins.out_bytes + opb
            if op in ("dot", "convolution") or (
                    op == "custom-call" and "matmul" in ins.rest):
                flops += _dot_flops(ins, by_name)
            elif False:
                pass      # ~1 flop per f32 element
        memo[comp.name] = (flops, bytes_, wire, pod_wire, breakdown)
        return memo[comp.name]

    # fusions reference their computations via calls=; don't double count:
    # we only recurse through while/call/conditional, never fusion bodies.
    f, b, w, pw, bd = visit(entry)
    return {"flops": f, "hbm_bytes": b, "wire_bytes": w,
            "pod_wire_bytes": pw, "loops": loops,
            "wire_breakdown": dict(sorted(bd.items(), key=lambda x: -x[1]))}
