"""Roofline report generator: dry-run JSONs -> markdown tables.

Usage: PYTHONPATH=src python -m repro.launch.report [--out EXPERIMENTS_roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(results_dir: str) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*", "*", "*.json"))):
        d = json.load(open(f))
        d["_file"] = f
        cells.append(d)
    return cells


def fmt_table(cells: list[dict], mesh_name: str, plan_filter=None) -> str:
    rows = [
        "| arch | shape | plan | compute s | memory s | collective s | "
        "dominant | roofline frac | useful | peak GiB | pod-wire GiB |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        if d.get("mesh_name") != mesh_name:
            continue
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | "
                        f"skipped | — | — | — | — |")
            continue
        if "error" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | ? | ERROR | | | | | | | |")
            continue
        plan = d.get("plan", "?")
        if plan_filter and plan not in plan_filter:
            continue
        r = d["roofline"]
        mem = d["memory"]["peak_estimate_bytes"] / 2**30
        podw = d["collectives"].get("pod_crossing_wire_bytes", 0) / 2**30
        rows.append(
            f"| {d['arch']} | {d['shape']} | {plan} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r.get('useful_flop_ratio', 0):.2f} | {mem:.1f} "
            f"| {podw:.1f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--plans", default=None)
    args = ap.parse_args()
    cells = load_cells(args.results)
    pf = args.plans.split(",") if args.plans else None
    print(fmt_table(cells, args.mesh, plan_filter=pf))


if __name__ == "__main__":
    main()
