"""Quickstart: train a small LM with NEURON-Fabric low-bit gradient
aggregation on simulated devices, watching traffic drop ~28x for the
admitted backbone while loss converges.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
from jax.sharding import AxisType

from repro.configs import get_config
from repro.core import AdmissionPlan, AggregationMode, Schedule
from repro.data import SyntheticLMStream
from repro.fabric import Fabric
from repro.optim import AdamW
from repro.runtime import Trainer, TrainerConfig


def main():
    # 8 simulated devices: 4-way data parallel x 2-way tensor parallel
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

    # One Fabric session owns the aggregation surface: worker count,
    # policy resolution, schedule-backend dispatch, compiled-step cache.
    fabric = Fabric(mesh, dp_axes=("data",))

    cfg = get_config("qwen3_0p6b", smoke=True)      # reduced qwen3 family
    data = SyntheticLMStream(vocab=cfg.vocab_size, seq_len=64, batch=16,
                             seed=0)

    # The paper's recovered operating point: G-Binary backbone via the
    # packed controller schedule, FP32 head/embeddings/norms.
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.PACKED_A2A)

    # Simulate your plan before training it: the same bucket layout the
    # train step will launch, replayed by the repro.sim discrete-event
    # simulator on two interconnects — is the aggregation datapath
    # hidden behind the collective, or exposed in the step time?
    from repro.models import init_params
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    for topo in ("ici_ring", "cxl_switched"):
        rep = fabric.simulate(params, plan, topology=topo,
                              compute_time_s=1e-3)
        print(f"[sim:{topo}] launches={rep.num_launches} "
              f"step={rep.step_time_s * 1e3:.2f}ms "
              f"exposed={rep.exposed_pct:.2f}% of step "
              f"({'datapath hidden' if rep.hidden else 'datapath exposed'})")

    # Fused datapath (DESIGN §12): codecs that bring a KernelSet lower
    # each bucket's encode→vote→decode(+EF) as fused Pallas kernels —
    # bit-identical to the staged reference path, fewer launches, less
    # HBM traffic.  layout_kernel_stats prices the exact bucket layout
    # the train step below will launch.
    from repro.fabric import layout_kernel_stats
    layout = fabric.layout_for(params, plan)
    stats = layout_kernel_stats(layout, fabric.num_workers)
    print(f"[kernels] buckets={stats['collectives']} "
          f"launches fused={stats['launches_fused']} "
          f"vs unfused={stats['launches_unfused']}, HBM/step "
          f"{stats['hbm_bytes_fused'] / 2**20:.0f}MiB fused vs "
          f"{stats['hbm_bytes_unfused'] / 2**20:.0f}MiB unfused "
          f"(opt out: Fabric(..., fused_kernels=False))")
    assert stats["launches_fused"] < stats["launches_unfused"]

    trainer = Trainer(cfg, mesh, AdamW(peak_lr=2e-3, total_steps=200),
                      data, plan=plan, fabric=fabric,
                      tcfg=TrainerConfig(dp_axes=("data",), log_interval=20))
    history = trainer.run(120)

    first, last = history[0], history[-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{len(history)} steps")
    print(f"gradient traffic vs FP32: {last['traffic_ratio']:.4f} "
          f"(G-Binary backbone + FP32 head)")
    assert last["loss"] < first["loss"], "did not converge"
    print("OK")


if __name__ == "__main__":
    main()
