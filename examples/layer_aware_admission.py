"""Paper Section 7 end-to-end: the convergence boundary and its recovery.

Reproduces (on synthetic tasks — see EXPERIMENTS.md) the paper's central
result chain:
  1. easy workload: full-path low-bit aggregation stays near FP32;
  2. hard fine-grained workload: full-path low-bit collapses;
  3. cosine diagnostics localize the sensitive group;
  4. layer-aware admission (low-bit backbone + FP32 head) recovers the
     accuracy at a fraction of the gradient traffic;
  5. the same operating point expressed as a user-defined
     :class:`repro.fabric.control.PolicyProgram` phase schedule
     ("everything low-bit, head back on FP32 after step N").

Run:  PYTHONPATH=src python examples/layer_aware_admission.py [--fast]
"""
import argparse

from repro.core.experiments import easy_task, hard_task, run_training
from repro.fabric.control import PolicyProgram


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    steps_e, steps_h = (150, 300) if args.fast else (300, 700)

    et, ht = easy_task(), hard_task()
    print("== 1. easy workload (validated regime) ==")
    for pol, lr in (("fp32", None), ("gbinary", 5e-4)):
        r = run_training(et, policy=pol, steps=steps_e, batch=256, lr=lr)
        print(f"  {pol:8s} acc={r.final_acc:.3f} traffic={r.traffic_ratio:.3f}")

    print("== 2. hard workload: the boundary ==")
    r_fp = run_training(ht, policy="fp32", steps=steps_h, batch=64)
    r_lb = run_training(ht, policy="gbinary", steps=steps_h, batch=64,
                        lr=2e-4, diagnose_at=49)
    print(f"  fp32     acc={r_fp.final_acc:.3f}")
    print(f"  gbinary  acc={r_lb.final_acc:.3f}  "
          f"(gap: {100*(r_fp.final_acc - r_lb.final_acc):.1f} pts)")

    print("== 3. diagnostics (end of FP32 warm-up) ==")
    c = r_lb.cosines
    print(f"  backbone cos(gbinary, fp32) = {c['backbone']['gbinary']:.3f}")
    print(f"  head     cos(gbinary, fp32) = {c['head']['gbinary']:.3f}")

    print("== 4. layer-aware admission: low-bit backbone + FP32 head ==")
    r_mix = run_training(ht, policy="gbinary", head_policy="fp32",
                         steps=steps_h, batch=64, lr=2e-4)
    print(f"  mixed    acc={r_mix.final_acc:.3f} "
          f"traffic={r_mix.traffic_ratio:.3f} "
          f"(recovers {100*(r_mix.final_acc - r_lb.final_acc):.1f} pts)")

    print("== 5. the same policy as a declarative phase program ==")
    # warm-up on FP32, admit everything to G-Binary, then pull the head
    # back to FP32 mid-run — a user-defined phase schedule, no custom
    # control-loop code
    program = PolicyProgram.staged([
        ("warmup", ("fp32", "fp32"), 50),
        ("all_lowbit", ("gbinary", "gbinary"), steps_h // 2),
        ("head_fp32", ("gbinary", "fp32"), None)])
    r_prog = run_training(ht, policy="gbinary", head_policy="fp32",
                          steps=steps_h, batch=64, lr=2e-4, program=program)
    print(f"  staged   acc={r_prog.final_acc:.3f} "
          f"phases={[e.kind for e in program.events]}")


if __name__ == "__main__":
    main()
