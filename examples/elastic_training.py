"""Elastic training end-to-end: crash, rejoin, stragglers, replay.

Runs a tiny LM over four virtual workers (``jax.vmap`` lanes on one
host — no cluster needed) through a scripted fault scenario:

  * worker 3 **crashes** at step 9 and rejoins at step 14 — the
    trainer rolls back to the last durable checkpoint, replays the lost
    step under the 3-worker fleet, and re-plans again when the worker
    returns;
  * worker 1 **straggles** 6x from step 3 to 12 — the detector flags
    it from per-worker step times, and the ``straggler_aware``
    controller demotes the backbone to G-Binary (shrinking the exposed
    communication the slow worker serializes behind), recovering to
    FP32 once the fleet is stable again.

The same scenario description then replays offline through the
``repro.sim`` DES (:func:`repro.elastic.replay_schedule`), printing the
per-phase exposed-time decomposition — how a schedule is priced before
running it.

Run:  PYTHONPATH=src python examples/elastic_training.py
"""
import tempfile

import jax

from repro.data import SyntheticLMStream
from repro.elastic import (ElasticConfig, ElasticTrainer,
                           StragglerAwareController, replay_schedule)
from repro.models import ModelConfig, init_params
from repro.optim import SgdMomentum

WORKERS = 4
STEPS = 24
FAULTS = [("crash", {"worker": 3, "step": 9, "rejoin_step": 14}),
          ("straggler", {"worker": 1, "start": 3, "stop": 12,
                         "factor": 6.0})]


def main():
    cfg = ModelConfig(name="elastic-demo", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=128, dtype="float32", remat=False)
    data = SyntheticLMStream(vocab=128, seq_len=16, batch=4, seed=0)
    controller = StragglerAwareController(demote_after=2, recover_after=6)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = ElasticTrainer(
            cfg, SgdMomentum(peak_lr=0.1, total_steps=2 * STEPS), data,
            WORKERS, controller=controller, faults=FAULTS,
            ckpt_dir=ckpt_dir,
            ecfg=ElasticConfig(checkpoint_interval=4,
                               synthetic_step_time_s=1e-3,
                               log_interval=10_000))
        history = trainer.run(STEPS)

    print(f"{'step':>4} {'W':>2} {'epoch':>5} {'loss':>7} "
          f"{'stragglers':>10}  plan")
    for h in history:
        print(f"{h['step']:>4} {h['num_workers']:>2} "
              f"{h['membership_epoch']:>5} {h['loss']:>7.4f} "
              f"{str(h['stragglers']):>10}  {h['plan'][:40]}")

    report = trainer.report()
    print(f"\nrestarts={report['restarts']} "
          f"replayed_steps={report['replayed_steps']} "
          f"traffic_overhead={report['traffic_overhead']:.4f}x "
          f"compiled_steps={report['compiled_steps']}")
    for rec in report["recoveries"]:
        print(f"crash at step {rec['crash_step']}: restored step "
              f"{rec['restored_step']} ({rec['steps_to_recover']} lost)")
    for ev in controller.events:
        print(f"controller {ev.kind} at step {ev.step}")

    # price the same schedule offline through the DES
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    replay = replay_schedule(params, controller.lowbit_plan, WORKERS,
                             STEPS, faults=FAULTS, topology="cxl_direct",
                             compute_time_s=1e-4)
    print(f"\nreplay: {len(replay.phases)} phases, "
          f"total={replay.total_time_s * 1e3:.3f} ms, "
          f"exposed={replay.exposed_pct:.2f}%")
    for p in replay.phases:
        print(f"  steps [{p.start},{p.stop}) W={p.num_workers} "
              f"epoch={p.epoch} straggler={p.straggler_scale:.1f}x "
              f"step={p.step_time_s * 1e3:.4f} ms "
              f"exposed={p.exposed_pct:.2f}%")


if __name__ == "__main__":
    main()
