"""Autotune: compile the best codec/schedule/bucket plan for a model
and topology offline, inspect the decision record, and install the
winner as a named preset — no devices needed (abstract shapes + the
discrete-event simulator).

Run:  PYTHONPATH=src python examples/autotune_plan.py
"""
import jax

from repro.configs import get_config
from repro.fabric import Fabric
from repro.fabric.control import plan_presets, unregister_plan_preset
from repro.models import init_params
from repro.tune import MaxLowbitFraction, PinGroup, default_space


def main():
    # A mesh-free session prices plans for any fleet size: the tuner
    # only reads shapes/dtypes and the analytic + DES models.
    fabric = Fabric(num_workers=32)

    cfg = get_config("qwen3_0p6b", smoke=True)
    params = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))

    # The default space: every plan_presets() entry as an always-
    # sim-scored seed, plus generated low-bit backbone/embed axes over
    # two bucket budgets — with the paper's guardrail (classifier head
    # pinned to FP32) as an admission constraint.  Tighten it further:
    # cap the low-bit fraction so norms/head/embeddings stay FP32-heavy.
    space = default_space(
        constraints=(PinGroup("head"), MaxLowbitFraction(0.95)))

    for topology in ("ici_ring", "multihop"):
        tuned = fabric.autotune(params, space, topology=topology,
                                strategy="successive_halving")
        s = tuned.summary()
        print(f"[{topology}] winner: {s['plan_signature']}")
        print(f"  step={s['step_time_s'] * 1e6:.1f}us "
              f"wire={s['wire_bytes'] / 1e6:.2f}MB/device "
              f"exposed={s['exposed_pct']:.2f}% "
              f"bucket={s['bucket_bytes'] // 2**20}MiB")
        print(f"  searched {tuned.provenance['candidates']['enumerated']} "
              f"candidates, sim-certified "
              f"{tuned.provenance['candidates']['sim_scored']}")
        for r in tuned.runners_up[:3]:
            if r.score is not None:
                print(f"  runner-up {r.name}: "
                      f"{r.score.step_time_s * 1e6:.1f}us")

    # The artifact is a reproducible JSON record ...
    path = tuned.save("/tmp/tuned_plan.json")
    print(f"artifact: {path}")

    # ... that installs back into the preset table by name, where the
    # launcher (--plan tuned_demo), StaticController, and dry-run
    # tooling resolve it like any built-in.
    name = tuned.install("tuned_demo")
    assert plan_presets()[name].signature() == tuned.plan.signature()
    print(f"installed as plan preset {name!r}")
    unregister_plan_preset(name)

    # At train time, close the sim-to-reality loop through the standard
    # controller seam: fabric.attach_controller("tuned", tuned=tuned)
    # latches the winner and re-ranks the sim-certified shortlist if
    # live step times drift off the prediction.


if __name__ == "__main__":
    main()
