"""End-to-end LM training driver (deliverable b): train a configurable LM
for a few hundred steps on a learnable synthetic Markov stream with the
full production stack — partial-manual shard_map, low-bit aggregation,
ZeRO-1, checkpointing, straggler watchdog.

Default is a CPU-sized model; ``--preset 100m`` selects a ~100M-parameter
configuration (the assignment's reference size — expect long CPU runtimes;
on TPU this is the real driver).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
from jax.sharding import AxisType

from repro.core import AdmissionPlan, AggregationMode, Schedule
from repro.data import SyntheticLMStream
from repro.fabric import Fabric
from repro.models import ModelConfig
from repro.optim import AdamW
from repro.runtime import Trainer, TrainerConfig

PRESETS = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", num_layers=4,
                        d_model=128, num_heads=8, num_kv_heads=4, d_ff=512,
                        vocab_size=2048, dtype="float32", remat=False),
    "20m": ModelConfig(name="lm-20m", family="dense", num_layers=8,
                       d_model=384, num_heads=8, num_kv_heads=4, d_ff=1536,
                       vocab_size=8192, qk_norm=True, dtype="float32",
                       remat=True),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=3072, vocab_size=32768, qk_norm=True,
                        dtype="bfloat16", remat=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--plan", default="gbin_packed",
                    choices=["fp32", "gbin", "gbin_packed"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    ndev = jax.device_count()
    model_par = 2 if ndev % 2 == 0 else 1
    mesh = jax.make_mesh((ndev // model_par, model_par), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

    data = SyntheticLMStream(vocab=cfg.vocab_size, seq_len=args.seq_len,
                             batch=args.batch, seed=0)
    plan = {
        "fp32": AdmissionPlan.fp32_all(),
        "gbin": AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY),
        "gbin_packed": AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY, schedule=Schedule.PACKED_A2A),
    }[args.plan]

    trainer = Trainer(
        cfg, mesh, AdamW(peak_lr=args.lr, total_steps=args.steps),
        data, plan=plan, fabric=Fabric(mesh, dp_axes=("data",)),
        tcfg=TrainerConfig(dp_axes=("data",), log_interval=20,
                           checkpoint_interval=100),
        ckpt_dir=args.ckpt_dir)
    hist = trainer.run(args.steps)
    import numpy as np
    first10 = float(np.mean([h["loss"] for h in hist[:10]]))
    last10 = float(np.mean([h["loss"] for h in hist[-10:]]))
    print(f"\n{cfg.name}: loss {first10:.3f} -> {last10:.3f} "
          f"({args.steps} steps, traffic {hist[-1]['traffic_ratio']:.4f}x)")


if __name__ == "__main__":
    main()
