"""Paper Section 8 (Fig 6): guarded-recovery pilot with live mode selection.

Training starts on FP32, the Commander admits G-Binary after warm-up, a
degradation window is injected mid-run, the Supervisor's CUSUM guard
recovers to FP32, and after cooldown the mode is re-admitted.  The trace
prints every mode transition.

Run:  PYTHONPATH=src python examples/guarded_recovery.py
"""
from repro.core.admission import (Commander, ControlPlane, CusumGuard,
                                  Supervisor)
from repro.core.experiments import hard_task, run_training


def main():
    cp = ControlPlane(
        commander=Commander(tau_binary=0.2),
        supervisor=Supervisor(guard=CusumGuard(kappa=0.02, h=0.6),
                              cooldown_steps=60),
        warmup_steps=50)
    state = {"mode": ("fp32", "fp32"), "lowbit": 0, "total": 0}

    def callback(step, loss):
        plan = cp.step(loss, cosines={
            "backbone": {"gbinary": 0.8, "gternary": 0.7},
            "head": {"gbinary": 0.8, "gternary": 0.7}})
        lowbit = "gbinary" in plan.signature()
        mode = ("gbinary", "gbinary") if lowbit else ("fp32", "fp32")
        state["total"] += 1
        state["lowbit"] += int(lowbit)
        if mode != state["mode"]:
            print(f"  step {step:4d}: mode -> {mode[0]}  (loss={loss:.3f})")
            state["mode"] = mode
        return mode

    print("guarded recovery pilot (degradation injected at steps 250-280):")
    r = run_training(hard_task(), policy="fp32", steps=600, batch=64,
                     lr=2e-4, warmup_fp32=0, degrade=(250, 280),
                     plan_callback=callback, seed=0)

    frac = state["lowbit"] / state["total"]
    print(f"\nfinal acc      : {r.final_acc:.3f}")
    print(f"low-bit steps  : {100*frac:.1f}%")
    print(f"control events : {[e.kind for e in cp.events]}")
    assert "recovery" in [e.kind for e in cp.events], "guard never fired"
    assert "readmitted" in [e.kind for e in cp.events], "never re-admitted"
    print("OK")


if __name__ == "__main__":
    main()
