"""Paper Section 8 (Fig 6): guarded-recovery pilot with live mode selection.

Drives the registered ``"paper"`` admission controller
(:mod:`repro.fabric.control`) through its phase program:

    warmup --(warmup_steps)--> calibrate --(cosines)--> admitted
       admitted/readmitted --(CUSUM trigger)--> recovery
       recovery --(cooldown over)--> readmitted

Training starts on FP32; once warm-up ends and calibration cosines are
available the Commander admits G-Binary; a degradation window is
injected mid-run; the Supervisor's CUSUM guard recovers to FP32; and
after cooldown the mode is re-admitted.  Each step the pilot feeds the
controller one typed :class:`~repro.fabric.control.Telemetry` record and
reads back the latched plan — the same ``observe`` path the production
Trainer drives.  The trace prints every mode transition.

Run:  PYTHONPATH=src python examples/guarded_recovery.py
"""
from repro.core.admission import Commander, CusumGuard, Supervisor
from repro.core.experiments import hard_task, run_training
from repro.fabric.control import Telemetry, make_controller


def main():
    controller = make_controller(
        "paper",
        commander=Commander(tau_binary=0.2),
        supervisor=Supervisor(guard=CusumGuard(kappa=0.02, h=0.6),
                              cooldown_steps=60),
        warmup_steps=50)
    state = {"mode": ("fp32", "fp32"), "lowbit": 0, "total": 0}

    def callback(step, loss):
        plan = controller.observe(Telemetry(step=step, loss=loss, cosines={
            "backbone": {"gbinary": 0.8, "gternary": 0.7},
            "head": {"gbinary": 0.8, "gternary": 0.7}}))
        lowbit = "gbinary" in plan.signature()
        mode = ("gbinary", "gbinary") if lowbit else ("fp32", "fp32")
        state["total"] += 1
        state["lowbit"] += int(lowbit)
        if mode != state["mode"]:
            print(f"  step {step:4d}: mode -> {mode[0]}  (loss={loss:.3f})")
            state["mode"] = mode
        return mode

    print("guarded recovery pilot (degradation injected at steps 250-280):")
    r = run_training(hard_task(), policy="fp32", steps=600, batch=64,
                     lr=2e-4, warmup_fp32=0, degrade=(250, 280),
                     plan_callback=callback, seed=0)

    frac = state["lowbit"] / state["total"]
    kinds = [e.kind for e in controller.events]
    print(f"\nfinal acc      : {r.final_acc:.3f}")
    print(f"low-bit steps  : {100*frac:.1f}%")
    print(f"control events : {kinds}")
    assert "recovery" in kinds, "guard never fired"
    assert "readmitted" in kinds, "never re-admitted"
    print("OK")


if __name__ == "__main__":
    main()
