"""Batched serving example: prefill + decode with the production sharding.

Decodes a batch of sequences with the KV cache sharded (batch over DP,
cache sequence over the model axis) — the same code path the decode_32k /
long_500k dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.configs import get_config
from repro.models import init_cache, init_params
from repro.runtime import build_serve_step

BATCH, MAX_SEQ, DECODE_TOKENS = 8, 64, 24


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = get_config("qwen3_0p6b", smoke=True)
    with jax.set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        step, sh = build_serve_step(cfg, mesh, batch=BATCH, max_seq=MAX_SEQ,
                                    dp_axes=("data",))
        params = jax.device_put(params, sh["params"])
        cache = jax.device_put(init_cache(cfg, BATCH, MAX_SEQ), sh["cache"])
        tok = jax.device_put(
            jnp.asarray(np.random.randint(0, cfg.vocab_size, (BATCH, 1)),
                        jnp.int32), sh["token"])

        outs = []
        t0 = time.perf_counter()
        for t in range(DECODE_TOKENS):
            logits, cache = step(params, tok, cache, jnp.int32(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            tok = jax.device_put(tok, sh["token"])
            outs.append(np.asarray(tok)[:, 0])
        dt = time.perf_counter() - t0

    gen = np.stack(outs, 1)
    print(f"decoded {DECODE_TOKENS} tokens x {BATCH} seqs in {dt:.2f}s "
          f"({BATCH*DECODE_TOKENS/dt:.1f} tok/s on CPU-sim)")
    print("first sequence:", gen[0][:16], "...")
    assert gen.shape == (BATCH, DECODE_TOKENS)
    print("OK")


if __name__ == "__main__":
    main()
