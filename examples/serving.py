"""Continuous-batching serving example: paged KV cache + sim replay.

Serves a staggered multi-request trace through :class:`repro.serve.ServeEngine`
on a block pool small enough to force preemption and CXL spill, with the
KV cache quantized through the ``int4`` codec, then replays the decode
timeline's fabric traffic on both CXL topologies.

Run:  PYTHONPATH=src python examples/serving.py
"""
import time

from repro.models import ModelConfig
from repro.serve import ServeEngine

TRACE = (
    {"prompt": [11, 7, 5, 3, 2, 13, 17, 19], "max_new_tokens": 10,
     "arrival_step": 0},
    {"prompt": [4, 8, 15, 16, 23, 42], "max_new_tokens": 12,
     "arrival_step": 0},
    {"prompt": [1, 2, 3, 5, 8, 13, 21, 34, 55], "max_new_tokens": 8,
     "arrival_step": 1},
    {"prompt": [9, 9, 9, 9, 9], "max_new_tokens": 11, "arrival_step": 3},
)


def main():
    cfg = ModelConfig(name="serving_toy", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=97, dtype="float32", remat=False)
    eng = ServeEngine(cfg, max_batch=3, max_seq=32, num_blocks=10,
                      block_size=4, kv_codec="int4", policy="fcfs")

    t0 = time.perf_counter()
    outputs = eng.serve(TRACE)
    dt = time.perf_counter() - t0

    tl = eng.timeline()
    print(f"served {len(outputs)} requests, {tl.total_new_tokens} tokens "
          f"in {tl.num_steps} steps / {dt:.2f}s "
          f"({tl.total_new_tokens / dt:.1f} tok/s on CPU-sim)")
    print(f"preemptions={tl.total_preemptions} "
          f"spills={eng.cache.tier.spills} fetches={eng.cache.tier.fetches} "
          f"kv_wire_bytes={tl.total_wire_bytes:.0f} (int4-priced)")
    for rid, toks in sorted(outputs.items()):
        print(f"  request {rid}: {toks}")

    for topo in ("cxl_direct", "cxl_switched"):
        rep = eng.simulate(tl, topology=topo, step_compute_s=1e-3)
        print(f"sim/{topo}: step_time={rep.step_time_s * 1e3:.2f}ms "
              f"launches={rep.num_launches} "
              f"exposed={rep.exposed_pct:.1f}%")

    assert all(len(t) == e["max_new_tokens"]
               for t, e in zip((outputs[r] for r in sorted(outputs)), TRACE))
    assert tl.total_preemptions > 0
    print("OK")


if __name__ == "__main__":
    main()
