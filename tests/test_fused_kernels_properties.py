"""Hypothesis round trips for the fused Pallas kernels.

The property under test: for every codec that brings a fused kernel
set, the kernel path reproduces the staged reference path **bit for
bit** on ragged (non-tile-multiple) sizes and across the worker-count
sweep W in {3, 31, 128, 256}, error feedback on and off.  The reference
side is always jitted — bit-identity is a claim about compiled
programs; XLA CPU rounds one eager scalar division differently from
the jitted equivalent (DESIGN.md section 12).

Separate module from tests/test_fused_kernels.py so environments
without the optional hypothesis dependency still run the deterministic
fused-kernel suite (module-level importorskip skips whole files).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fabric import get_codec
from repro.kernels import Int4KernelSet, TopKKernelSet, fused, ref

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dependency (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

#: the satellite's worker-count sweep (odd, large, power-of-two, > ports)
W_SWEEP = [3, 31, 128, 256]

#: ragged element counts — never a tile multiple unless by accident
_sizes = st.integers(min_value=1, max_value=3 * 4096 + 17)


@st.composite
def _flat_values(draw, sizes=_sizes):
    n = draw(sizes)
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return np.random.RandomState(seed).randn(n).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(vals=_flat_values())
def test_hyp_int4_kernel_roundtrip(vals):
    plane = ref.to_plane(jnp.asarray(vals))
    want = jax.jit(ref.int4_quant_plane)(plane)
    got = fused.int4_quant_plane(plane, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # codec-level: Int4KernelSet.encode_flat == Int4Codec.encode in one
    # jit program (the production configuration)
    ks = Int4KernelSet()
    codec = get_codec("int4")
    flat = jnp.asarray(vals)
    enc_k = jax.jit(lambda x: ks.encode_flat(x, interpret=True))(flat)
    enc_c = jax.jit(lambda x: codec.encode(None, x))(flat)
    np.testing.assert_array_equal(np.asarray(enc_k), np.asarray(enc_c))


@settings(max_examples=20, deadline=None)
@given(vals=_flat_values())
def test_hyp_topk_kernel_roundtrip(vals):
    ks = TopKKernelSet(1 / 16)
    codec = get_codec("topk")
    flat = jnp.asarray(vals)
    enc_k = jax.jit(lambda x: ks.encode_flat(x, interpret=True))(flat)
    enc_c = jax.jit(lambda x: codec.encode(None, x))(flat)
    np.testing.assert_array_equal(np.asarray(enc_k), np.asarray(enc_c))


@settings(max_examples=15, deadline=None)
@given(vals=_flat_values(sizes=st.integers(min_value=1, max_value=2000)),
       w=st.sampled_from(W_SWEEP),
       ternary=st.booleans(), phase=st.integers(min_value=0, max_value=2),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hyp_vote_pipeline_roundtrip(vals, w, ternary, phase, seed):
    n = vals.shape[0]
    stack_vals = np.random.RandomState(seed).randn(w, n).astype(np.float32)
    stack_vals[0] = vals                        # ragged hypothesis payload
    stack = jnp.stack([ref.to_plane(jnp.asarray(stack_vals[i]))
                       for i in range(w)])
    gate = fused.local_gate_words(stack.shape[1] // ref.PACK,
                                  ternary=ternary, gate_phase=phase)
    want = jax.jit(ref.vote_pipeline_dense, static_argnums=1)(
        stack, w, gate).astype(jnp.float32)
    got = fused.vote_pipeline(stack, gate, num_workers=w, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@settings(max_examples=15, deadline=None)
@given(vals=_flat_values(),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hyp_encode_pack_ef_roundtrip(vals, seed):
    e_vals = np.random.RandomState(seed).randn(vals.shape[0])
    g = ref.to_plane(jnp.asarray(vals))
    e = ref.to_plane(jnp.asarray(e_vals, jnp.float32))
    want_w, want_g = jax.jit(ref.encode_pack_ef)(g, e)
    got_w, got_g = fused.encode_pack_ef(g, e, interpret=True)
    np.testing.assert_array_equal(np.asarray(want_w), np.asarray(got_w))
    np.testing.assert_array_equal(np.asarray(want_g), np.asarray(got_g))
