"""Per-architecture smoke tests: reduced same-family configs on CPU.

Each assigned architecture instantiates its SMOKE config, runs one forward
and one gradient step, asserts output shapes and finite values, then runs
one decode step against a fresh cache (all ten archs have decoders).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (count_params, decode_step, forward, init_cache,
                          init_params, loss_fn, param_pspecs)


def _batch_for(cfg, rng, b=2, s=24):
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patch_feats"] = jnp.asarray(
            rng.randn(b, cfg.vision_patches, cfg.vision_feat_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # pspec tree must be structurally congruent with params
    specs = param_pspecs(cfg)
    jax.tree.map(lambda p, s: None, params, specs,
                 is_leaf=lambda x: hasattr(x, "dtype")
                 or type(x).__name__ == "PartitionSpec")
    batch = _batch_for(cfg, rng)
    b, s = batch["tokens"].shape
    logits = forward(params, cfg, batch)
    total = s + (cfg.vision_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, max_seq = 2, 16
    cache = init_cache(cfg, b, max_seq)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # a second step with the updated cache must also be finite
    logits2, _ = decode_step(params, cfg, tok, cache2, jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode reproduces the parallel forward logits."""
    cfg = get_config(arch, smoke=True)
    if cfg.family in ("vlm", "encdec"):
        pytest.skip("prefix modalities make positions differ; covered above")
    if cfg.moe is not None:
        pytest.skip("capacity-based token dropping differs between batched "
                    "prefill and single-token decode by design")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 8
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    ref_logits = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache,
                                jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(ref_logits), rtol=2e-2, atol=2e-2)


def test_full_configs_param_counts():
    """FULL configs match their published sizes (sanity on the table)."""
    expect = {
        "phi3_vision_4p2b": (3.5e9, 4.5e9),
        "llama4_scout_17b_a16e": (95e9, 115e9),
        "deepseek_moe_16b": (15e9, 18e9),
        "whisper_tiny": (2.5e7, 4.5e7),
        "hymba_1p5b": (1.2e9, 1.8e9),
        "qwen3_0p6b": (5.0e8, 7.5e8),
        "gemma3_27b": (25e9, 29e9),
        "qwen2p5_14b": (13e9, 16e9),
        "starcoder2_15b": (14e9, 17e9),
        "xlstm_125m": (1.0e8, 1.6e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:,}, {hi:,}]"
