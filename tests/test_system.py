"""End-to-end system behaviour on the default (single-device) backend.

The full stack — config → model → admission plan → partial-manual
shard_map train step → optimizer → control plane — on a 1x1 mesh, where
W=1 majority voting degenerates to sign(g) (checked), plus the adaptive
control plane driving a live Trainer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    pytest.skip("installed jax lacks jax.sharding.AxisType (needs >= 0.7)",
                allow_module_level=True)

from repro.core import (AdmissionPlan, AggregationMode, Commander,
                        CusumGuard, Schedule, Supervisor)
from repro.data import SyntheticLMStream
from repro.fabric import make_controller
from repro.models import ModelConfig
from repro.optim import SgdMomentum
from repro.runtime import Trainer, TrainerConfig


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def _cfg():
    return ModelConfig(name="sys", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                       dtype="float32", remat=False)


def test_full_stack_trains_and_tracks_traffic():
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=8, seed=0)
    tr = Trainer(_cfg(), _mesh(), SgdMomentum(peak_lr=0.2, total_steps=60),
                 data,
                 plan=AdmissionPlan.lowbit_backbone(
                     AggregationMode.G_BINARY, schedule=Schedule.PACKED_A2A),
                 tcfg=TrainerConfig(dp_axes=("data",), log_interval=1000))
    hist = tr.run(40)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # mixed-plan traffic: low-bit backbone + FP32 everything else
    assert 0.0 < hist[-1]["traffic_ratio"] < 1.0
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_w1_majority_equals_sign():
    """With a single worker the Section-2 vote degenerates to sign(g)."""
    from repro.kernels import ref
    g = jnp.asarray(np.random.RandomState(0).randn(1, 4096), jnp.float32)
    u = ref.gbinary_aggregate_dense(g)
    np.testing.assert_array_equal(np.asarray(u), np.sign(np.asarray(g[0])))


def test_adaptive_control_plane_drives_trainer():
    """Warm-up on FP32, then the Commander admits from live diagnostics."""
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=8, seed=1)
    control = make_controller(
        "paper",
        commander=Commander(tau_binary=-1.0),   # always-admitting ladder
        supervisor=Supervisor(guard=CusumGuard(h=1e9)),
        warmup_steps=5)
    tr = Trainer(_cfg(), _mesh(), SgdMomentum(peak_lr=0.1, total_steps=40),
                 data, controller=control,
                 tcfg=TrainerConfig(dp_axes=("data",), log_interval=1000))
    hist = tr.run(12)
    plans = [h["plan"] for h in hist]
    assert "gbinary" not in plans[0], "must warm up on FP32"
    assert any("gbinary" in p for p in plans[6:]), "never admitted"
    assert "admitted" in [e.kind for e in control.events]
    # diagnostics were recorded during calibration steps
    assert any(k.startswith("cos/") for k in hist[0])


def test_plan_change_uses_compile_cache():
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=8, seed=2)
    tr = Trainer(_cfg(), _mesh(), SgdMomentum(peak_lr=0.1, total_steps=40),
                 data, plan=AdmissionPlan.fp32_all(),
                 tcfg=TrainerConfig(dp_axes=("data",), log_interval=1000))
    tr.run(3)
    tr.static_plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    tr.run(6)
    tr.static_plan = AdmissionPlan.fp32_all()
    tr.run(9)
    # two distinct plan signatures -> exactly two cached compilations
    # (the per-plan jit cache lives in the Fabric session)
    assert len(tr.fabric._compiled) == 2
