"""repro.elastic: membership, faults, detector, ElasticTrainer, replay.

Acceptance criteria exercised here (ISSUE 8):

  * a scripted crash→rejoin schedule runs end-to-end through
    ``ElasticTrainer``, bit-identical to a fixed-membership run on the
    same effective batch when no faults fire;
  * under a ``straggler`` fault the detector emits Telemetry that flips
    the admission ladder;
  * the same schedule replays through ``repro.sim`` with per-phase
    exposed-time reporting;
  * checkpoint/restore across a membership change re-plans buckets for
    the new worker count and does not reset the controller to warm-up;
  * step-cache keys include the membership epoch (Fabric + elastic).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, AggregationMode, Commander,
                        CusumGuard, Schedule, Supervisor)
from repro.data import SyntheticLMStream
from repro.elastic import (Crash, ElasticConfig, ElasticTrainer,
                           FaultModel, LocalSgdController, Membership,
                           MembershipEvent, StragglerAwareController,
                           StragglerDetector, WorkerView, available_faults,
                           make_fault, register_fault, replay_schedule,
                           resolve_faults, unregister_fault)
from repro.models import ModelConfig, init_params
from repro.optim import SgdMomentum


def _cfg():
    return ModelConfig(name="el", family="dense", num_layers=2, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                       dtype="float32", remat=False)


def _data(seed=0):
    return SyntheticLMStream(vocab=128, seq_len=16, batch=4, seed=seed)


def _ecfg(**kw):
    kw.setdefault("synthetic_step_time_s", 1e-3)
    kw.setdefault("log_interval", 10_000)
    return ElasticConfig(**kw)


_PLAN = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                      schedule=Schedule.VOTE_PSUM,
                                      error_feedback=True)


# ---------------------------------------------------------------------------
# membership ledger
# ---------------------------------------------------------------------------

def test_membership_ledger_epochs_and_validation():
    m = Membership(4, schedule=[MembershipEvent(3, "leave", 2),
                                MembershipEvent(7, "join", 2)])
    assert m.view == WorkerView(0, (0, 1, 2, 3))
    assert m.step_events(2) == ()
    (ev,) = m.step_events(3)
    assert m.apply(ev) == WorkerView(1, (0, 1, 3))
    # re-removing an absent worker / re-joining a live one are bugs
    with pytest.raises(ValueError):
        m.apply(MembershipEvent(4, "leave", 2))
    with pytest.raises(ValueError):
        m.apply(MembershipEvent(4, "join", 0))
    (ev,) = m.step_events(7)
    assert m.apply(ev) == WorkerView(2, (0, 1, 2, 3))
    assert [e.kind for e, _ in m.log] == ["leave", "join"]
    # events scheduled in a rolled-past window still fire exactly once
    m2 = Membership(2, schedule=[MembershipEvent(1, "join", 5)])
    assert [e.worker for e in m2.step_events(4)] == [5]
    assert m2.step_events(4) == ()


def test_membership_never_empties():
    m = Membership([7])
    with pytest.raises(ValueError):
        m.apply(MembershipEvent(0, "crash", 7))


# ---------------------------------------------------------------------------
# fault-model registry
# ---------------------------------------------------------------------------

def test_fault_registry_builtins_and_custom():
    assert {"crash", "straggler", "link_degrade"} <= set(available_faults())
    crash = make_fault("crash", worker=3, step=8, rejoin_step=14)
    kinds = [e.kind for e in crash.scheduled_events()]
    assert kinds == ["crash", "join"]
    # live path fires each event exactly once, even when steps replay
    assert [e.kind for e in crash.membership_events(8)] == ["crash"]
    assert crash.membership_events(8) == ()

    @register_fault("toy_blip")
    class Blip(FaultModel):
        name = "toy_blip"

        def __init__(self, step=0):
            super().__init__()
            self.step = step

        def bandwidth_scale(self, step):
            return 0.5 if step == self.step else 1.0

    try:
        specs = resolve_faults([("toy_blip", {"step": 2}),
                                {"name": "straggler", "worker": 0,
                                 "start": 0, "stop": 4},
                                Crash(worker=1, step=9)])
        assert [type(f).__name__ for f in specs] == ["Blip", "Straggler",
                                                     "Crash"]
        assert specs[0].bandwidth_scale(2) == 0.5
    finally:
        unregister_fault("toy_blip")
    with pytest.raises(KeyError):
        make_fault("toy_blip")


def test_fault_parameter_validation():
    with pytest.raises(ValueError):
        make_fault("crash", worker=0, step=5, rejoin_step=5)
    with pytest.raises(ValueError):
        make_fault("straggler", worker=0, start=0, stop=4, factor=0.5)
    with pytest.raises(ValueError):
        make_fault("link_degrade", start=0, stop=4, factor=0.0)


# ---------------------------------------------------------------------------
# straggler detector
# ---------------------------------------------------------------------------

def test_detector_flags_sustained_straggler_only():
    det = StragglerDetector(threshold=2.0, alpha=0.3, warmup=1)
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert det.observe(0, base).stragglers == ()      # warmup
    assert det.observe(1, base).stragglers == ()
    # one-off spike is absorbed by the EWMA
    spike = {**base, 2: 3.5}
    assert det.observe(2, spike).stragglers == ()
    # sustained slowdown is flagged, with the right slowdown ratio
    stats = det.observe(3, spike)
    assert stats.stragglers == (2,)
    assert stats.slowdown > 2.0
    # departed workers drop out of the fleet statistics
    stats = det.observe(4, {0: 1.0, 1: 1.0, 3: 1.0})
    assert stats.stragglers == ()
    assert set(stats.times) == {0, 1, 3}


# ---------------------------------------------------------------------------
# ElasticTrainer: bit-identity, crash→rejoin, epoch-keyed jit cache
# ---------------------------------------------------------------------------

def test_no_fault_run_bit_identical_to_fixed_membership():
    """Armed-but-never-firing faults must not perturb a single bit."""
    def run(faults):
        tr = ElasticTrainer(_cfg(), SgdMomentum(peak_lr=0.2, total_steps=40),
                            _data(), 4, plan=_PLAN, faults=faults,
                            ecfg=_ecfg())
        return [h["loss"] for h in tr.run(8)]

    fixed = run(())
    armed = run([("crash", dict(worker=3, step=100)),
                 ("straggler", dict(worker=1, start=50, stop=60))])
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(armed))
    assert fixed[-1] < fixed[0]


def test_crash_rejoin_end_to_end(tmp_path):
    tr = ElasticTrainer(
        _cfg(), SgdMomentum(peak_lr=0.2, total_steps=60), _data(), 4,
        plan=_PLAN, ckpt_dir=str(tmp_path),
        faults=[("crash", dict(worker=3, step=9, rejoin_step=14))],
        ecfg=_ecfg(checkpoint_interval=4))
    hist = tr.run(20)
    rep = tr.report()
    # crash at 9, last durable checkpoint at 8 -> one replayed step
    assert rep["restarts"] == 1
    assert rep["recoveries"][0]["steps_to_recover"] == 1
    assert rep["replayed_steps"] == 1
    assert rep["traffic_overhead"] > 1.0
    # fleet trajectory: 4 -> 3 (crash) -> 4 (rejoin), epochs 0/1/2;
    # step 8 executes twice (original at W=4, replayed at W=3)
    eights = [h for h in hist if h["step"] == 8]
    assert [h["num_workers"] for h in eights] == [4, 3]
    by_step = {h["step"]: h for h in hist}
    assert by_step[10]["num_workers"] == 3
    assert by_step[15]["num_workers"] == 4
    assert rep["final_view"] == {"epoch": 2, "workers": [0, 1, 2, 3]}
    # one compiled step per (plan, W, epoch) - the rejoined view has the
    # same W as epoch 0 but must not be served the stale step
    assert rep["compiled_steps"] == 3
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_restore_across_membership_change_replans_and_keeps_phase(tmp_path):
    """Satellite 3: restore into a different worker count re-plans
    buckets for the live view, and the controller resumes in its
    checkpointed phase instead of warm-up."""
    from repro.fabric.control import PaperController

    def controller():
        return PaperController(commander=Commander(tau_binary=-1.0),
                               supervisor=Supervisor(guard=CusumGuard(h=1e9)),
                               warmup_steps=3)

    ctrl_a = controller()
    tr_a = ElasticTrainer(_cfg(), SgdMomentum(peak_lr=0.1, total_steps=60),
                          _data(), 4, controller=ctrl_a,
                          ckpt_dir=str(tmp_path),
                          ecfg=_ecfg(checkpoint_interval=2))
    tr_a.run(10)
    assert ctrl_a.program.phase == "admitted"

    # new process, new fleet size: 3 workers instead of 4
    ctrl_b = controller()
    tr_b = ElasticTrainer(_cfg(), SgdMomentum(peak_lr=0.1, total_steps=60),
                          _data(), 3, controller=ctrl_b,
                          ckpt_dir=str(tmp_path),
                          ecfg=_ecfg(checkpoint_interval=2))
    hist = tr_b.run(12)
    # restored at the checkpointed step, not from scratch
    assert hist[0]["step"] == 10
    # controller phase survived the worker-count change
    assert ctrl_b.program.phase == "admitted"
    assert "gbinary" in hist[0]["plan"]
    # the step ran under the live 3-worker view (fresh plan/bucket
    # build), not a resurrected 4-worker artifact
    assert hist[0]["num_workers"] == 3
    assert tr_b.fabric.num_workers == 3
    assert all(w == 3 for (_, _, w, _) in tr_b._compiled)


def test_fabric_step_cache_keys_include_membership_epoch():
    """Satellite 6 at the session level: re-binding an epoch-bumped view
    must miss the jit cache even at the same worker count."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        pytest.skip("installed jax lacks jax.sharding.AxisType")
    from repro.fabric import Fabric
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    fabric = Fabric(mesh, ("data",))
    cfg = _cfg()
    opt = SgdMomentum(peak_lr=0.1, total_steps=10)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    plan = AdmissionPlan.fp32_all()
    with jax.set_mesh(mesh):
        fabric.step_for(cfg, opt, plan, params)
        fabric.step_for(cfg, opt, plan, params)
        assert len(fabric._compiled) == 1
        fabric.bind_membership(WorkerView(epoch=1, workers=(0,)))
        fabric.step_for(cfg, opt, plan, params)
    assert len(fabric._compiled) == 2
    # a mesh-bound session cannot change worker count
    with pytest.raises(ValueError):
        fabric.bind_membership(WorkerView(epoch=2, workers=(0, 1)))


# ---------------------------------------------------------------------------
# detector -> Telemetry -> admission ladder (acceptance criterion)
# ---------------------------------------------------------------------------

def test_straggler_fault_flips_admission_ladder():
    ctrl = StragglerAwareController(demote_after=2, recover_after=6)
    tr = ElasticTrainer(
        _cfg(), SgdMomentum(peak_lr=0.1, total_steps=60), _data(), 4,
        controller=ctrl,
        faults=[("straggler", dict(worker=1, start=3, stop=12, factor=6.0))],
        ecfg=_ecfg())
    hist = tr.run(24)
    # the detector surfaced the slow worker in telemetry
    assert any(h["stragglers"] == (1,) for h in hist)
    # ... which demoted the ladder to low-bit, then recovered to FP32
    kinds = [e.kind for e in ctrl.events]
    assert kinds == ["demoted", "recovered"]
    plans = [h["plan"] for h in hist]
    assert "gbinary" not in plans[0] and any("gbinary" in p for p in plans)
    assert "gbinary" not in plans[-1]
    # controller state round-trips
    blob = ctrl.state_dict()
    fresh = StragglerAwareController()
    fresh.load_state_dict(blob)
    assert fresh.phase == ctrl.phase
    assert fresh.plan.signature() == ctrl.plan.signature()


def test_graceful_leave_and_join_without_rollback():
    m = Membership(4, schedule=[MembershipEvent(3, "leave", 0),
                                MembershipEvent(6, "join", 0)])
    tr = ElasticTrainer(_cfg(), SgdMomentum(peak_lr=0.2, total_steps=40),
                        _data(), m, plan=_PLAN, ecfg=_ecfg())
    hist = tr.run(9)
    rep = tr.report()
    assert rep["restarts"] == 0 and rep["replayed_steps"] == 0
    assert [h["num_workers"] for h in hist] == [4, 4, 4, 3, 3, 3, 4, 4, 4]
    assert [h["membership_epoch"] for h in hist] == [0] * 3 + [1] * 3 + [2] * 3
    assert all(np.isfinite(h["loss"]) for h in hist)


# ---------------------------------------------------------------------------
# local-SGD strategy through the public seams
# ---------------------------------------------------------------------------

def test_local_sgd_strategy_traffic_and_training():
    tr = ElasticTrainer(_cfg(), SgdMomentum(peak_lr=0.3, total_steps=40),
                        _data(), 4,
                        controller=LocalSgdController(sync_every=4),
                        ecfg=_ecfg())
    hist = tr.run(16)
    traffic = [h["traffic_ratio"] for h in hist]
    # H-1 zero-wire local steps, then one low-bit sync step
    assert traffic[:4] == [0.0, 0.0, 0.0, traffic[3]]
    assert traffic[3] > 0.0
    for i, t in enumerate(traffic):
        assert (t > 0.0) == (i % 4 == 3), (i, t)
    # the banked gradients actually train the model at sync steps
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_local_accum_requires_error_feedback():
    from repro.elastic import LocalAccumBackend
    from repro.fabric.registry import AggregationContext
    backend = LocalAccumBackend()
    ctx = AggregationContext(dp_axes=(), num_workers=1)
    g = jnp.ones((4,))
    agg, ef = backend.aggregate(ctx, g, None, ef=jnp.zeros((4,)))
    np.testing.assert_array_equal(np.asarray(agg), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(ef), np.ones(4))
    with pytest.raises(ValueError):
        backend.aggregate(ctx, g, None, ef=None)


def test_local_codec_canonicalizes_onto_local_accum():
    """A 0-bit payload must never ride a real collective: any built-in
    schedule a policy nominally names travels on local_accum (same
    normalization precedent as hierarchical routes)."""
    from repro.core.modes import wire_schedule
    for nominal in ("psum", "vote_psum", "packed_a2a", "local_accum"):
        assert wire_schedule("local", nominal) == "local_accum"


# ---------------------------------------------------------------------------
# sim replay (acceptance criterion: per-phase exposed-time reporting)
# ---------------------------------------------------------------------------

def test_replay_schedule_reports_per_phase_exposure():
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), _cfg()))
    faults = [("crash", dict(worker=3, step=8, rejoin_step=14)),
              ("straggler", dict(worker=1, start=4, stop=8, factor=5.0)),
              ("link_degrade", dict(start=16, stop=20, factor=0.25))]
    rep = replay_schedule(params, _PLAN, 4, 24, faults=faults,
                          topology="cxl_direct", compute_time_s=1e-4)
    assert rep.num_steps == 24
    spans = [(p.start, p.stop, p.num_workers, p.straggler_scale,
              p.bandwidth_scale) for p in rep.phases]
    assert spans == [(0, 4, 4, 1.0, 1.0), (4, 8, 4, 5.0, 1.0),
                     (8, 14, 3, 1.0, 1.0), (14, 16, 4, 1.0, 1.0),
                     (16, 20, 4, 1.0, 0.25), (20, 24, 4, 1.0, 1.0)]
    # straggler phases are slower; the report prices the whole scenario
    slow = next(p for p in rep.phases if p.straggler_scale > 1)
    assert slow.step_time_s > rep.phases[0].step_time_s
    assert rep.total_time_s > 0
    assert rep.summary()["num_phases"] == 6
    # a degraded link exposes at least as much communication
    degraded = next(p for p in rep.phases if p.bandwidth_scale < 1)
    assert degraded.exposed_s >= rep.phases[0].exposed_s
    # fault-free replay of the same plan is strictly cheaper
    clean = replay_schedule(params, _PLAN, 4, 24, topology="cxl_direct",
                            compute_time_s=1e-4)
    assert len(clean.phases) == 1
    assert clean.total_time_s < rep.total_time_s
