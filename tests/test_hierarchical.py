"""Hierarchical hop-plan collectives: the hop-aware aggregation stack.

The hierarchy contract: a 1-hop :class:`HopPlan` is *bit-identical* to
the flat backend of its single codec (per-leaf and fused, EF on and
off); a multi-hop plan composes each hop's encode -> reduce -> decode
over its own worker group (validated against a nested-vmap oracle); the
per-hop wire legs from ``hop_wire_bytes_per_device`` sum to the route
total and each leg is priced by the hop backend's own ring model; and
the sim's ``multihop`` topology replays hierarchical launches leg by
leg, agreeing with the analytic :class:`MultiHopModel` within 1% on
degenerate single-launch cases.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, Commander, IciModel, MultiHopModel,
                        codec_name, hop_wire_bytes_per_device,
                        init_ef_states, modeled_layout_comm_time,
                        modeled_layout_multihop_time, plan_buckets,
                        resolve_policies, schedule_name,
                        wire_bytes_per_device, wire_schedule)
from repro.fabric import (Fabric, HopPlan, HopSpec, get_codec,
                          plan_presets, register_hop_plan,
                          unregister_hop_plan)
from repro.sim import LaunchSpec, layout_launch_specs, simulate_launches

#: sim-vs-analytic tolerance, same contract as tests/test_sim.py
REL_TOL = 0.01

#: the built-in flat codecs every 1-hop plan must be bit-identical to
FLAT_CODECS = ["fp32", "gbinary", "gternary", "int4"]


def _tree_equal(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b)
    return all(jax.tree.leaves(flags))


def _grads(rng, w=None):
    mk = (lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)) if w is None \
        else (lambda *s: jnp.asarray(rng.randn(w, *s), jnp.float32))
    return {"backbone": {"w1": mk(40, 33), "w2": mk(257), "w3": mk(64, 8)},
            "embed": {"table": mk(130, 7)},
            "head": {"w": mk(17)},
            "norms": {"scale": mk(33)}}


def _default_wire_schedule(mode):
    return wire_schedule(mode, get_codec(mode).default_schedule)


# ---------------------------------------------------------------------------
# plan construction + group sizing
# ---------------------------------------------------------------------------

def test_hop_plan_validation():
    with pytest.raises(ValueError):
        HopPlan("bad_empty", ())
    with pytest.raises(ValueError):            # two remainder hops
        HopPlan("bad_two_rem", (HopSpec("fp32"), HopSpec("gbinary")))
    with pytest.raises(ValueError):
        HopSpec("fp32", workers=0)
    with pytest.raises(ValueError):            # hop plans do not nest
        register_hop_plan(HopPlan("bad_nested",
                                  (HopSpec("hier_fp32_gbinary"),)))


def test_group_sizes_clamp_divide_and_remainder():
    builtin = get_codec("hier_fp32_gbinary").plan
    assert builtin.group_sizes(32) == (8, 4)
    assert builtin.group_sizes(4) == (4, 1)    # intra hop clamps to W
    assert builtin.group_sizes(1) == (1, 1)
    odd = HopPlan("odd", (HopSpec("fp32", workers=3), HopSpec("gbinary")))
    with pytest.raises(ValueError):            # 3 does not divide 8
        odd.group_sizes(8)
    short = HopPlan("short", (HopSpec("fp32", workers=2),))
    with pytest.raises(ValueError):            # no remainder hop for the rest
        short.group_sizes(8)


def test_signature_is_stable_route_identity():
    plan = HopPlan("x", (HopSpec("fp32", workers=8),
                         HopSpec("gbinary", schedule="vote_psum")))
    assert plan.signature() == "x[fp32:8>gbinary:*@vote_psum]"
    assert get_codec("hier_fp32_gbinary").hop_signature == \
        "hier_fp32_gbinary[fp32:8>gbinary:*]"


def test_hier_codec_contract_delegates_to_hops():
    c = get_codec("hier_fp32_gternary")
    assert c.reduction == "hierarchical"
    assert schedule_name(c.default_schedule) == "hierarchical"
    assert c.bits_per_element == get_codec("gternary").bits_per_element
    assert c.lane == get_codec("gternary").lane
    assert c.gated == get_codec("gternary").gated
    assert c.threads_ef
    # every flat schedule a policy could name routes to the hier backend
    for sched in ("psum", "vote_psum", "packed_a2a"):
        assert wire_schedule("hier_fp32_gternary", sched) == "hierarchical"


# ---------------------------------------------------------------------------
# 1-hop plan == flat backend (bit-identical, per-leaf and fused)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", FLAT_CODECS)
@pytest.mark.parametrize("fused", [False, True])
def test_one_hop_plan_matches_flat_backend(rng, mode, fused):
    w, name = 4, f"hier1_{mode}"
    gs = _grads(rng, w=w)
    register_hop_plan(HopPlan(name, (HopSpec(mode),)))
    try:
        fabric = Fabric(dp_axes=("w",), num_workers=w)

        def run(plan):
            def one(g):
                return fabric.aggregate(g, plan, fused=fused)[0]
            return jax.vmap(one, axis_name="w")(gs)

        flat = run(AdmissionPlan.lowbit_all(mode))
        hier = run(AdmissionPlan.lowbit_all(name))
        assert _tree_equal(flat, hier)
    finally:
        unregister_hop_plan(name)


@pytest.mark.parametrize("mode", ["gbinary", "gternary"])
@pytest.mark.parametrize("fused", [False, True])
def test_one_hop_plan_matches_flat_backend_with_ef(rng, mode, fused):
    w, name = 4, f"hier1ef_{mode}"
    gs = _grads(rng, w=w)
    register_hop_plan(HopPlan(name, (HopSpec(mode),)))
    try:
        fabric = Fabric(dp_axes=("w",), num_workers=w)
        g0 = jax.tree.map(lambda x: x[0], gs)
        flat_plan = AdmissionPlan.lowbit_all(mode, error_feedback=True)
        hier_plan = AdmissionPlan.lowbit_all(name, error_feedback=True)
        ef0 = init_ef_states(g0, resolve_policies(g0, flat_plan))
        efs = jax.tree.map(
            lambda e: jnp.asarray(rng.randn(w, *e.shape), e.dtype), ef0)

        def run(plan):
            def one(g, e):
                return fabric.aggregate(g, plan, ef=e, fused=fused)
            return jax.vmap(one, axis_name="w")(gs, efs)

        flat, flat_ef = run(flat_plan)
        hier, hier_ef = run(hier_plan)
        assert _tree_equal(flat, hier)
        assert _tree_equal(flat_ef, hier_ef)   # EF residuals identical too
    finally:
        unregister_hop_plan(name)


# ---------------------------------------------------------------------------
# multi-hop semantics (nested virtual-worker mesh)
# ---------------------------------------------------------------------------

def _hier_2x2_plan():
    # intra group sized to the inner axis of the 2x2 test mesh
    return HopPlan("hier_test_2x2", (HopSpec("fp32", workers=2),
                                     HopSpec("gbinary")))


def test_two_hop_plan_matches_nested_vmap_oracle(rng):
    """Hop 0 = fp32 mean over the *inner* axis, hop 1 = gbinary vote
    over the outer axis: exactly sign(sum_outer(sign(mean_inner(g))))."""
    outer, inner = 2, 2
    gs = jnp.asarray(rng.randn(outer, inner, 64), jnp.float32)
    register_hop_plan(_hier_2x2_plan())
    try:
        fabric = Fabric(dp_axes=("outer", "inner"),
                        num_workers=outer * inner)
        plan = AdmissionPlan.lowbit_all("hier_test_2x2")

        def one(g):
            return fabric.aggregate({"p": g}, plan, fused=False)[0]["p"]
        got = jax.vmap(jax.vmap(one, axis_name="inner"),
                       axis_name="outer")(gs)
        want = jnp.sign(jnp.sign(jnp.mean(gs, axis=1)).sum(axis=0))
        assert _tree_equal(got[0, 0], want)
        # every worker sees the same aggregate
        assert _tree_equal(got, jnp.broadcast_to(want, got.shape))
    finally:
        unregister_hop_plan("hier_test_2x2")


@pytest.mark.parametrize("error_feedback", [False, True])
def test_two_hop_fused_matches_per_leaf(rng, error_feedback):
    outer, inner = 2, 2
    w = outer * inner
    gs = jax.tree.map(
        lambda x: jnp.reshape(x, (outer, inner) + x.shape[1:]),
        _grads(rng, w=w))
    register_hop_plan(_hier_2x2_plan())
    try:
        fabric = Fabric(dp_axes=("outer", "inner"), num_workers=w)
        plan = AdmissionPlan.lowbit_all("hier_test_2x2",
                                        error_feedback=error_feedback)
        g0 = jax.tree.map(lambda x: x[0, 0], gs)
        ef0 = init_ef_states(g0, resolve_policies(g0, plan))
        efs = jax.tree.map(
            lambda e: jnp.asarray(rng.randn(outer, inner, *e.shape),
                                  e.dtype), ef0)

        def run(fused):
            def one(g, e):
                return fabric.aggregate(
                    g, plan, ef=(e if error_feedback else None), fused=fused)
            return jax.vmap(jax.vmap(one, axis_name="inner"),
                            axis_name="outer")(gs, efs)

        want, want_ef = run(False)
        got, got_ef = run(True)
        assert _tree_equal(want, got)
        assert _tree_equal(want_ef, got_ef)
    finally:
        unregister_hop_plan("hier_test_2x2")


def test_multi_hop_plan_requires_matching_axes(rng):
    """A 2-hop plan on a multi-worker session with one dp axis cannot
    place its hops; the backend must refuse, not silently mis-group."""
    w = 4
    gs = _grads(rng, w=w)
    fabric = Fabric(dp_axes=("w",), num_workers=w)
    plan = AdmissionPlan.lowbit_all("hier_fp32_gbinary")
    with pytest.raises(ValueError):
        jax.vmap(lambda g: fabric.aggregate(g, plan, fused=True)[0],
                 axis_name="w")(gs)


def test_host_local_hier_matches_flat_backbone(rng):
    """With no dp axes every hop degenerates to its local encode/decode
    round-trip, so the route equals its backbone codec alone."""
    grads = _grads(rng)
    fabric = Fabric()
    a, _ = fabric.aggregate(grads,
                            AdmissionPlan.lowbit_all("hier_fp32_gbinary"),
                            fused=True)
    b, _ = fabric.aggregate(grads, AdmissionPlan.lowbit_all("gbinary"),
                            fused=True)
    assert _tree_equal(a, b)


# ---------------------------------------------------------------------------
# per-hop traffic accounting
# ---------------------------------------------------------------------------

def test_flat_codecs_report_a_single_leg():
    n = 1 << 16
    for mode in FLAT_CODECS:
        sched = _default_wire_schedule(mode)
        for w in (1, 4, 32):
            legs = hop_wire_bytes_per_device(n, mode, sched, w)
            assert len(legs) == 1
            assert legs[0] == wire_bytes_per_device(n, mode, sched, w)


def test_hier_legs_priced_by_each_hop_backend():
    n, w = 1000, 32
    legs = hop_wire_bytes_per_device(n, "hier_fp32_gbinary",
                                     "hierarchical", w)
    assert legs == (wire_bytes_per_device(n, "fp32",
                                          _default_wire_schedule("fp32"), 8),
                    wire_bytes_per_device(n, "gbinary",
                                          _default_wire_schedule("gbinary"),
                                          4))
    # the route total IS the sum of its legs
    assert sum(legs) == wire_bytes_per_device(n, "hier_fp32_gbinary",
                                              "hierarchical", w)


def test_hier_backbone_leg_beats_flat_backbone_total():
    """The paper-style win: after the intra-node FP32 stage only 1/8 of
    the workers vote across the backbone, so the inter-node leg carries
    fewer bytes than the flat single-codec collective at full width."""
    n, w = 1 << 20, 32
    legs = hop_wire_bytes_per_device(n, "hier_fp32_gbinary",
                                     "hierarchical", w)
    flat = wire_bytes_per_device(n, "gbinary",
                                 _default_wire_schedule("gbinary"), w)
    assert legs[-1] < flat


def test_hop_wire_bytes_property_for_every_codec_pair():
    pytest.importorskip("hypothesis",
                        reason="optional test dependency (pip install .[test])")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(intra=st.sampled_from(FLAT_CODECS),
           backbone=st.sampled_from(FLAT_CODECS),
           intra_w=st.sampled_from([2, 4, 8]),
           w=st.sampled_from([2, 4, 8, 16, 32]),
           n=st.integers(min_value=1, max_value=1 << 16))
    def per_hop_legs_sum_to_route_total(intra, backbone, intra_w, w, n):
        plan = HopPlan("hier_prop_tmp",
                       (HopSpec(intra, workers=intra_w), HopSpec(backbone)))
        register_hop_plan(plan, override=True)
        try:
            legs = hop_wire_bytes_per_device(n, "hier_prop_tmp",
                                             "hierarchical", w)
            sizes = plan.group_sizes(w)
            assert len(legs) == len(plan.hops)
            for leg, hop, s in zip(legs, plan.hops, sizes):
                assert leg == wire_bytes_per_device(
                    n, hop.codec, _default_wire_schedule(hop.codec), s)
            assert sum(legs) == wire_bytes_per_device(
                n, "hier_prop_tmp", "hierarchical", w)
        finally:
            unregister_hop_plan("hier_prop_tmp")

    per_hop_legs_sum_to_route_total()


def test_layout_comm_time_sums_per_hop_legs():
    w = 32
    params = {"p": jax.ShapeDtypeStruct((1 << 16,), "float32")}
    plan = AdmissionPlan.lowbit_all("hier_fp32_gbinary")
    layout = plan_buckets(params, resolve_policies(params, plan))
    assert layout.num_launches == 1
    legs = hop_wire_bytes_per_device(1 << 16, "hier_fp32_gbinary",
                                     "hierarchical", w)
    ici = IciModel()
    assert modeled_layout_comm_time(layout, w, ici) == pytest.approx(
        ici.collective_time(sum(legs), w, num_launches=1))


# ---------------------------------------------------------------------------
# bucket identity: routes never mix
# ---------------------------------------------------------------------------

def test_bucket_key_carries_hop_signature(rng):
    grads = _grads(rng)
    fabric = Fabric()
    plan = AdmissionPlan.lowbit_backbone("hier_fp32_gbinary")
    layout = fabric.layout_for(grads, plan)
    hops = {b.key.mode: b.key.hops for b in layout.buckets}
    assert hops["hier_fp32_gbinary"] == \
        "hier_fp32_gbinary[fp32:8>gbinary:*]"
    assert hops["fp32"] is None               # flat codecs carry no route


def test_layout_cache_invalidated_when_hop_plan_swapped(rng):
    grads = _grads(rng)
    fabric = Fabric()
    plan = AdmissionPlan.lowbit_all("hier_swap")
    register_hop_plan(HopPlan("hier_swap", (HopSpec("gbinary"),)))
    try:
        lay1 = fabric.layout_for(grads, plan)
        assert lay1.buckets[0].key.hops == "hier_swap[gbinary:*]"
        register_hop_plan(HopPlan("hier_swap", (HopSpec("fp32", workers=2),
                                                HopSpec("gbinary"))),
                          override=True)
        lay2 = fabric.layout_for(grads, plan)
        assert lay2.buckets[0].key.hops == \
            "hier_swap[fp32:2>gbinary:*]"
    finally:
        unregister_hop_plan("hier_swap")


# ---------------------------------------------------------------------------
# control surface: presets + admission ladder
# ---------------------------------------------------------------------------

def test_hier_presets_registered():
    presets = plan_presets(error_feedback=True)
    for name in ("hier_fp32_gbinary", "hier_fp32_gternary",
                 "hier_fp32_int4"):
        pol = presets[name].policy_for("backbone")
        assert codec_name(pol.mode) == name
        assert schedule_name(pol.resolved_schedule()) == "hierarchical"
        # head stays FP32 — hier presets are backbone plans
        assert codec_name(presets[name].policy_for("head").mode) == "fp32"
    assert presets["hier_fp32_gbinary"].policy_for("backbone").error_feedback
    # int4 backbone pins EF off, like the flat int4_backbone preset
    assert not presets["hier_fp32_int4"].policy_for("backbone").error_feedback


def test_commander_ladder_admits_hier_modes():
    cmd = Commander(binary_mode="hier_fp32_gbinary",
                    ternary_mode="hier_fp32_gternary",
                    tau_binary=0.5, tau_ternary=0.2)
    plan = cmd.propose({"backbone": {"gbinary": 0.9},
                        "embed": {"gbinary": 0.3, "gternary": 0.4},
                        "norms": {"gbinary": 0.9}})
    assert codec_name(plan.policy_for("backbone").mode) == \
        "hier_fp32_gbinary"
    assert codec_name(plan.policy_for("embed").mode) == \
        "hier_fp32_gternary"
    assert codec_name(plan.policy_for("norms").mode) == "fp32"


# ---------------------------------------------------------------------------
# sim: multihop replays hierarchical routes leg by leg
# ---------------------------------------------------------------------------

def test_multihop_sim_matches_analytic_model_single_launch():
    """Degenerate single-launch, queue-free replay must agree with
    MultiHopModel.route_time within the 1% sim-validation tolerance."""
    n, w = 1 << 20, 32
    legs = hop_wire_bytes_per_device(n, "hier_fp32_gbinary",
                                     "hierarchical", w)
    spec = LaunchSpec("b", "hier_fp32_gbinary", "hierarchical", n,
                      float(sum(legs)), hop_bytes=tuple(legs))
    rep = simulate_launches([spec], w, topology="multihop", datapath=None)
    launch = rep.launches[0]
    assert launch.links == ("hop0", "hop1")
    ref = MultiHopModel().route_time(legs, num_launches=1)
    assert launch.collective_s == pytest.approx(ref, rel=REL_TOL)


def test_layout_specs_carry_hop_bytes_and_match_layout_model():
    w = 32
    params = {"p": jax.ShapeDtypeStruct((1 << 16,), "float32")}
    plan = AdmissionPlan.lowbit_all("hier_fp32_gbinary")
    layout = plan_buckets(params, resolve_policies(params, plan))
    specs = layout_launch_specs(layout, w)
    assert len(specs) == 1
    legs = hop_wire_bytes_per_device(1 << 16, "hier_fp32_gbinary",
                                     "hierarchical", w)
    assert specs[0].hop_bytes == tuple(legs)
    assert specs[0].wire_bytes == pytest.approx(sum(legs))
    rep = simulate_launches(specs, w, topology="multihop", datapath=None)
    ref = modeled_layout_multihop_time(layout, w)
    assert rep.launches[0].collective_s == pytest.approx(ref, rel=REL_TOL)


def test_flat_launch_specs_do_not_grow_hop_bytes(rng):
    """Flat codecs keep hop_bytes=None so the multihop topology applies
    its own per-stage payload profile exactly as before this refactor."""
    grads = _grads(rng)
    plan = AdmissionPlan.lowbit_all("gbinary")
    layout = plan_buckets(grads, resolve_policies(grads, plan))
    for spec in layout_launch_specs(layout, 8):
        assert spec.hop_bytes is None


def test_fabric_simulate_multihop_reports_per_hop_links():
    fabric = Fabric(dp_axes=("w",), num_workers=32)
    params = {"backbone": {"w1": jax.ShapeDtypeStruct((4096,), "float32")}}
    plan = AdmissionPlan.lowbit_all("hier_fp32_gbinary")
    rep = fabric.simulate(params, plan, topology="multihop")
    assert {"hop0", "hop1"} <= set(rep.link_utilization)
