"""Bucketed (fused) aggregation: layout planner + bit-for-bit equivalence.

The fusion contract: `aggregate_tree_bucketed` / `Fabric.aggregate(fused=
True)` must be *bit-identical* — aggregates and EF states — to the
per-leaf path for every built-in schedule, in every mode, with and
without error feedback, for any gate phase.  Multi-worker semantics are
exercised with virtual workers via ``jax.vmap(..., axis_name='w')``
(psum/all_to_all/all_gather resolve against the vmapped axis exactly as
on a mesh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, AggregationMode, GroupPolicy,
                        IciModel, Schedule, init_ef_states,
                        modeled_layout_comm_time, plan_buckets,
                        resolve_policies)
from repro.core.buckets import DEFAULT_BUCKET_BYTES
from repro.core.lowbit import LeafPolicy
from repro.fabric import (Fabric, aggregate_tree, aggregate_tree_bucketed,
                          register_schedule, unregister_schedule)
from jax.sharding import PartitionSpec as P


def _tree_equal(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b)
    return all(jax.tree.leaves(flags))


def _grads(rng, w=None):
    mk = (lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)) if w is None \
        else (lambda *s: jnp.asarray(rng.randn(w, *s), jnp.float32))
    return {"backbone": {"w1": mk(40, 33), "w2": mk(257), "w3": mk(64, 8)},
            "embed": {"table": mk(130, 7)},
            "head": {"w": mk(17)},
            "norms": {"scale": mk(33)}}


def _plan(schedule=None, error_feedback=False,
          mode=AggregationMode.G_BINARY):
    return AdmissionPlan.from_dict(
        {"backbone": GroupPolicy(mode, schedule,
                                 error_feedback=error_feedback),
         "embed": GroupPolicy(AggregationMode.G_TERNARY, schedule)},
        default=GroupPolicy(AggregationMode.FP32))


# ---------------------------------------------------------------------------
# layout planner
# ---------------------------------------------------------------------------

def test_layout_groups_by_compatibility_key(rng):
    grads = _grads(rng)
    layout = plan_buckets(grads, resolve_policies(grads, _plan()))
    # three distinct keys -> three buckets (backbone / embed / fp32 rest)
    assert len(layout.buckets) == 3 and not layout.unfused
    assert layout.num_leaves == 6 and layout.num_launches == 3
    by_mode = {b.key.mode: b for b in layout.buckets}
    backbone = by_mode[AggregationMode.G_BINARY]
    assert [s.name for s in backbone.slots] == ["backbone/w1", "backbone/w2",
                                                "backbone/w3"]
    # offsets are a running sum of sizes; bucket size is the total
    assert [s.offset for s in backbone.slots] == [0, 40 * 33, 40 * 33 + 257]
    assert backbone.size == 40 * 33 + 257 + 64 * 8
    # fp32 leaves from different groups fuse (same wire schedule + mode)
    fp32 = by_mode[AggregationMode.FP32]
    assert {s.name for s in fp32.slots} == {"head/w", "norms/scale"}


def test_layout_respects_bucket_byte_budget(rng):
    grads = _grads(rng)
    policies = resolve_policies(grads, _plan())
    # 1 KiB budget = 256 f32 elements: backbone leaves can't share buckets
    layout = plan_buckets(grads, policies, bucket_bytes=1024)
    backbone = [b for b in layout.buckets
                if b.key.mode == AggregationMode.G_BINARY]
    assert len(backbone) == 3          # every leaf overflows the budget
    for b in backbone:                 # oversize leaves bucket alone
        assert len(b.slots) == 1 and b.slots[0].offset == 0


def test_layout_per_leaf_degenerate_and_stability(rng):
    grads = _grads(rng)
    policies = resolve_policies(grads, _plan())
    per_leaf = plan_buckets(grads, policies, bucket_bytes=1)
    assert per_leaf.num_launches == per_leaf.num_leaves == 6
    # deterministic: same inputs -> identical layout (jit-cache safe)
    a = plan_buckets(grads, policies)
    b = plan_buckets(grads, policies)
    assert a == b
    assert list(a.launches()) == list(b.launches())


def test_layout_tp_sharded_and_nonfusable_leaves_stay_per_leaf(rng):
    grads = {"a": jnp.asarray(rng.randn(8, 4), jnp.float32),
             "b": jnp.asarray(rng.randn(8, 4), jnp.float32)}
    policies = {
        "a": LeafPolicy(AggregationMode.G_BINARY, Schedule.PACKED_A2A,
                        model_spec=P(None, "model")),
        "b": LeafPolicy(AggregationMode.G_BINARY, Schedule.PACKED_A2A)}
    layout = plan_buckets(grads, policies)
    assert [u.name for u in layout.unfused] == ["a"]     # TP-sharded
    assert len(layout.buckets) == 1
    # a predicate rejecting the schedule forces per-leaf for both
    layout2 = plan_buckets(grads, policies, fusable=lambda s: False)
    assert len(layout2.unfused) == 2 and not layout2.buckets


def test_layout_key_uses_wire_schedule(rng):
    """FP32 leaves nominally on packed_a2a fuse with plain psum leaves."""
    grads = {"a": jnp.asarray(rng.randn(8), jnp.float32),
             "b": jnp.asarray(rng.randn(8), jnp.float32)}
    policies = {
        "a": LeafPolicy(AggregationMode.FP32, Schedule.PACKED_A2A),
        "b": LeafPolicy(AggregationMode.FP32, Schedule.PSUM)}
    layout = plan_buckets(grads, policies)
    assert len(layout.buckets) == 1
    assert layout.buckets[0].key.schedule == "psum"


def test_ternary_gate_mask_is_per_leaf_indexed():
    sds = jax.ShapeDtypeStruct
    grads = {"a": sds((5,), jnp.float32), "b": sds((4,), jnp.float32)}
    pol = LeafPolicy(AggregationMode.G_TERNARY, Schedule.VOTE_PSUM,
                     gate_phase=1)
    layout = plan_buckets(grads, {"a": pol, "b": pol})
    (bucket,) = layout.buckets
    # each leaf restarts the 2-of-3 pattern at its own flat index 0
    leaf = (((np.arange(5) + 1) % 3) != 2)
    want = np.concatenate([leaf, leaf[:4]])
    gate = bucket.gate()
    np.testing.assert_array_equal(gate.mask(), want)
    # the on-device representation matches the host mask bit for bit
    np.testing.assert_array_equal(np.asarray(gate.vector(jnp.float32)),
                                  want.astype(np.float32))


def test_gate_phase_normalized_for_non_ternary_modes(rng):
    """gate_phase only affects G-Ternary; binary leaves differing only in
    phase must still share a bucket."""
    grads = {"a": jnp.asarray(rng.randn(8), jnp.float32),
             "b": jnp.asarray(rng.randn(8), jnp.float32)}
    policies = {
        "a": LeafPolicy(AggregationMode.G_BINARY, Schedule.VOTE_PSUM,
                        gate_phase=0),
        "b": LeafPolicy(AggregationMode.G_BINARY, Schedule.VOTE_PSUM,
                        gate_phase=1)}
    layout = plan_buckets(grads, policies)
    assert len(layout.buckets) == 1 and not layout.unfused


# ---------------------------------------------------------------------------
# bit-for-bit equivalence: fused vs per-leaf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", [None, Schedule.VOTE_PSUM])
@pytest.mark.parametrize("error_feedback", [False, True])
def test_fused_matches_per_leaf_host_local(rng, schedule, error_feedback):
    grads = _grads(rng)
    plan = _plan(schedule=schedule, error_feedback=error_feedback)
    fabric = Fabric()
    policies = fabric.resolve(grads, plan)
    ef = init_ef_states(grads, policies) if error_feedback else None
    want, want_ef = fabric.aggregate(grads, plan, ef=ef, fused=False)
    got, got_ef = fabric.aggregate(grads, plan, ef=ef, fused=True)
    assert _tree_equal(want, got)
    if error_feedback:
        assert _tree_equal(want_ef, got_ef)
        # EF actually produced a nonzero residual somewhere
        assert float(jnp.sum(jnp.abs(got_ef["backbone"]["w1"]))) > 0
    else:
        assert want_ef is None and got_ef is None


@pytest.mark.parametrize("mode", [AggregationMode.G_BINARY,
                                  AggregationMode.G_TERNARY])
@pytest.mark.parametrize("gate_phase", [0, 1, 2])
def test_fused_matches_per_leaf_all_gate_phases(rng, mode, gate_phase):
    grads = _grads(rng)
    pol = lambda _: LeafPolicy(mode, Schedule.VOTE_PSUM,
                               gate_phase=gate_phase)
    policies = jax.tree.map(pol, grads)
    ctx = Fabric().context
    want, _ = aggregate_tree(ctx, grads, policies)
    got, _ = aggregate_tree_bucketed(ctx, grads, policies)
    assert _tree_equal(want, got)


@pytest.mark.parametrize("schedule", [Schedule.VOTE_PSUM,
                                      Schedule.PACKED_A2A])
@pytest.mark.parametrize("error_feedback", [False, True])
def test_fused_matches_per_leaf_virtual_workers(rng, schedule,
                                                error_feedback):
    """W=4 virtual workers via vmap: binary + ternary + FP32 mixed plan.

    Covers the fused packed_a2a datapath end to end — pack, all_to_all,
    PopCount/majority with the bucket-wide gate words, all_gather — and
    its per-bucket EF handling, against the per-leaf reference.
    """
    w = 4
    gs = _grads(rng, w=w)
    plan = _plan(schedule=schedule, error_feedback=error_feedback)
    fabric = Fabric(dp_axes=("w",), num_workers=w)
    g0 = jax.tree.map(lambda x: x[0], gs)
    policies = fabric.resolve(g0, plan)
    if error_feedback:
        ef0 = init_ef_states(g0, policies)
        # nonzero per-worker residuals so injection has a real effect
        efs = jax.tree.map(
            lambda e: (jnp.asarray(rng.randn(w, *e.shape), jnp.float32)
                       if e.ndim > 0 else jnp.zeros((w,) + e.shape)), ef0)
    else:
        efs = jax.tree.map(lambda x: jnp.zeros((x.shape[0],)), gs)  # unused

    def run(fused):
        def one(g, e):
            return fabric.aggregate(
                g, plan, ef=(e if error_feedback else None), fused=fused)
        return jax.vmap(one, axis_name="w")(gs, efs)

    want, want_ef = run(False)
    got, got_ef = run(True)
    assert _tree_equal(want, got)
    if error_feedback:
        assert _tree_equal(want_ef, got_ef)
    # semantic oracle for the ternary group (dense Section-2 reduction)
    from repro.kernels import ref
    table = gs["embed"]["table"]
    want_ter = np.asarray(ref.gternary_aggregate_dense(
        table.reshape(w, -1))).reshape(table.shape[1:])
    np.testing.assert_array_equal(np.asarray(got["embed"]["table"][0]),
                                  want_ter)


def test_fused_is_the_default_aggregate_path(rng):
    grads = _grads(rng)
    fabric = Fabric()
    assert fabric.fused
    got, _ = fabric.aggregate(grads, _plan())           # default route
    want, _ = fabric.aggregate(grads, _plan(), fused=False)
    assert _tree_equal(want, got)
    # the layout is planned once and cached per (tree, policies) signature
    lay = fabric.layout_for(grads, _plan())
    assert lay is fabric.layout_for(grads, _plan())
    assert lay.num_launches < lay.num_leaves


def test_non_fusable_custom_backend_routes_per_leaf(rng):
    """A registered backend without `fusable` still works under the
    default fused path — its leaves ride the per-leaf fallback."""
    @register_schedule("toy_unfused_mean")
    class ToyMean:
        name = "toy_unfused_mean"

        def aggregate(self, ctx, g, policy, ef=None):
            return 2.0 * g, ef

    try:
        grads = {"a": jnp.asarray(np.arange(6.0), jnp.float32),
                 "b": jnp.asarray(np.arange(4.0), jnp.float32)}
        plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                        schedule="toy_unfused_mean")
        fabric = Fabric()
        layout = fabric.layout_for(grads, plan)
        assert len(layout.unfused) == 2 and not layout.buckets
        got, _ = fabric.aggregate(grads, plan)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      2.0 * np.arange(6.0))
    finally:
        unregister_schedule("toy_unfused_mean")


def test_layout_cache_invalidated_when_backend_swapped(rng):
    """Swapping a schedule backend under the same name (the documented
    extension workflow) must not leave a stale fused layout routing
    leaves to a backend that no longer implements aggregate_flat."""
    grads = {"a": jnp.asarray(rng.randn(8), jnp.float32)}
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                    schedule="toy_swappable")
    fabric = Fabric()

    @register_schedule("toy_swappable")
    class FusableToy:
        name = "toy_swappable"
        fusable = True

        def aggregate(self, ctx, g, policy, ef=None):
            return g, ef

        def aggregate_flat(self, ctx, flat, codec, *, gate=None):
            return flat

    try:
        assert len(fabric.layout_for(grads, plan).buckets) == 1
        fabric.aggregate(grads, plan)
        unregister_schedule("toy_swappable")

        @register_schedule("toy_swappable")
        class PerLeafToy:
            name = "toy_swappable"       # no fusable / aggregate_flat

            def aggregate(self, ctx, g, policy, ef=None):
                return 3.0 * g, ef

        layout = fabric.layout_for(grads, plan)
        assert not layout.buckets and len(layout.unfused) == 1
        got, _ = fabric.aggregate(grads, plan)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      3.0 * np.asarray(grads["a"]))
    finally:
        unregister_schedule("toy_swappable")


def test_mixed_dtypes_never_share_a_bucket(rng):
    grads = {"a": jnp.asarray(rng.randn(8), jnp.float32),
             "b": jnp.asarray(rng.randn(8), jnp.bfloat16)}
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY)
    fabric = Fabric()
    layout = fabric.layout_for(grads, plan)
    assert len(layout.buckets) == 2
    want, _ = fabric.aggregate(grads, plan, fused=False)
    got, _ = fabric.aggregate(grads, plan, fused=True)
    assert _tree_equal(want, got)
    assert got["b"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# traffic model: the per-launch term explains the fusion win
# ---------------------------------------------------------------------------

def test_layout_comm_time_rewards_fusion(rng):
    grads = _grads(rng)
    policies = resolve_policies(grads, _plan())
    fused = plan_buckets(grads, policies)
    per_leaf = plan_buckets(grads, policies, bucket_bytes=1)
    w = 32
    t_fused = modeled_layout_comm_time(fused, w)
    t_leaf = modeled_layout_comm_time(per_leaf, w)
    assert t_fused < t_leaf
    ici = IciModel()
    # identical bytes: the whole gap is launches * per-launch latency
    per_launch = (2 * (w - 1)) * ici.hop_latency_s + ici.launch_overhead_s
    gap = (per_leaf.num_launches - fused.num_launches) * per_launch
    assert t_leaf - t_fused == pytest.approx(gap)


def test_collective_time_launch_term_monotonic():
    ici = IciModel()
    one = ici.collective_time(2 ** 20, 8, num_launches=1)
    many = ici.collective_time(2 ** 20, 8, num_launches=10)
    assert many > one
    assert many - one == pytest.approx(
        9 * (14 * ici.hop_latency_s + ici.launch_overhead_s))
