"""Gradient-codec registry: the representation axis behind the fabric.

Covers the registry contract (round-trip, duplicate protection, clear
unknown-name error, parameterized instances), bit-for-bit equivalence of
the codec-dispatched built-ins with the direct core collectives (the
pre-redesign paths) on per-leaf and fused routes, the normalized
``wire_schedule`` over the full codec x schedule grid, the
``AggregationMode`` deprecation shims, and — the seam this PR exists
for — a codec registered *outside* ``repro.fabric.codecs`` flowing
through the fused bucket path, the traffic model, the simulator, and a
compiled train step with zero edits to schedule backends or sim lanes.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, AggregationMode, GroupPolicy,
                        Schedule, bits_per_element, codec_name,
                        canonical_mode, group_sizes, plan_traffic_ratio,
                        resolve_policies, wire_bytes_per_device,
                        wire_schedule)
from repro.core.lowbit import fp32_allreduce
from repro.fabric import (Codec, Fabric, GradientCodec, available_codecs,
                          get_codec, plan_presets, register_codec,
                          unregister_codec)
from repro.fabric.extra_codecs import Int4Codec, TopKCodec


def _tree_equal(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b)
    return all(jax.tree.leaves(flags))


def _grads(rng):
    return {"backbone": {"w1": jnp.asarray(rng.randn(40, 33), jnp.float32),
                         "w2": jnp.asarray(rng.randn(257), jnp.float32)},
            "embed": {"table": jnp.asarray(rng.randn(130, 7), jnp.float32)},
            "head": {"w": jnp.asarray(rng.randn(17), jnp.float32)}}


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_builtin_codecs_registered():
    names = available_codecs()
    for expected in ("identity", "fp32", "gbinary", "gternary",
                     "int4", "topk"):
        assert expected in names
    # enum and string keys resolve to the same codec
    assert get_codec(AggregationMode.G_BINARY) is get_codec("gbinary")
    assert isinstance(get_codec("gternary"), Codec)
    # the paper's Table 2 payload figures live on the codecs
    assert get_codec("gbinary").bits_per_element == 1.0
    assert get_codec("gternary").bits_per_element == pytest.approx(
        np.log2(3.0))
    assert get_codec("fp32").bits_per_element == 32.0
    assert get_codec("int4").bits_per_element == 4.0


def test_register_codec_roundtrip_and_duplicate():
    @register_codec("toy_codec")
    class Toy(GradientCodec):
        name = "toy_codec"
        bits_per_element = 8.0

    try:
        assert isinstance(get_codec("toy_codec"), Toy)
        with pytest.raises(ValueError, match="already registered"):
            register_codec("toy_codec")(Toy)
        # a clash on any alias must not half-register the fresh name
        with pytest.raises(ValueError, match="already registered"):
            register_codec("toy_fresh", "toy_codec")(Toy)
        assert "toy_fresh" not in available_codecs()
    finally:
        unregister_codec("toy_codec")
    assert "toy_codec" not in available_codecs()


def test_override_registration_sweeps_stale_aliases():
    """Overriding a name must not leave other aliases resolving the
    replaced instance — a plan naming the alias would silently use the
    old codec."""
    @register_codec("ov_main", "ov_alias")
    class A(GradientCodec):
        name = "ov_main"
        bits_per_element = 8.0

    try:
        @register_codec("ov_main", override=True)
        class B(GradientCodec):
            name = "ov_main"
            bits_per_element = 4.0

        assert get_codec("ov_main").bits_per_element == 4.0
        assert "ov_alias" not in available_codecs()   # stale alias swept
    finally:
        unregister_codec("ov_main")
        unregister_codec("ov_alias")


def test_unregister_codec_tears_down_aliases():
    @register_codec("toy_main", "toy_alias")
    class Toy(GradientCodec):
        name = "toy_main"
        bits_per_element = 8.0

    unregister_codec("toy_main")
    assert "toy_main" not in available_codecs()
    assert "toy_alias" not in available_codecs()   # alias removed too
    # re-registering the alias name must not clash with a stale entry
    register_codec("toy_alias")(Toy)
    unregister_codec("toy_alias")


def test_unknown_codec_raises_clear_error():
    with pytest.raises(KeyError, match="unknown codec 'nope'"):
        get_codec("nope")
    with pytest.raises(KeyError, match="register_codec"):
        get_codec("nope")


def test_parameterized_codec_instance_registration():
    dense = TopKCodec(fraction=1.0)
    register_codec("topall")(dense)
    try:
        assert get_codec("topall") is dense
        assert get_codec("topall").bits_per_element == 64.0
        # fraction=1 keeps everything: encode is the identity
        g = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
        np.testing.assert_array_equal(np.asarray(dense.encode(None, g)),
                                      np.asarray(g))
    finally:
        unregister_codec("topall")
    with pytest.raises(ValueError, match="fraction"):
        TopKCodec(fraction=0.0)


def test_register_codec_rejects_incomplete_objects():
    with pytest.raises(TypeError, match="bits_per_element"):
        @register_codec("toy_bad")
        class Bad:                       # no name / bits_per_element
            pass


# ---------------------------------------------------------------------------
# wire_schedule: normalized returns over the codec x schedule grid
# ---------------------------------------------------------------------------

def test_wire_schedule_always_returns_canonical_string():
    """Old behavior leaked a Schedule enum on one branch and the caller's
    enum-or-string otherwise; the return is now always the registry key
    string — exhaustively over every built-in codec x schedule pairing
    (enum and string spellings) plus custom names on both axes."""
    schedules = [Schedule.PSUM, Schedule.VOTE_PSUM, Schedule.PACKED_A2A,
                 "psum", "vote_psum", "packed_a2a", "sign_of_mean",
                 "my_custom_sched"]
    for mode in list(AggregationMode) + [m.value for m in AggregationMode] \
            + ["int4", "topk"]:
        votes = get_codec(mode).reduction == "vote"
        for sched in schedules:
            got = wire_schedule(mode, sched)
            assert type(got) is str, (mode, sched, got)
            name = sched.value if isinstance(sched, Schedule) else sched
            if not votes and name in ("vote_psum", "packed_a2a"):
                assert got == "psum"            # mean codecs ride the bypass
            elif votes and name == "psum":
                assert got == "vote_psum"       # votes have no mean path
            else:
                assert got == name              # everything else: as named


def test_wire_schedule_mean_codec_never_on_vote_transport():
    # the int4 mean codec nominally on the vote transports rides psum,
    # exactly like FP32 — the generalized bypass semantics
    assert wire_schedule("int4", Schedule.VOTE_PSUM) == "psum"
    assert wire_schedule("int4", Schedule.PACKED_A2A) == "psum"
    assert wire_schedule("int4", "sign_of_mean") == "sign_of_mean"


# ---------------------------------------------------------------------------
# built-ins: bit-identical to the pre-redesign direct collectives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", [None, Schedule.VOTE_PSUM])
@pytest.mark.parametrize("fused", [False, True])
def test_builtin_codecs_bit_identical_string_vs_enum(rng, schedule, fused):
    """Naming built-in codecs by string is bit-for-bit the enum path —
    per-leaf and fused (packed_a2a needs real/virtual workers and is
    covered by test_string_named_packed_a2a_virtual_workers)."""
    grads = _grads(rng)
    fabric = Fabric()

    def plan(modes):
        backbone, embed = modes
        return AdmissionPlan.from_dict(
            {"backbone": GroupPolicy(backbone, schedule),
             "embed": GroupPolicy(embed, schedule)},
            default=GroupPolicy(AggregationMode.FP32))

    want, _ = fabric.aggregate(
        grads, plan((AggregationMode.G_BINARY, AggregationMode.G_TERNARY)),
        fused=fused)
    got, _ = fabric.aggregate(grads, plan(("gbinary", "gternary")),
                              fused=fused)
    assert _tree_equal(want, got)


def test_fp32_codec_is_exact_pmean(rng):
    grads = _grads(rng)
    for fused in (False, True):
        agg, _ = Fabric().aggregate(grads, AdmissionPlan.fp32_all(),
                                    fused=fused)
        ref = jax.tree.map(lambda g: fp32_allreduce(g, ()), grads)
        assert _tree_equal(agg, ref)


def test_vote_codecs_match_dense_oracle_multiworker(rng):
    """W=4 virtual workers: codec-dispatched gbinary/gternary equal the
    dense Section-2 oracle, with and without error feedback threading."""
    from repro.kernels import ref
    w = 4
    gs = jnp.asarray(rng.randn(w, 64, 5), jnp.float32)
    fabric = Fabric(dp_axes=("w",), num_workers=w)

    for mode, oracle in (("gbinary", ref.gbinary_aggregate_dense),
                         ("gternary", ref.gternary_aggregate_dense)):
        plan = AdmissionPlan.lowbit_all(mode)

        def one(g):
            agg, _ = fabric.aggregate({"g": g}, plan)
            return agg["g"]

        got = jax.vmap(one, axis_name="w")(gs)
        want = np.asarray(oracle(gs.reshape(w, -1))).reshape(64, 5)
        np.testing.assert_array_equal(np.asarray(got[0]), want)


def test_string_named_packed_a2a_virtual_workers(rng):
    """String-named codecs on the packed controller schedule (W=4 vmap)
    are bit-identical to the enum-named path, fused and per-leaf."""
    w = 4
    gs = {"backbone": jnp.asarray(rng.randn(w, 40, 33), jnp.float32),
          "embed": jnp.asarray(rng.randn(w, 130), jnp.float32)}
    fabric = Fabric(dp_axes=("w",), num_workers=w)

    def plan(modes):
        backbone, embed = modes
        return AdmissionPlan.from_dict(
            {"backbone": GroupPolicy(backbone, Schedule.PACKED_A2A),
             "embed": GroupPolicy(embed, Schedule.PACKED_A2A)},
            default=GroupPolicy(AggregationMode.FP32))

    for fused in (False, True):
        def run(p, fused=fused):
            def one(g):
                agg, _ = fabric.aggregate(g, p, fused=fused)
                return agg
            return jax.vmap(one, axis_name="w")(gs)

        want = run(plan((AggregationMode.G_BINARY,
                         AggregationMode.G_TERNARY)))
        got = run(plan(("gbinary", "gternary")))
        assert _tree_equal(want, got)


def test_codec_threads_ef_flag_gates_fused_ef(rng):
    """EF rides the fused collective only when the codec allows it: a
    mean codec with threads_ef=False on an EF-enabled plan leaves the
    residuals untouched (exactly the per-leaf psum behavior)."""
    from repro.core import init_ef_states
    grads = _grads(rng)
    plan = AdmissionPlan.lowbit_all("int4", error_feedback=True)
    fabric = Fabric()
    policies = fabric.resolve(grads, plan)
    ef = init_ef_states(grads, policies)
    _, new_ef = fabric.aggregate(grads, plan, ef=ef, fused=True)
    assert _tree_equal(ef, new_ef)       # int4 declares threads_ef=False


# ---------------------------------------------------------------------------
# AggregationMode deprecation shim
# ---------------------------------------------------------------------------

def test_legacy_mode_tables_warn_and_match_registry():
    from repro.core import modes
    with pytest.warns(DeprecationWarning, match="BITS_PER_ELEMENT"):
        table = modes.BITS_PER_ELEMENT
    assert table == {m: get_codec(m).bits_per_element
                     for m in AggregationMode}
    with pytest.warns(DeprecationWarning, match="DEFAULT_SCHEDULE"):
        defaults = modes.DEFAULT_SCHEDULE
    assert defaults == {m: Schedule(get_codec(m).default_schedule)
                        for m in AggregationMode}
    with pytest.warns(DeprecationWarning, match="is_lowbit"):
        assert AggregationMode.G_BINARY.is_lowbit
    with pytest.raises(AttributeError):
        modes.NOT_A_TABLE


def test_shimmed_enum_reproduces_pilot_decisions():
    """The Fig-6 pilot's Commander ladder still emits the same plans
    through the shim (its values are the built-in codec names)."""
    from repro.core import Commander
    cmd = Commander(tau_binary=0.35, tau_ternary=0.30)
    plan = cmd.propose({"backbone": {"gbinary": 0.5},
                        "embed": {"gbinary": 0.1, "gternary": 0.4},
                        "head": {"gbinary": 0.0, "gternary": 0.0}})
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY
    assert plan.policy_for("embed").mode == AggregationMode.G_TERNARY
    assert plan.policy_for("head").mode == AggregationMode.FP32
    assert plan.signature() == ("backbone:gbinary:vote_psum:0"
                                "|embed:gternary:vote_psum:0"
                                "|head:fp32:psum:0|*:fp32:psum:0")


def test_canonical_mode_and_plan_json_roundtrip():
    assert canonical_mode("gbinary") is AggregationMode.G_BINARY
    assert canonical_mode(AggregationMode.FP32) is AggregationMode.FP32
    assert canonical_mode("int4") == "int4" and codec_name("int4") == "int4"

    from repro.fabric import plan_from_jsonable, plan_to_jsonable
    plan = AdmissionPlan.from_dict(
        {"backbone": GroupPolicy("int4"),
         "embed": GroupPolicy(AggregationMode.G_TERNARY)},
        default=GroupPolicy(AggregationMode.FP32))
    back = plan_from_jsonable(plan_to_jsonable(plan))
    assert back.signature() == plan.signature()
    assert back.policy_for("backbone").mode == "int4"
    assert back.policy_for("embed").mode is AggregationMode.G_TERNARY


# ---------------------------------------------------------------------------
# the extension seam: a codec registered outside repro.fabric.codecs
# flows through buckets + traffic + sim + build_step with no backend edits
# ---------------------------------------------------------------------------

def test_extra_codec_int4_fused_aggregate_and_traffic(rng):
    grads = _grads(rng)
    plan = plan_presets()["int4_backbone"]
    fabric = Fabric()
    layout = fabric.layout_for(grads, plan)
    # the int4 leaves fuse on the psum wire schedule (mean transport)
    int4_buckets = [b for b in layout.buckets if b.key.mode == "int4"]
    assert len(int4_buckets) == 1
    assert int4_buckets[0].key.schedule == "psum"
    agg, _ = fabric.aggregate(grads, plan)
    # W=1 psum mean of the quantized payload == the quantized payload;
    # per-bucket absmax scale, so quantize the fused flat payload
    codec = Int4Codec()
    flat = jnp.concatenate([grads["backbone"]["w1"].reshape(-1),
                            grads["backbone"]["w2"].reshape(-1)])
    q = np.asarray(codec.encode(None, flat))
    np.testing.assert_array_equal(
        np.asarray(agg["backbone"]["w1"]).reshape(-1), q[:40 * 33])
    # quantization actually happened (few distinct magnitudes) but kept
    # the direction
    assert len(np.unique(np.abs(q))) <= 8
    np.testing.assert_array_equal(np.sign(q[q != 0]),
                                  np.sign(np.asarray(flat)[q != 0]))
    # head stays exact FP32
    np.testing.assert_array_equal(np.asarray(agg["head"]["w"]),
                                  np.asarray(grads["head"]["w"]))

    # traffic accounting picks the codec up by name
    sizes = group_sizes(grads)
    ratio = plan_traffic_ratio(sizes, plan)
    nb = sizes["backbone"]
    total = sum(sizes.values())
    assert ratio == pytest.approx((nb * 4.0 + (total - nb) * 32.0)
                                  / (32.0 * total))
    w = 8
    f = (w - 1) / w
    assert wire_bytes_per_device(1000, "int4", "psum", w) == pytest.approx(
        2 * f * 1000 * 0.5)


@pytest.mark.parametrize("topology", ["ici_ring", "cxl_direct"])
def test_extra_codec_simulates_on_topologies(rng, topology):
    """The int4 codec's layout simulates on >= 2 topologies, timed by its
    own lane descriptor — no edits to sim/datapath built-in lanes."""
    grads = _grads(rng)
    fabric = Fabric(num_workers=8)
    rep = fabric.simulate(grads, plan_presets()["int4_backbone"],
                          topology=topology, compute_time_s=1e-4)
    assert rep.topology == topology
    assert rep.step_time_s > 0 and rep.num_launches >= 2
    int4 = [l for l in rep.launches if l.mode == "int4"]
    assert len(int4) == 1 and int4[0].wire_bytes > 0
    # the codec's 4-bit payload moves 8x fewer wire bytes than its FP32
    # sibling of the same element count would
    fp32 = [l for l in rep.launches if l.mode == "fp32"]
    assert all(l.wire_bytes > 0 for l in fp32)
    from repro.sim import FlitPipeline
    pipe = FlitPipeline()
    assert pipe.lane("int4").name == "int4_dense"
    assert pipe.flits(1 << 20, "int4") == (1 << 20) * 4 // 512


def test_custom_codec_registered_in_test_runs_everywhere(rng):
    """A codec defined *here* (outside the repo's codec modules): scaled
    mean with custom bits — proof the representation axis is open."""
    @register_codec("halfmean")
    class HalfMean(GradientCodec):
        name = "halfmean"
        bits_per_element = 16.0

        def decode(self, ctx, u):
            return 0.5 * u

    try:
        grads = _grads(rng)
        plan = AdmissionPlan.lowbit_backbone("halfmean")
        fabric = Fabric()
        # fused path: one bucket on the psum transport, halved mean
        layout = fabric.layout_for(grads, plan)
        assert any(b.key.mode == "halfmean" for b in layout.buckets)
        agg, _ = fabric.aggregate(grads, plan)
        np.testing.assert_allclose(
            np.asarray(agg["backbone"]["w1"]),
            0.5 * np.asarray(grads["backbone"]["w1"]), rtol=1e-6)
        # traffic + sim, by name only
        assert bits_per_element("halfmean") == 16.0
        rep = fabric.simulate(grads, plan, topology="cxl_switched")
        assert any(l.mode == "halfmean" for l in rep.launches)
    finally:
        unregister_codec("halfmean")


def test_custom_vote_codec_without_ef_consistent_across_paths(rng):
    """A vote codec with threads_ef=False: the per-leaf path must apply
    the same EF gate as the fused path — no injection, residuals
    untouched, aggregates identical on both routes."""
    @register_codec("vote_noef")
    class VoteNoEf(GradientCodec):
        name = "vote_noef"
        bits_per_element = 1.0
        reduction = "vote"
        threads_ef = False
        default_schedule = "vote_psum"

    try:
        grads = {"a": jnp.asarray(rng.randn(33, 5), jnp.float32)}
        plan = AdmissionPlan.lowbit_all("vote_noef", error_feedback=True)
        fabric = Fabric()
        ef = {"a": jnp.asarray(rng.randn(1, 33, 5), jnp.float32)}
        a1, e1 = fabric.aggregate(grads, plan, ef=ef, fused=True)
        a2, e2 = fabric.aggregate(grads, plan, ef=ef, fused=False)
        assert _tree_equal(a1, a2)
        # the residual is neither injected (W=1 vote == sign(g), not
        # sign(g + e)) nor updated, on either path
        np.testing.assert_array_equal(np.asarray(a1["a"]),
                                      np.sign(np.asarray(grads["a"])))
        assert _tree_equal(e1, ef) and _tree_equal(e2, ef)
    finally:
        unregister_codec("vote_noef")


def test_custom_leaf_gate_mask_same_zeros_on_both_vote_transports(rng):
    """A gated codec with a custom keep pattern zeroes the same elements
    on vote_psum and packed_a2a, per-leaf and fused (W=4 vmap)."""
    def even_mask(n):
        return (np.arange(n) % 2) == 0

    @register_codec("even_keep")
    class EvenKeep(GradientCodec):
        name = "even_keep"
        bits_per_element = 1.0
        reduction = "vote"
        gated = True
        threads_ef = True
        default_schedule = "vote_psum"

        # bucket_gate deliberately NOT overridden: the base-class
        # default must compose the fused gate from leaf_gate_mask so
        # fused and per-leaf paths zero the same elements
        def leaf_gate_mask(self, shape, gate_phase):
            return even_mask(int(np.prod(shape)))

    try:
        from repro.kernels import ref
        w = 4
        gs = {"g": jnp.asarray(rng.randn(w, 64, 6), jnp.float32)}
        fabric = Fabric(dp_axes=("w",), num_workers=w)
        want = (np.asarray(ref.gbinary_aggregate_dense(
            gs["g"].reshape(w, -1))) * even_mask(64 * 6)).reshape(64, 6)
        for schedule in (Schedule.VOTE_PSUM, Schedule.PACKED_A2A):
            plan = AdmissionPlan.lowbit_all("even_keep", schedule=schedule)
            for fused in (False, True):
                def one(g, plan=plan, fused=fused):
                    agg, _ = fabric.aggregate(g, plan, fused=fused)
                    return agg
                got = jax.vmap(one, axis_name="w")(gs)
                np.testing.assert_array_equal(
                    np.asarray(got["g"][0]), want,
                    err_msg=f"schedule={schedule} fused={fused}")
    finally:
        unregister_codec("even_keep")


def test_layout_cache_invalidated_when_codec_swapped(rng):
    """Swapping a codec under the same name (override/unregister) must
    not serve a stale layout: gate-phase normalization depends on the
    codec's gated flag, exactly like fusability depends on the backend."""
    from repro.core.lowbit import LeafPolicy
    grads = {"a": jnp.asarray(rng.randn(9), jnp.float32),
             "b": jnp.asarray(rng.randn(9), jnp.float32)}
    policies = {
        "a": LeafPolicy("toy_swap_codec", Schedule.VOTE_PSUM, gate_phase=0),
        "b": LeafPolicy("toy_swap_codec", Schedule.VOTE_PSUM, gate_phase=1)}
    fabric = Fabric()

    @register_codec("toy_swap_codec")
    class Ungated(GradientCodec):
        name = "toy_swap_codec"
        bits_per_element = 1.0
        reduction = "vote"
        default_schedule = "vote_psum"

    try:
        # ungated: gate_phase normalizes to 0, both leaves share a bucket
        assert len(fabric.layout_for(grads, policies).buckets) == 1
        unregister_codec("toy_swap_codec")

        @register_codec("toy_swap_codec")
        class Gated(Ungated):
            gated = True

        layout = fabric.layout_for(grads, policies)
        # gated: distinct gate phases must split the bucket (stale cache
        # would still fuse them under one phase-0 gate)
        assert len(layout.buckets) == 2
        assert {b.key.gate_phase for b in layout.buckets} == {0, 1}
    finally:
        unregister_codec("toy_swap_codec")


def test_parameterized_codec_carries_registration_name():
    codec = TopKCodec(0.25, name="top25pct")
    register_codec("top25pct")(codec)
    try:
        from repro.fabric import get_codec
        assert get_codec("top25pct").name == "top25pct"
        assert get_codec("top25pct").bits_per_element == 16.0
    finally:
        unregister_codec("top25pct")


def test_ungated_codec_with_leaf_gate_mask_raises(rng):
    """A codec supplying a keep mask while declaring gated=False is a
    contract violation — it must fail loudly on both paths, never
    silently drop the gate."""
    @register_codec("bad_gate")
    class BadGate(GradientCodec):
        name = "bad_gate"
        bits_per_element = 1.0
        reduction = "vote"
        gated = False               # inconsistent with the mask below
        default_schedule = "vote_psum"

        def leaf_gate_mask(self, shape, gate_phase):
            return np.ones(int(np.prod(shape)), bool)

    try:
        grads = {"a": jnp.asarray(rng.randn(8), jnp.float32)}
        plan = AdmissionPlan.lowbit_all("bad_gate")
        for fused in (True, False):
            with pytest.raises(ValueError, match="gated=False"):
                Fabric().aggregate(grads, plan, fused=fused)
    finally:
        unregister_codec("bad_gate")


def test_topk_codec_sparsifies_and_aggregates(rng):
    g = jnp.asarray(rng.randn(1024), jnp.float32)
    codec = TopKCodec(fraction=1 / 16)
    enc = np.asarray(codec.encode(None, g))
    kept = np.count_nonzero(enc)
    assert 64 <= kept <= 80                      # ties may keep a few extra
    # the kept entries are the largest magnitudes, passed through exactly
    assert np.min(np.abs(enc[enc != 0])) >= np.sort(np.abs(np.asarray(g)))[-80]
    np.testing.assert_array_equal(enc[enc != 0], np.asarray(g)[enc != 0])

    agg, _ = Fabric().aggregate({"backbone": {"w": g}},
                                plan_presets()["topk_backbone"])
    assert 0 < np.count_nonzero(np.asarray(agg["backbone"]["w"])) < g.size


def test_extra_codec_trains_through_build_step(rng):
    """Acceptance: the int4 codec trains through Fabric.build_step —
    resolved purely by plan name, fused by default, loss decreases."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        pytest.skip("installed jax lacks AxisType (needs >= 0.7)")
    from repro.models import ModelConfig, init_params
    from repro.optim import SgdMomentum
    from repro.fabric import TrainState

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = ModelConfig(name="codec_t", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, dtype="float32", remat=False)
    fabric = Fabric(mesh, dp_axes=("data",))
    plan = plan_presets()["int4_backbone"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = SgdMomentum(peak_lr=0.2, total_steps=20)
    step = fabric.build_step(cfg, opt, plan, params)
    assert "int4" in plan.signature()
    assert step.aux["layout"] is not None
    assert any(b.key.mode == "int4" for b in step.aux["layout"].buckets)

    policies = step.aux["policies"]
    state = TrainState(params=params, opt=opt.init(params),
                       ef=fabric.init_ef(params, policies),
                       step=jnp.zeros((), jnp.int32))
    tokens = jnp.asarray(rng.randint(0, 256, size=(8, 33)), jnp.int32)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()
