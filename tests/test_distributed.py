"""Distributed integration tests (subprocess-isolated: forced device count).

Each test runs a small script in a fresh interpreter with
``--xla_force_host_platform_device_count`` so the main pytest process keeps
its single real CPU device.  Covered:

  * aggregation schedules on a real mesh match the dense Section-2 oracle;
  * Trainer end-to-end: convergence + failure injection + deterministic
    restart (losses bitwise-equal with and without a mid-run crash);
  * elastic restart: checkpoint written on a (4,2) mesh restores onto a
    (2,4) mesh (reshard-on-load);
  * the production dry-run entry point succeeds for a full-size cell.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from conftest import needs_modern_jax

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_script(body: str, devices: int = 8, timeout: int = 900) -> str:
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    import sys
    sys.path.insert(0, {SRC!r})
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
@needs_modern_jax
def test_aggregation_schedules_match_dense_oracle():
    out = run_script("""
    import functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding, AxisType
    from repro.core import lowbit_vote_psum, lowbit_packed_a2a, sign_of_mean
    from repro.kernels import ref

    mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
    W = 4
    n = 32 * 128 * 2 + 77           # deliberately unaligned
    rng = np.random.RandomState(0)
    gs = rng.randn(W, n).astype(np.float32)

    def agg(fn):
        @functools.partial(jax.shard_map, mesh=mesh,
                           in_specs=P(("data",)), out_specs=P(),
                           axis_names=frozenset({"data"}), check_vma=False)
        def run(stacked):
            return fn(stacked[0])
        return np.asarray(jax.jit(run)(jnp.asarray(gs)))

    want_bin = np.asarray(ref.gbinary_aggregate_dense(jnp.asarray(gs)))
    got_vote = agg(lambda g: lowbit_vote_psum(g, ("data",), W)[0])
    np.testing.assert_array_equal(got_vote, want_bin)
    got_packed = agg(lambda g: lowbit_packed_a2a(g, ("data",), W)[0])
    np.testing.assert_array_equal(got_packed, want_bin)
    got_ter = agg(lambda g: lowbit_vote_psum(g, ("data",), W, ternary=True)[0])
    want_ter = np.asarray(ref.gternary_aggregate_dense(jnp.asarray(gs)))
    np.testing.assert_array_equal(got_ter, want_ter)
    som = agg(lambda g: sign_of_mean(g, ("data",)))
    np.testing.assert_array_equal(som, np.sign(gs.mean(0)))
    print("SCHEDULES_MATCH")
    """)
    assert "SCHEDULES_MATCH" in out


@pytest.mark.slow
@needs_modern_jax
def test_trainer_failure_recovery_is_deterministic():
    out = run_script("""
    import jax, tempfile, shutil
    from jax.sharding import AxisType
    from repro.models import ModelConfig
    from repro.optim import AdamW
    from repro.core import AdmissionPlan, AggregationMode, Schedule
    from repro.runtime import Trainer, TrainerConfig, FailureInjector
    from repro.data import SyntheticLMStream

    mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", remat=False)
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=16, seed=0)
    opt = AdamW(peak_lr=3e-3, warmup_steps=5, total_steps=100)
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.PACKED_A2A)
    def run(fail):
        ck = tempfile.mkdtemp()
        tr = Trainer(cfg, mesh, opt, data, plan=plan,
                     tcfg=TrainerConfig(dp_axes=("data",),
                                        checkpoint_interval=5,
                                        log_interval=1000),
                     ckpt_dir=ck,
                     failure_injector=FailureInjector(at_steps=[12]) if fail
                     else None)
        h = tr.run(18)
        shutil.rmtree(ck)
        return [x["loss"] for x in h], tr.restarts

    a, r0 = run(False)
    b, r1 = run(True)
    assert r0 == 0 and r1 == 1
    assert a[-1] == b[-1], (a[-1], b[-1])
    assert a[-1] < a[0]
    print("RECOVERY_DETERMINISTIC", a[0], "->", a[-1])
    """)
    assert "RECOVERY_DETERMINISTIC" in out


@pytest.mark.slow
@needs_modern_jax
def test_elastic_restart_across_mesh_shapes():
    out = run_script("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import AxisType
    from repro.models import ModelConfig
    from repro.optim import SgdMomentum
    from repro.core import AdmissionPlan
    from repro.runtime import Trainer, TrainerConfig
    from repro.data import SyntheticLMStream

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", remat=False)
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=16, seed=0)
    opt = SgdMomentum(peak_lr=1e-2)
    ck = tempfile.mkdtemp()

    mesh_a = jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
    tr = Trainer(cfg, mesh_a, opt, data, plan=AdmissionPlan.fp32_all(),
                 tcfg=TrainerConfig(dp_axes=("data",), checkpoint_interval=5,
                                    log_interval=1000), ckpt_dir=ck)
    tr.run(10)
    w_before = np.asarray(tr.state.params["layers"]["attn"]["wq"])

    # "elastic rescale": restart on a different mesh shape
    mesh_b = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
    tr2 = Trainer(cfg, mesh_b, opt, data, plan=AdmissionPlan.fp32_all(),
                  tcfg=TrainerConfig(dp_axes=("data",), checkpoint_interval=5,
                                     log_interval=1000), ckpt_dir=ck)
    tr2.run(10)   # restores step 10 checkpoint; no extra steps needed
    w_after = np.asarray(tr2.state.params["layers"]["attn"]["wq"])
    np.testing.assert_allclose(w_before, w_after, rtol=1e-6)
    assert int(tr2.state.step) == 10
    print("ELASTIC_RESTART_OK")
    """)
    assert "ELASTIC_RESTART_OK" in out


@pytest.mark.slow
@needs_modern_jax
def test_dryrun_entrypoint_full_size_cell(tmp_path):
    """The production dry-run proves (e): lower+compile on the 16x16 mesh."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3_0p6b",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path),
         "--force"],
        capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    data = json.load(open(tmp_path / "pod_16x16" / "qwen3_0p6b"
                          / "decode_32k.decode.json"))
    assert data["num_devices"] == 256
    assert data["flops_per_device"] > 0
    assert data["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_straggler_watchdog():
    from repro.runtime import StragglerWatchdog
    wd = StragglerWatchdog(threshold=2.0, warmup=2)
    flags = [wd.observe(i, d) for i, d in
             enumerate([1.0, 1.0, 1.0, 1.05, 5.0, 1.0])]
    assert flags == [False, False, False, False, True, False]
    assert len(wd.events) == 1 and wd.events[0].step == 4
    # EWMA must not be polluted by the straggler sample
    assert wd.ewma < 1.2


@pytest.mark.slow
@needs_modern_jax
def test_grad_accumulation_equivalence():
    """grad_accum=4 reproduces grad_accum=1 (linear FP32 aggregation)."""
    out = run_script("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.models import ModelConfig
    from repro.optim import AdamW
    from repro.core import AdmissionPlan
    from repro.runtime import Trainer, TrainerConfig
    from repro.runtime.train import build_train_step
    from repro.data import SyntheticLMStream

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", remat=False)
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=16, seed=0)
    opt = AdamW(peak_lr=3e-3, warmup_steps=5, total_steps=50)
    plan = AdmissionPlan.fp32_all()
    losses = {}
    for ga in (1, 4):
        tr = Trainer(cfg, mesh, opt, data, plan=plan,
                     tcfg=TrainerConfig(dp_axes=("data",), log_interval=1000))
        tr.init_state()
        jitted, _, b_sh, _ = build_train_step(
            cfg, mesh, opt, plan, tr.state.params, dp_axes=("data",),
            grad_accum=ga, donate=False)
        st = tr.state
        for step in range(6):
            b = jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), b_sh),
                             data.batch_at(step))
            st, m = jitted(st, b)
        losses[ga] = float(m["loss"])
    assert abs(losses[1] - losses[4]) < 2e-4, losses
    print("GRAD_ACCUM_OK")
    """)
    assert "GRAD_ACCUM_OK" in out
