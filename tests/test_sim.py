"""repro.sim: engine semantics, topology registry, and — the load-bearing
part — agreement with the analytic exposure/traffic models on degenerate
configs plus the paper's operating-point regimes."""
import dataclasses

import jax
import pytest

from repro.core.buckets import (AdmissionPlan, DEFAULT_BUCKET_BYTES,
                                plan_buckets, resolve_policies)
from repro.core.exposure import ExposureModel, TpuDatapathModel
from repro.core.modes import AggregationMode, Schedule
from repro.core.traffic import IciModel, modeled_layout_comm_time
from repro.fabric import Fabric
from repro.sim import (FlitPipeline, LaunchSpec, PAPER_EXPOSED_BOUND_PCT,
                       available_topologies, get_topology,
                       paper_operating_points, register_topology,
                       simulate_launches, simulate_layout,
                       unregister_topology)
from repro.sim.engine import Engine, Resource

REL_TOL = 0.01      # the acceptance bar: sim-vs-analytic within 1%


def _quiet_ici(link_bw: float) -> IciModel:
    """ICI constants with zero latency terms — pure bandwidth path."""
    return IciModel(link_bytes_per_s=link_bw, hop_latency_s=0.0,
                    launch_overhead_s=0.0)


def _params(leaves: int = 6, n: int = 1 << 18):
    return {"backbone": {f"w{i}": jax.ShapeDtypeStruct((n,), "float32")
                         for i in range(leaves)},
            "head": {"w": jax.ShapeDtypeStruct((n, 4), "float32")}}


# ---------------------------------------------------------------------------
# engine primitives
# ---------------------------------------------------------------------------

def test_engine_orders_events_and_resources_fifo():
    eng = Engine()
    order = []
    eng.at(2.0, lambda: order.append("late"))
    eng.at(1.0, lambda: order.append("early"))
    eng.at(1.0, lambda: order.append("early2"))   # tie -> scheduling order
    res = Resource("link", eng)
    grants = []
    res.request(0.0, 3.0, lambda s, e: grants.append((s, e)))
    res.request(1.0, 2.0, lambda s, e: grants.append((s, e)))
    eng.run()
    assert order == ["early", "early2", "late"]
    assert grants == [(0.0, 3.0), (3.0, 5.0)]     # second queued behind first
    assert res.stats.busy_s == 5.0
    assert res.stats.queue_delay_s == 2.0         # 3.0 start vs 1.0 ready


# ---------------------------------------------------------------------------
# topology registry
# ---------------------------------------------------------------------------

def test_builtin_topologies_registered():
    names = available_topologies()
    assert len(names) >= 4
    assert {"cxl_direct", "cxl_switched", "ici_ring",
            "multihop"} <= set(names)


def test_register_unregister_roundtrip():
    @register_topology("test_bus")
    @dataclasses.dataclass(frozen=True)
    class Bus:
        name: str = "test_bus"
        bw: float = 1e9

        def route(self, wire_bytes, num_workers, index=0):
            from repro.sim import Hop, Route
            return Route(hops=(Hop("bus", wire_bytes / self.bw),),
                         latency_s=0.0)

    try:
        assert "test_bus" in available_topologies()
        topo = get_topology("test_bus", bw=2e9)
        assert topo.bw == 2e9
        spec = LaunchSpec("x", AggregationMode.FP32, "psum", 1024, 2e9)
        rep = simulate_launches([spec], 4, topology="test_bus", bw=2e9)
        assert rep.topology == "test_bus"
        assert rep.launches[0].service_s == pytest.approx(1.0)
        with pytest.raises(ValueError):
            register_topology("test_bus")(Bus)     # duplicate name
    finally:
        unregister_topology("test_bus")
    with pytest.raises(KeyError):
        get_topology("test_bus")


def test_multihop_compresses_payload_per_hop():
    topo = get_topology("multihop", hops=3, compression=0.5)
    route = topo.route(1024.0, 8)
    assert [h.bytes for h in route.hops] == [1024.0, 512.0, 256.0]
    assert len({h.link for h in route.hops}) == 3   # distinct stage links


# ---------------------------------------------------------------------------
# sim vs analytic: degenerate single-launch agreement (acceptance bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlap", [1.0, 0.5, 0.0])
@pytest.mark.parametrize("wire_bytes", [1024.0, 3 * (8 << 20) / 8])
def test_degenerate_exposed_matches_exposure_model(overlap, wire_bytes):
    """One launch, no queueing: sim exposed == ExposureModel within 1%."""
    n, w = 8 << 20, 32
    model = ExposureModel(overlap_fraction=overlap)
    ref = model.exposed(n, w, wire_bytes)
    spec = LaunchSpec("b", AggregationMode.G_BINARY, "vote_psum",
                      n, wire_bytes)
    rep = simulate_launches([spec], w, topology="ici_ring",
                            datapath=model.datapath,
                            overlap_fraction=overlap,
                            ici=_quiet_ici(model.link_bw))
    sim_exposed = rep.launches[0].exposed_s
    if ref["t_exposed_s"] == 0.0:
        assert sim_exposed == 0.0 and rep.hidden
    else:
        assert sim_exposed == pytest.approx(ref["t_exposed_s"], rel=REL_TOL)
    assert rep.launches[0].t_agg_s == pytest.approx(ref["t_agg_s"],
                                                    rel=REL_TOL)


@pytest.mark.parametrize("overlap", [1.0, 0.5])
def test_degenerate_exposed_matches_analytic_with_hop_latency(overlap):
    """Nonzero route latency: the sim's hiding window must fold the
    fixed latency in exactly like ExposureModel's extra_service_s, so
    the two models agree off the zero-latency subspace too."""
    n, w, wire_bytes = 8 << 20, 32, 1024.0
    model = ExposureModel(overlap_fraction=overlap)
    ici = IciModel(link_bytes_per_s=model.link_bw)     # default latencies
    latency = 2 * (w - 1) * ici.hop_latency_s + ici.launch_overhead_s
    ref = model.exposed(n, w, wire_bytes, extra_service_s=latency)
    spec = LaunchSpec("b", AggregationMode.G_BINARY, "vote_psum",
                      n, wire_bytes)
    rep = simulate_launches([spec], w, topology="ici_ring",
                            datapath=model.datapath,
                            overlap_fraction=overlap, ici=ici)
    if ref["t_exposed_s"] == 0.0:
        assert rep.launches[0].exposed_s == 0.0
    else:
        assert rep.launches[0].exposed_s == pytest.approx(
            ref["t_exposed_s"], rel=REL_TOL)


def test_zero_hop_route_still_models_the_datapath():
    """A pure-latency route (no serialized hops) must not silently skip
    datapath occupancy — exposure accounting still runs."""
    @register_topology("test_loopback")
    @dataclasses.dataclass(frozen=True)
    class Loopback:
        name: str = "test_loopback"

        def route(self, wire_bytes, num_workers, index=0):
            from repro.sim import Route
            return Route(hops=(), latency_s=5e-6)

    try:
        n, w = 1 << 20, 8
        dp = FlitPipeline()
        rep = simulate_launches(
            [LaunchSpec("x", AggregationMode.G_BINARY, "vote_psum",
                        n, 0.0, ready_s=1e-3)],
            w, topology="test_loopback", datapath=dp)
        l = rep.launches[0]
        assert l.start_s == pytest.approx(1e-3)
        assert l.t_agg_s == pytest.approx(
            dp.t_agg(n, w, AggregationMode.G_BINARY))
        assert l.dp_end_s > l.dp_start_s >= l.start_s
        # nothing to hide behind but the 5us latency
        assert l.exposed_s == pytest.approx(max(0.0, l.t_agg_s - 5e-6))
        assert "datapath" in rep.link_utilization
    finally:
        unregister_topology("test_loopback")


def test_ready_times_length_mismatch_raises():
    fabric = Fabric(num_workers=8)
    params = _params(leaves=2)
    plan = AdmissionPlan.fp32_all()
    launches = fabric.layout_for(params, plan).num_launches
    with pytest.raises(ValueError, match="ready times"):
        fabric.simulate(params, plan, ready_times=[0.0] * (launches + 1))


@pytest.mark.parametrize("num_workers", [2, 8, 32])
def test_degenerate_collective_matches_ici_model(num_workers):
    """One launch, no queueing: ready->delivered == collective_time."""
    n = 4 << 20
    ici = IciModel()
    wire_bytes = 3 * n / 8
    ref = ici.collective_time(wire_bytes, num_workers, num_launches=1)
    spec = LaunchSpec("b", AggregationMode.G_BINARY, "packed_a2a",
                      n, wire_bytes)
    rep = simulate_launches([spec], num_workers, topology="ici_ring",
                            datapath=None, ici=ici)
    assert rep.launches[0].collective_s == pytest.approx(ref, rel=REL_TOL)


def test_layout_sim_bracketed_by_analytic_launch_model():
    """Multi-launch: queueing serializes bandwidth terms but overlaps
    latency terms, so the simulated timeline lands between the pure
    bandwidth sum and the fully-serial analytic per-launch sum."""
    w = 8
    params = _params()
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                    schedule=Schedule.PACKED_A2A)
    policies = resolve_policies(params, plan)
    layout = plan_buckets(params, policies, bucket_bytes=1 << 20)
    assert layout.num_launches > 1
    ici = IciModel()
    analytic_serial = modeled_layout_comm_time(layout, w, ici)
    rep = simulate_layout(layout, w, topology="ici_ring", datapath=None,
                          compute_time_s=0.0, ici=ici)
    bw_sum = sum(l.service_s for l in rep.launches)
    per_launch_latency = rep.launches[0].latency_s
    assert bw_sum + per_launch_latency <= rep.step_time_s
    assert rep.step_time_s <= analytic_serial * (1 + REL_TOL)
    # the shared ring link actually queued the later buckets
    assert any(l.queue_delay_s > 0 for l in rep.launches[1:])
    assert all(0.0 <= u <= 1.0 for u in rep.link_utilization.values())


# ---------------------------------------------------------------------------
# the paper's operating points
# ---------------------------------------------------------------------------

def test_paper_full_miss_regime_exposed_but_bounded():
    rep = paper_operating_points()["full_miss"]
    assert not rep.hidden
    assert 0.0 < rep.exposed_pct <= PAPER_EXPOSED_BOUND_PCT


def test_paper_bandwidth_pressure_fully_hidden():
    rep = paper_operating_points()["bandwidth_pressure"]
    assert rep.hidden
    assert rep.exposed_pct == 0.0
    assert all(l.exposed_s == 0.0 for l in rep.launches)


# ---------------------------------------------------------------------------
# datapath pipeline model
# ---------------------------------------------------------------------------

def test_flit_pipeline_lanes_and_stalls():
    dp = FlitPipeline()
    n, w = 1 << 20, 8
    binary = dp.t_agg(n, w, AggregationMode.G_BINARY)
    ternary = dp.t_agg(n, w, AggregationMode.G_TERNARY)
    fp32 = dp.t_agg(n, w, AggregationMode.FP32)
    assert ternary > binary          # gate fetch stalls the pipeline
    assert fp32 > binary             # 32x the flits on the bypass lane
    # full-miss stalls strictly slow the same launch down
    missy = FlitPipeline(miss_stall_cycles=2.0)
    assert missy.t_agg(n, w, AggregationMode.G_BINARY) > binary
    # flit math: 1 bit/element -> n/512 flits
    assert dp.flits(n, AggregationMode.G_BINARY) == n // 512
    assert dp.flits(n, AggregationMode.FP32) == n * 32 // 512


def test_flit_pipeline_worker_fanin_serializes():
    dp = FlitPipeline(worker_ports=16)
    n = 1 << 20
    assert dp.t_agg(n, 64, AggregationMode.G_BINARY) > \
        dp.t_agg(n, 16, AggregationMode.G_BINARY)


# ---------------------------------------------------------------------------
# Fabric.simulate + report plumbing
# ---------------------------------------------------------------------------

def test_fabric_simulate_reports_layout_timeline():
    fabric = Fabric(num_workers=8)
    params = _params()
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.PACKED_A2A)
    layout = fabric.layout_for(params, plan)
    rep = fabric.simulate(params, plan, topology="cxl_switched",
                          compute_time_s=2e-3)
    assert rep.num_launches == layout.num_launches
    assert rep.step_time_s >= 2e-3
    assert rep.topology == "cxl_switched"
    # per-launch records carry the per-bucket start/end timeline
    for l in rep.launches:
        assert l.end_s >= l.start_s >= 0.0
        assert l.hidden_s == pytest.approx(l.t_agg_s - l.exposed_s)
    # report is JSON-serializable for dryrun / BENCH_sim.json
    import json
    blob = json.dumps(rep.to_jsonable())
    assert "link_utilization" in blob
    summary = rep.summary()
    assert "launches" not in summary and summary["num_launches"] == \
        rep.num_launches


def test_sim_report_feeds_telemetry():
    fabric = Fabric(num_workers=4)
    plan = AdmissionPlan.fp32_all()
    rep = fabric.simulate(_params(leaves=2), plan, topology="ici_ring",
                          compute_time_s=1e-3)
    t = rep.telemetry(step=7, loss=3.25)
    assert t.step == 7 and t.loss == 3.25
    assert t.step_time_s == rep.step_time_s


def test_fabric_simulate_unknown_topology_raises():
    fabric = Fabric(num_workers=4)
    with pytest.raises(KeyError, match="unknown topology"):
        fabric.simulate(_params(leaves=1), AdmissionPlan.fp32_all(),
                        topology="warp_drive")
