"""repro.fabric.control: controller registry, phase programs, persistence.

Covers the registry contract (round-trip, unknown-name error, duplicate
protection), the typed Telemetry record, the PolicyProgram phase machine
(staged user phases, state round-trip), the ``"paper"`` controller's
event sequence ``warmup_end -> admitted -> recovery -> readmitted`` on a
scripted loss curve (including the admission *retry* while calibration
cosines are pending — the old one-shot-window bug), CusumGuard
properties under hypothesis, controller state threading through the
CheckpointManager, a failure-replay regression (restored runs keep the
Supervisor cooldown and the admitted plan instead of resetting to
warm-up), and — on a capable jax — the acceptance path: paper / static /
custom controllers all driving the Trainer through
``fabric.attach_controller``, bit-identical to the legacy static-plan
Trainer.
"""
import json
import math

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import (AdmissionPlan, AggregationMode, Commander,
                        CusumGuard, Schedule, Supervisor)
from repro.fabric import Fabric
from repro.fabric.control import (Controller, FP32Controller,
                                  PaperController, Phase, PolicyProgram,
                                  StaticController, Telemetry,
                                  available_controllers, get_controller,
                                  make_controller, plan_from_jsonable,
                                  plan_presets, plan_to_jsonable,
                                  register_controller,
                                  unregister_controller)
from repro.runtime.fault import FailureInjector, SimulatedFailure

from conftest import needs_modern_jax

COS = {"backbone": {"gbinary": 0.8, "gternary": 0.7},
       "head": {"gbinary": 0.1, "gternary": 0.1}}


def _t(step, loss, cosines=None, **kw):
    return Telemetry(step=step, loss=loss, cosines=cosines, **kw)


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_builtin_controllers_registered():
    names = available_controllers()
    for expected in ("paper", "adaptive", "static", "fp32"):
        assert expected in names
    assert get_controller("adaptive") is get_controller("paper")
    assert isinstance(make_controller("paper", warmup_steps=3),
                      PaperController)
    assert isinstance(make_controller("fp32"), FP32Controller)
    static = make_controller("static", plan="gbin_packed")
    assert static.plan.signature() == plan_presets()["gbin_packed"].signature()


def test_register_controller_roundtrip():
    @register_controller("toy_ctrl")
    class ToyController:
        name = "toy_ctrl"

        def __init__(self, plan=None):
            self.plan = plan or AdmissionPlan.fp32_all()

        def observe(self, telemetry):
            return self.plan

    try:
        c = make_controller("toy_ctrl")
        assert isinstance(c, ToyController)
        assert isinstance(c, Controller)      # protocol satisfied
        assert "toy_ctrl" in available_controllers()
    finally:
        unregister_controller("toy_ctrl")
    assert "toy_ctrl" not in available_controllers()


def test_unknown_controller_raises_clear_error():
    with pytest.raises(KeyError, match="unknown controller 'nope'"):
        get_controller("nope")
    with pytest.raises(KeyError, match="register_controller"):
        make_controller("nope")


def test_duplicate_controller_registration_raises_unless_override():
    with pytest.raises(ValueError, match="already registered"):
        @register_controller("paper")
        class Clash:
            name = "paper"

            def observe(self, telemetry):
                return AdmissionPlan.fp32_all()

    original = get_controller("static")

    @register_controller("static", override=True)
    class Replacement(StaticController):
        pass

    try:
        assert get_controller("static") is Replacement
    finally:
        register_controller("static", override=True)(original)
    assert get_controller("static") is original


def test_unregister_controller_removes_aliases_too():
    @register_controller("toy_main", "toy_alias")
    class Toy:
        name = "toy_main"

        def observe(self, telemetry):
            return AdmissionPlan.fp32_all()

    unregister_controller("toy_main")
    assert "toy_alias" not in available_controllers()
    # the same (name, *aliases) registration is repeatable after teardown
    register_controller("toy_main", "toy_alias")(Toy)
    unregister_controller("toy_alias")       # either key clears both
    assert "toy_main" not in available_controllers()


def test_builtin_controllers_satisfy_protocol():
    assert isinstance(make_controller("paper"), Controller)
    assert isinstance(make_controller("static"), Controller)


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

def test_telemetry_from_metrics_parses_cosine_keys():
    metrics = {"loss": 1.25, "agg_norm": 3.0, "traffic_ratio": 0.25,
               "plan": "sig", "cos/backbone/gbinary": 0.8,
               "cos/backbone/gternary": 0.7, "cos/head/gbinary": 0.1}
    t = Telemetry.from_metrics(7, metrics, step_time_s=0.5, restart=True)
    assert t.step == 7 and t.loss == 1.25 and t.restart
    assert t.traffic_ratio == 0.25 and t.step_time_s == 0.5
    assert t.plan_signature == "sig"
    assert t.cosines == {"backbone": {"gbinary": 0.8, "gternary": 0.7},
                         "head": {"gbinary": 0.1}}
    # no cos/ keys -> cosines is None (calibration window over)
    assert Telemetry.from_metrics(8, {"loss": 1.0}).cosines is None


# ---------------------------------------------------------------------------
# plan (de)serialization
# ---------------------------------------------------------------------------

def test_plan_jsonable_roundtrip_preserves_signature():
    plans = list(plan_presets(error_feedback=True).values())
    plans += [AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                            schedule="my_custom_sched"),
              AdmissionPlan.fp32_all()]
    for plan in plans:
        blob = json.dumps(plan_to_jsonable(plan))          # JSON-safe
        back = plan_from_jsonable(json.loads(blob))
        assert back.signature() == plan.signature()
        assert back == plan


def test_plan_presets_match_launcher_vocabulary():
    presets = plan_presets()
    assert presets["gbin_vote"].signature() == \
        AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                      schedule=Schedule.VOTE_PSUM).signature()
    assert presets["fp32"].signature() == AdmissionPlan.fp32_all().signature()
    ef = plan_presets(error_feedback=True)
    assert ef["gbin_backbone"].policy_for("backbone").error_feedback
    assert not presets["gbin_backbone"].policy_for("backbone").error_feedback


def test_every_preset_json_roundtrip_is_canonical():
    """Every plan_presets entry — including hier_* hop plans and the
    int4/topk extension codecs — resolves its leaf policies, survives
    JSON, and re-resolves to the same canonical codec/schedule names."""
    import jax

    from repro.core.modes import codec_name, schedule_name
    from repro.fabric import get_codec

    sds = jax.ShapeDtypeStruct
    tree = {"wte": sds((512, 64), "float32"),
            "h00": {"qkv": sds((64, 192), "float32"),
                    "ln1_scale": sds((64,), "float32")},
            "head_w": sds((64, 512), "float32")}
    fab = Fabric(num_workers=4)
    for name, plan in plan_presets(error_feedback=True).items():
        # resolves: every leaf policy's codec is registered and its
        # wire schedule has a name
        policies = fab.resolve(tree, plan)
        for pol in jax.tree.leaves(
                policies, is_leaf=lambda x: hasattr(x, "mode")):
            get_codec(pol.mode)
        back = plan_from_jsonable(json.loads(
            json.dumps(plan_to_jsonable(plan))))
        assert back.signature() == plan.signature(), name
        for group in ("backbone", "head", "norms", "embed"):
            a, b = plan.policy_for(group), back.policy_for(group)
            assert codec_name(a.mode) == codec_name(b.mode), (name, group)
            assert schedule_name(a.resolved_schedule()) == \
                schedule_name(b.resolved_schedule()), (name, group)
            assert a.error_feedback == b.error_feedback, (name, group)


def test_register_plan_preset_roundtrip_and_builtin_guard():
    from repro.fabric.control import (register_plan_preset,
                                      unregister_plan_preset)

    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_TERNARY)
    register_plan_preset("my_tuned", plan)
    try:
        assert plan_presets()["my_tuned"].signature() == plan.signature()
        # duplicate registration raises unless override
        with pytest.raises(ValueError, match="already registered"):
            register_plan_preset("my_tuned", AdmissionPlan.fp32_all())
        register_plan_preset("my_tuned", AdmissionPlan.fp32_all(),
                             override=True)
        assert plan_presets()["my_tuned"].signature() == \
            AdmissionPlan.fp32_all().signature()
    finally:
        unregister_plan_preset("my_tuned")
    assert "my_tuned" not in plan_presets()
    # built-ins are never shadowable or removable
    with pytest.raises(ValueError, match="built-in"):
        register_plan_preset("fp32", plan, override=True)
    with pytest.raises(ValueError, match="built-in"):
        unregister_plan_preset("fp32")
    with pytest.raises(KeyError):
        unregister_plan_preset("never_registered")


# ---------------------------------------------------------------------------
# the paper controller's event sequence on a scripted loss curve
# ---------------------------------------------------------------------------

def test_paper_event_sequence_on_scripted_losses():
    c = PaperController(warmup_steps=5,
                        supervisor=Supervisor(
                            guard=CusumGuard(kappa=0.0, h=0.3),
                            cooldown_steps=5))
    fp32_sig = AdmissionPlan.fp32_all().signature()

    # warm-up: FP32, controller keeps asking for diagnostics
    for i in range(4):
        plan = c.observe(_t(i, 1.0 - 0.01 * i))
        assert plan.signature() == fp32_sig
        assert c.wants_diagnostics

    # cosines pending past the warm-up boundary: admission must RETRY,
    # not silently expire (the old one-shot `_step == warmup_steps` bug)
    for i in range(4, 7):
        plan = c.observe(_t(i, 0.95))
        assert plan.signature() == fp32_sig
        assert c.wants_diagnostics, "must keep calibrating until cosines land"
    assert [e.kind for e in c.events] == ["warmup_end"]

    # cosines finally arrive -> admitted
    plan = c.observe(_t(7, 0.9, cosines=COS))
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY
    assert plan.policy_for("head").mode == AggregationMode.FP32
    assert not c.wants_diagnostics

    # sustained loss growth -> CUSUM recovery to FP32
    step = 8
    while c.program.phase != "recovery":
        assert step < 30, "guard never fired"
        c.observe(_t(step, 0.9 + 0.2 * (step - 7)))
        step += 1
    assert c.plan.signature() == fp32_sig
    assert c.supervisor.in_cooldown

    # healthy again -> re-admitted (stored plan; no cosines needed)
    while c.program.phase != "readmitted":
        assert step < 60, "never re-admitted"
        c.observe(_t(step, 0.5))
        step += 1
    assert c.plan.signature() == plan.signature()
    assert [e.kind for e in c.events] == \
        ["warmup_end", "admitted", "recovery", "readmitted"]


def test_paper_warmup_end_and_admission_can_share_a_step():
    """When cosines are already there as warm-up ends, the program chains
    warmup -> calibrate -> admitted on a single observe."""
    c = PaperController(warmup_steps=3, supervisor=Supervisor(
        guard=CusumGuard(h=1e9)))
    for i in range(2):
        c.observe(_t(i, 1.0, cosines=COS))
        assert [e.kind for e in c.events] == []
    plan = c.observe(_t(2, 1.0, cosines=COS))
    assert [e.kind for e in c.events] == ["warmup_end", "admitted"]
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY


def test_supervisor_trigger_during_warmup_does_not_emit_recovery():
    """On the FP32 path already -> nothing to recover (legacy semantics)."""
    c = PaperController(warmup_steps=50, supervisor=Supervisor(
        guard=CusumGuard(kappa=0.0, h=0.01), cooldown_steps=5))
    for i in range(20):
        c.observe(_t(i, 1.0 + 0.5 * i))    # exploding loss during warm-up
    assert [e.kind for e in c.events] == []
    assert c.plan.signature() == AdmissionPlan.fp32_all().signature()


# ---------------------------------------------------------------------------
# CusumGuard properties (hypothesis)
# ---------------------------------------------------------------------------

def test_cusum_nonfinite_loss_always_triggers():
    pytest.importorskip("hypothesis",
                        reason="optional test dependency (pip install .[test])")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(prefix=st.lists(st.floats(0.1, 10.0), max_size=20),
           bad=st.sampled_from([math.nan, math.inf, -math.inf]))
    def check(prefix, bad):
        g = CusumGuard()
        for x in prefix:
            g.update(x)
        assert g.update(bad) is True

    check()


def test_cusum_bounded_noise_never_triggers():
    pytest.importorskip("hypothesis",
                        reason="optional test dependency (pip install .[test])")
    from hypothesis import given, settings, strategies as st

    kappa = 0.05

    @settings(max_examples=60, deadline=None)
    @given(base=st.floats(0.5, 5.0),
           noise=st.lists(st.floats(-kappa / 2, kappa / 2),
                          min_size=1, max_size=200))
    def check(base, noise):
        # |loss - base| <= kappa/2 keeps loss - mu <= kappa: the EWMA mu
        # stays inside the noise band, so the CUSUM statistic never grows
        g = CusumGuard(kappa=kappa, h=0.25)
        assert not any(g.update(base + n) for n in noise)

    check()


def test_cusum_sustained_drift_eventually_triggers():
    pytest.importorskip("hypothesis",
                        reason="optional test dependency (pip install .[test])")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(base=st.floats(0.5, 5.0), rate=st.floats(0.05, 0.5))
    def check(base, rate):
        g = CusumGuard(kappa=0.01, h=0.25)
        assert any(g.update(base + rate * i) for i in range(400)), \
            f"drift {rate}/step never triggered"

    check()


# ---------------------------------------------------------------------------
# PolicyProgram
# ---------------------------------------------------------------------------

def test_policy_program_staged_user_phases():
    """'Head on FP32 after step N' as a declarative program."""
    prog = PolicyProgram.staged([
        ("warmup", ("fp32", "fp32"), 3),
        ("all_lowbit", ("gbinary", "gbinary"), 6),
        ("head_fp32", ("gbinary", "fp32"), None)])
    latched = [prog.advance(_t(i, 1.0)) for i in range(9)]
    assert latched[:3] == [("fp32", "fp32")] * 3
    assert latched[3:6] == [("gbinary", "gbinary")] * 3
    assert latched[6:] == [("gbinary", "fp32")] * 3
    assert [e.kind for e in prog.events] == ["all_lowbit", "head_fp32"]


def test_policy_program_latch_vs_live_plans():
    calls = {"latched": 0, "live": 0}

    def latched_plan(t, p):
        calls["latched"] += 1
        return "L"

    def live_plan(t, p):
        calls["live"] += 1
        return "V"

    prog = PolicyProgram([
        Phase("a", plan=latched_plan,
              transition=lambda t, p: "b" if t.step >= 2 else None),
        Phase("b", plan=live_plan, latch=False),
    ], plan="init")
    # a latched callable on the start phase defers to the first advance
    # (it needs telemetry); until then the constructor fallback holds
    assert prog.plan == "init"
    assert prog.advance(_t(0, 1.0)) == "L"
    for i in range(1, 5):
        prog.advance(_t(i, 1.0))
    # "a" latches exactly once; "b" (live) evaluates on entry at step 2
    # + every subsequent advance
    assert calls == {"latched": 1, "live": 3}
    assert prog.plan == "V"


def test_policy_program_single_phase_latched_callable():
    """Regression: a one-phase program whose only plan is a latched
    callable must evaluate it on the first advance, not return None."""
    prog = PolicyProgram([Phase("go", plan=lambda t, p: ("gbinary", "fp32"))])
    assert prog.advance(_t(0, 1.0)) == ("gbinary", "fp32")
    assert prog.advance(_t(1, 1.0)) == ("gbinary", "fp32")


def test_policy_program_state_roundtrip_with_plan_payload():
    prog = PolicyProgram.staged([
        ("warmup", AdmissionPlan.fp32_all(), 2),
        ("admit", plan_presets()["gbin_packed"], None)])
    for i in range(4):
        prog.advance(_t(i, 1.0))
    blob = json.dumps(prog.state_dict())

    fresh = PolicyProgram.staged([
        ("warmup", AdmissionPlan.fp32_all(), 2),
        ("admit", plan_presets()["gbin_packed"], None)])
    fresh.load_state_dict(json.loads(blob))
    assert fresh.phase == "admit"
    assert fresh.plan.signature() == plan_presets()["gbin_packed"].signature()
    assert [e.kind for e in fresh.events] == ["admit"]

    with pytest.raises(ValueError, match="not in this program"):
        PolicyProgram([Phase("only")]).load_state_dict(json.loads(blob))


def test_run_training_labels_user_program_result():
    """RunResult.policy must name what the program actually latched, not
    the (ignored) policy arguments."""
    from repro.core.experiments import easy_task, run_training
    r = run_training(easy_task(), policy="fp32", steps=4, batch=16,
                     hidden=16,
                     program=PolicyProgram.staged(
                         [("all", ("gternary", "gternary"), None)]))
    assert r.policy == "gternary+gternaryhead"


def test_policy_program_rejects_bad_shapes():
    with pytest.raises(ValueError, match="at least one phase"):
        PolicyProgram([])
    with pytest.raises(ValueError, match="duplicate phase"):
        PolicyProgram([Phase("a"), Phase("a")])
    with pytest.raises(KeyError, match="unknown phase"):
        PolicyProgram([Phase("a")]).enter("nope")
    # a callable plan cannot be computed without telemetry — clear error
    # instead of an AttributeError deep inside the plan function
    c = PaperController(warmup_steps=2)
    with pytest.raises(ValueError, match="requires telemetry"):
        c.program.enter("admitted")
    c.program.enter("recovery")              # static plan: fine without
    assert c.program.events[-1].kind == "recovery"


# ---------------------------------------------------------------------------
# controller persistence: state_dict / CheckpointManager threading
# ---------------------------------------------------------------------------

def _drive_to_mid_cooldown(c, cooldown=20):
    """Warm-up, admit, trigger recovery, then burn a few cooldown steps."""
    step = 0
    for _ in range(2):
        c.observe(_t(step, 1.0, cosines=COS))
        step += 1
    assert c.program.phase == "admitted"
    while c.program.phase != "recovery":
        c.observe(_t(step, 1.0 + 0.5 * step))
        step += 1
    for _ in range(3):                      # partially spend the cooldown
        c.observe(_t(step, 0.5))
        step += 1
    assert c.supervisor.in_cooldown
    return step


def _paper(cooldown=20):
    return PaperController(
        warmup_steps=2,
        commander=Commander(tau_binary=-1.0),
        supervisor=Supervisor(guard=CusumGuard(kappa=0.0, h=0.3),
                              cooldown_steps=cooldown))


def test_paper_state_dict_roundtrip_mid_cooldown():
    c = _paper()
    step = _drive_to_mid_cooldown(c)
    blob = json.dumps(c.state_dict())          # must be JSON-serializable

    fresh = _paper()
    fresh.warmup_steps = 99                 # restart with a different knob
    fresh.load_state_dict(json.loads(blob))
    assert fresh.warmup_steps == c.warmup_steps, \
        "the checkpointed calibration window must win over the constructor"
    assert fresh.program.phase == "recovery"
    assert fresh.supervisor.in_cooldown
    assert fresh.supervisor._cooldown_left == c.supervisor._cooldown_left
    assert fresh._admitted_plan.signature() == c._admitted_plan.signature()
    assert [e.kind for e in fresh.events] == [e.kind for e in c.events]

    # the restored twin re-admits in lockstep with the original
    for twin in (c, fresh):
        while twin.program.phase != "readmitted":
            twin.observe(_t(step, 0.5))
    assert c.events[-1].kind == fresh.events[-1].kind == "readmitted"
    assert c.plan.signature() == fresh.plan.signature()


def test_checkpoint_manager_threads_controller_state(tmp_path):
    import jax.numpy as jnp
    tree = {"w": jnp.zeros((4,))}
    c = _paper()
    _drive_to_mid_cooldown(c)

    m = CheckpointManager(str(tmp_path), interval=1, keep=2)
    m.maybe_save(5, tree, extra={"plan": c.plan.signature()}, controller=c)
    m.wait()

    fresh = _paper()
    step, _, extra = m.restore(tree, controller=fresh)
    assert step == 5 and "controller" in extra
    assert fresh.program.phase == "recovery"
    assert fresh.supervisor.in_cooldown
    assert fresh._admitted_plan.signature() == c._admitted_plan.signature()

    # controller-free callers are untouched by the threading
    m2 = CheckpointManager(str(tmp_path), interval=1)
    assert m2.restore(tree)[0] == 5

    # resuming under a DIFFERENT controller kind must not feed it a
    # foreign state dict (warn + keep the fresh controller)
    other = StaticController(plan_presets()["gbin_vote"])
    m.restore(tree, controller=other)
    assert other.plan.signature() == plan_presets()["gbin_vote"].signature()


def test_failure_replay_keeps_cooldown_and_admitted_plan(tmp_path):
    """Regression for tentpole item 4, mesh-free: a SimulatedFailure lands
    mid-cooldown; the restarted control loop (fresh controller restored
    from the checkpoint, Trainer `_recover` style) must keep the
    Supervisor cooldown and the admitted plan instead of resetting the
    control plane to warm-up."""
    cooldown = 12
    losses = ([1.0, 1.0]                     # warm-up (admits at step 1)
              + [1.0 + 0.5 * i for i in range(6)]   # drift -> recovery
              + [0.5] * 30)                  # healthy tail
    injector = FailureInjector(at_steps=(9,))
    ckpt = CheckpointManager(str(tmp_path), interval=1, keep=3,
                             async_save=False)
    import jax.numpy as jnp
    tree = {"w": jnp.zeros(())}              # stand-in model state

    c = _paper(cooldown=cooldown)
    step, restarts = 0, 0
    while step < 28:
        try:
            injector.check(step)
        except SimulatedFailure:
            restarts += 1
            c = _paper(cooldown=cooldown)    # process restart: fresh plane
            restored = ckpt.restore(tree, controller=c)
            step = restored[0]
            assert c.program.phase == "recovery", \
                "restore must land back mid-recovery, not in warm-up"
            assert c.supervisor.in_cooldown, "cooldown must survive restore"
            continue
        cos = COS if c.wants_diagnostics else None
        c.observe(_t(step, losses[step], cosines=cos))
        ckpt.maybe_save(step + 1, tree, controller=c)
        step += 1

    assert restarts == 1
    kinds = [e.kind for e in c.events]
    # one admission, one recovery, one re-admission: the restart neither
    # replayed warm-up nor re-fired admission
    assert kinds == ["warmup_end", "admitted", "recovery", "readmitted"]
    assert c.plan.signature() == c._admitted_plan.signature()
    readmit_step = c.events[-1].step
    recovery_step = c.events[-2].step
    assert readmit_step - recovery_step >= cooldown, \
        "re-admission must wait out the full (restored) cooldown"


# ---------------------------------------------------------------------------
# Fabric.attach_controller surface (mesh-free checks)
# ---------------------------------------------------------------------------

def test_attach_controller_by_name_and_instance():
    fabric = Fabric()
    c = fabric.attach_controller("paper", warmup_steps=7)
    assert fabric.controller is c and c.warmup_steps == 7

    fabric2 = Fabric()
    mine = StaticController(plan_presets()["gbin_vote"])
    assert fabric2.attach_controller(mine) is mine
    with pytest.raises(TypeError, match="registered name"):
        Fabric().attach_controller(mine, warmup_steps=3)


# ---------------------------------------------------------------------------
# full-stack acceptance: all controllers drive the Trainer through the
# same attach_controller path (jax >= 0.7 runtime required)
# ---------------------------------------------------------------------------

def _trainer_bits():
    import jax
    from jax.sharding import AxisType
    from repro.data import SyntheticLMStream
    from repro.models import ModelConfig
    from repro.optim import SgdMomentum
    from repro.runtime import Trainer, TrainerConfig

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = ModelConfig(name="ctl", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", remat=False)
    return mesh, cfg, SyntheticLMStream, SgdMomentum, Trainer, TrainerConfig


@needs_modern_jax
def test_static_controller_bit_identical_to_legacy_plan_path():
    mesh, cfg, Stream, Sgd, Trainer, TrainerConfig = _trainer_bits()
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule=Schedule.PACKED_A2A)

    h_legacy = Trainer(cfg, mesh, Sgd(peak_lr=0.2, total_steps=60),
                       Stream(vocab=256, seq_len=32, batch=8, seed=0),
                       plan=plan,
                       tcfg=TrainerConfig(dp_axes=("data",),
                                          log_interval=1000)).run(6)

    fabric = Fabric(mesh, ("data",))
    fabric.attach_controller("static", plan=plan)
    h_ctrl = Trainer(cfg, mesh, Sgd(peak_lr=0.2, total_steps=60),
                     Stream(vocab=256, seq_len=32, batch=8, seed=0),
                     fabric=fabric).run(6)

    assert [h["plan"] for h in h_ctrl] == [h["plan"] for h in h_legacy]
    np.testing.assert_array_equal(
        np.asarray([h["loss"] for h in h_ctrl]),
        np.asarray([h["loss"] for h in h_legacy]))
    np.testing.assert_array_equal(
        np.asarray([h["agg_norm"] for h in h_ctrl]),
        np.asarray([h["agg_norm"] for h in h_legacy]))


@needs_modern_jax
def test_custom_registered_controller_drives_trainer():
    """A test-registered controller flips the plan mid-run, selected
    purely by name through attach_controller — no core edits."""
    mesh, cfg, Stream, Sgd, Trainer, TrainerConfig = _trainer_bits()

    @register_controller("toy_flip")
    class FlipController:
        name = "toy_flip"
        wants_diagnostics = False

        def __init__(self, at=3):
            self.at = at
            self.plan = AdmissionPlan.fp32_all()

        def observe(self, telemetry):
            if telemetry.step + 1 >= self.at:
                self.plan = AdmissionPlan.lowbit_backbone(
                    AggregationMode.G_BINARY)
            return self.plan

    try:
        fabric = Fabric(mesh, ("data",))
        fabric.attach_controller("toy_flip", at=3)
        tr = Trainer(cfg, mesh, Sgd(peak_lr=0.1, total_steps=40),
                     Stream(vocab=256, seq_len=32, batch=8, seed=2),
                     fabric=fabric,
                     tcfg=TrainerConfig(dp_axes=("data",),
                                        log_interval=1000))
        hist = tr.run(6)
        plans = [h["plan"] for h in hist]
        assert "gbinary" not in plans[0]
        assert all("gbinary" in p for p in plans[3:])
        assert len(fabric._compiled) == 2      # one jit per plan signature
    finally:
        unregister_controller("toy_flip")


@needs_modern_jax
def test_trainer_controller_state_survives_failure_injector(tmp_path):
    """Satellite regression: SimulatedFailure mid-cooldown; the restored
    run must keep the Supervisor cooldown and the admitted plan."""
    mesh, cfg, Stream, Sgd, Trainer, TrainerConfig = _trainer_bits()

    class ScriptedSupervisor(Supervisor):
        """Deterministic guard: trigger at the Nth observe (telemetry is
        real training loss, which is not scriptable)."""

        def __init__(self, trigger_at, cooldown_steps):
            super().__init__(guard=CusumGuard(h=1e9),
                             cooldown_steps=cooldown_steps)
            self.trigger_at = int(trigger_at)
            self._n = 0

        def observe(self, loss):
            self._n += 1
            if self._cooldown_left > 0:
                self._cooldown_left -= 1
                return False
            if self._n == self.trigger_at:
                self._cooldown_left = self.cooldown_steps
                return True
            return False

        def state_dict(self):
            return dict(super().state_dict(), n=self._n)

        def load_state_dict(self, state):
            super().load_state_dict(state)
            self._n = int(state["n"])

    def controller():
        return PaperController(
            warmup_steps=2, commander=Commander(tau_binary=-1.0),
            supervisor=ScriptedSupervisor(trigger_at=5, cooldown_steps=8))

    def trainer(ctrl, injector=None):
        fabric = Fabric(mesh, ("data",))
        fabric.attach_controller(ctrl)
        return Trainer(cfg, mesh, Sgd(peak_lr=0.05, total_steps=100),
                       Stream(vocab=256, seq_len=32, batch=8, seed=3),
                       fabric=fabric, ckpt_dir=str(tmp_path),
                       failure_injector=injector,
                       tcfg=TrainerConfig(dp_axes=("data",),
                                          checkpoint_interval=1,
                                          log_interval=1000))

    # in-process restart path (Trainer._recover): failure at step 7, two
    # steps into the 8-step cooldown that started at step 4
    c1 = controller()
    tr = trainer(c1, injector=FailureInjector(at_steps=(7,)))
    tr.run(16)
    assert tr.restarts == 1
    kinds = [e.kind for e in c1.events]
    assert kinds == ["warmup_end", "admitted", "recovery", "readmitted"], \
        f"restart corrupted the control sequence: {kinds}"

    # process-restart path: a FRESH controller + Trainer on the same
    # checkpoint dir resumes mid-stream instead of re-warming up
    c2 = controller()
    tr2 = trainer(c2)
    tr2.run(20)
    kinds2 = [e.kind for e in c2.events]
    assert kinds2 == ["warmup_end", "admitted", "recovery", "readmitted"]
    # restored log, not re-fired: admission predates the checkpoint
    assert c2.events[1].step < 16 and c2.events[1].step == c1.events[1].step
    assert c2.plan.signature() == c1.plan.signature()
    assert "gbinary" in c2.plan.signature()
