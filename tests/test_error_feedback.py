"""Error-feedback (EF-signSGD) path: residual math + end-to-end benefit.

Beyond-paper option (DESIGN.md): votes taken on g + e with residual
e' = x - mean|x| * sign(x).  Properties: the residual shrinks what the
compressor discarded, and EF strictly reduces long-run compression error
on a fixed gradient (classic EF contraction).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowbit import _ef_inject, _ef_update, lowbit_vote_psum
from repro.core import aggregate_gradients, init_ef_states, resolve_policies
from repro.core import AdmissionPlan, AggregationMode, GroupPolicy


def test_residual_update_formula(rng):
    g = jnp.asarray(rng.randn(1024), jnp.float32)
    ef = jnp.zeros_like(g)
    g_eff, ef_in = _ef_inject(g, ef)
    np.testing.assert_array_equal(np.asarray(g_eff), np.asarray(g))
    new_ef = _ef_update(g_eff, ef_in)
    beta = float(jnp.mean(jnp.abs(g)))
    want = np.asarray(g) - beta * np.sign(np.asarray(g))
    np.testing.assert_allclose(np.asarray(new_ef), want, rtol=1e-6)


def test_ef_accumulates_what_compression_discards(rng):
    """On a constant gradient, sum of sent signals converges toward g."""
    g = jnp.asarray(rng.randn(4096) * 0.5, jnp.float32)
    ef = jnp.zeros_like(g)
    sent_total = np.zeros(4096, np.float32)
    for _ in range(50):
        x = g + ef
        beta = jnp.mean(jnp.abs(x))
        sent = beta * jnp.sign(x)
        sent_total += np.asarray(sent)
        ef = x - sent
    avg_sent = sent_total / 50
    err = np.linalg.norm(avg_sent - np.asarray(g)) / np.linalg.norm(np.asarray(g))
    assert err < 0.15, err     # EF closes most of the compression error


def test_ef_states_threaded_through_aggregation(rng):
    """aggregate_gradients round-trips EF sentinels and residuals."""
    params = {"backbone": {"w": jnp.zeros((64, 64))},
              "head": {"w": jnp.zeros((64, 8))}}
    plan = AdmissionPlan.from_dict(
        {"backbone": GroupPolicy(AggregationMode.G_BINARY,
                                 error_feedback=True)},
        default=GroupPolicy(AggregationMode.FP32))
    policies = resolve_policies(params, plan)
    ef = init_ef_states(params, policies)
    assert ef["backbone"]["w"].shape == (1, 64, 64)   # enabled: (W,*shape)
    assert ef["head"]["w"].shape == ()                # sentinel

    grads = jax.tree.map(lambda p: jnp.asarray(
        rng.randn(*p.shape), jnp.float32), params)
    agg, new_ef = aggregate_gradients(grads, policies, (), 1, ef_states=ef)
    # W=1: aggregate is sign(g); residual is g - mean|g|*sign(g)
    np.testing.assert_array_equal(np.asarray(agg["backbone"]["w"]),
                                  np.sign(np.asarray(grads["backbone"]["w"])))
    assert new_ef["backbone"]["w"].shape == (1, 64, 64)
    assert float(jnp.sum(jnp.abs(new_ef["backbone"]["w"]))) > 0
    assert new_ef["head"]["w"].shape == ()            # sentinel untouched
    np.testing.assert_allclose(np.asarray(agg["head"]["w"]),
                               np.asarray(grads["head"]["w"]), rtol=1e-6)
