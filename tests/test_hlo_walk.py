"""HLO cost-walk unit tests: loop trip counts, dot flops, ring wire model."""
import textwrap

from repro.launch.hlo_analysis import _wire_bytes
from repro.launch.hlo_walk import parse_module, walk

SAMPLE = textwrap.dedent("""
%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7), metadata={op_name="trip"}
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %a = f32[8,16]{1,0} parameter(1)
  %b = f32[16,4]{1,0} parameter(2)
  %d = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,4]{1,0} all-reduce(%d), channel_id=1, replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %t = (s32[]) tuple(%p)
}

ENTRY %main (x: f32[8,16]) -> f32[8,4] {
  %x = f32[8,16]{1,0} parameter(0)
  %init = (s32[]) tuple(%x)
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %y = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[8,4]{1,0} tuple(%w)
}
""")


def test_parse_and_trip_count():
    comps = parse_module(SAMPLE)
    assert {"cond", "body", "main"} <= set(comps)
    assert comps["main"].is_entry
    wk = walk(SAMPLE)
    assert wk["loops"] == {"body": 7}


def test_flops_scaled_by_trip_count():
    wk = walk(SAMPLE)
    # body dot: 2*8*4*16 = 1024 flops x 7 trips; entry dot: 2*128*128*16
    body_dot = 2 * 8 * 4 * 16 * 7
    entry_dot = 2 * 128 * 128 * 16
    assert abs(wk["flops"] - (body_dot + entry_dot)) < 1e-6


def test_collectives_scaled_by_trip_count():
    wk = walk(SAMPLE)
    # all-reduce payload 8*4*4 bytes, ring over group of 4: 2*(3/4)*128
    assert abs(wk["wire_bytes"] - 7 * 2 * (3 / 4) * 128) < 1e-6
    assert "all-reduce/f32/g4" in wk["wire_breakdown"]


def test_ring_wire_model():
    assert _wire_bytes("all-reduce", 100, 4) == 2 * 0.75 * 100
    assert _wire_bytes("all-gather", 100, 4) == 0.75 * 100
    assert _wire_bytes("reduce-scatter", 25, 4) == 75
    assert _wire_bytes("all-to-all", 100, 4) == 75
    assert _wire_bytes("collective-permute", 100, 2) == 100
    assert _wire_bytes("all-reduce", 100, 1) == 0
