"""The generic Registry helper and its behavior at every extension seam.

PR 5 fixed override/unregister alias sweeping for codecs and schedules
by hand; unifying the four hand-rolled registries (plus the new serve
policy seam) onto :class:`repro.core.registry.Registry` delivers that
fix everywhere.  These tests pin the sweep semantics on the two seams
that previously lacked it — controllers and topologies — plus the
generic class itself.
"""
import pytest

from repro.core.registry import Registry


# ---------------------------------------------------------------------------
# the generic class
# ---------------------------------------------------------------------------

def test_registry_duplicate_and_override_sweep():
    reg = Registry("widget")

    @reg.register("a", "a_alias")
    class A:
        pass

    with pytest.raises(ValueError, match="widget 'a' already registered"):
        reg.register("a")(object())

    # overriding the primary name must drop the stale alias of the
    # replaced object — 'a_alias' must never resolve the old entry
    @reg.register("a", override=True)
    class B:
        pass

    assert reg.get("a") is B
    assert "a_alias" not in reg
    assert reg.available() == ("a",)


def test_registry_unregister_sweeps_aliases():
    reg = Registry("widget")
    reg.register("x", "y", "z")(object())
    assert len(reg) == 3
    reg.unregister("y")                    # any key clears all three
    assert len(reg) == 0
    reg.unregister("x")                    # idempotent on absent keys


def test_registry_unknown_key_message_with_and_without_hint():
    plain = Registry("thing")
    with pytest.raises(KeyError, match=r"unknown thing 'nope'; available:"):
        plain.get("nope")
    hinted = Registry("thing", register_hint="@register_thing({key!r})")
    with pytest.raises(KeyError,
                       match=r"Register one with @register_thing\('nope'\)"):
        hinted.get("nope")


def test_registry_half_registration_never_happens():
    reg = Registry("widget")
    reg.register("taken")(object())
    with pytest.raises(ValueError):
        reg.register("fresh", "taken")(object())   # alias clashes
    assert "fresh" not in reg                      # nothing inserted


# ---------------------------------------------------------------------------
# the sweep fix reaching the controller seam
# ---------------------------------------------------------------------------

def test_controller_override_sweeps_stale_aliases():
    from repro.fabric.control import (available_controllers, get_controller,
                                      register_controller,
                                      unregister_controller)

    @register_controller("swp_main", "swp_alias")
    def first(**kw):
        return "first"

    try:
        @register_controller("swp_main", override=True)
        def second(**kw):
            return "second"

        assert get_controller("swp_main") is second
        assert "swp_alias" not in available_controllers()
        with pytest.raises(KeyError, match="unknown controller 'swp_alias'"):
            get_controller("swp_alias")
    finally:
        unregister_controller("swp_main")
    assert "swp_main" not in available_controllers()


# ---------------------------------------------------------------------------
# ... and the topology seam (which also gains aliases)
# ---------------------------------------------------------------------------

def test_topology_aliases_and_override_sweep():
    from repro.sim import (available_topologies, get_topology,
                           register_topology, unregister_topology)

    class Direct:
        name = "swp_topo"

        def route(self, wire_bytes, num_workers, index=0):
            from repro.sim import Route
            return Route(hops=(), latency_s=1e-6)

    register_topology("swp_topo", "swp_topo_alias")(lambda **kw: Direct())
    try:
        assert "swp_topo_alias" in available_topologies()
        assert get_topology("swp_topo_alias").name == "swp_topo"

        register_topology("swp_topo", override=True)(lambda **kw: Direct())
        assert "swp_topo_alias" not in available_topologies()
        with pytest.raises(KeyError, match="unknown topology 'swp_topo_alias'"):
            get_topology("swp_topo_alias")
    finally:
        unregister_topology("swp_topo")
    assert "swp_topo" not in available_topologies()


def test_serve_policy_rides_the_same_seam():
    from repro.serve import (available_policies, get_policy,
                             register_policy, unregister_policy)

    @register_policy("swp_pol", "swp_pol_alias")
    class Pol:
        name = "swp_pol"

        def admission_order(self, waiting):
            return list(waiting)

        def preemption_victim(self, running):
            return running[-1]

    try:
        assert get_policy("swp_pol_alias") is get_policy("swp_pol")
        with pytest.raises(ValueError, match="already registered"):
            register_policy("swp_pol")(Pol)
    finally:
        unregister_policy("swp_pol")
    assert "swp_pol_alias" not in available_policies()
