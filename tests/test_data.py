"""Data pipeline: determinism, host sharding, learnability, prefetch."""
import numpy as np

from repro.data import (MarkovLM, Prefetcher, SyntheticLMStream,
                        make_cluster_task)


def test_stream_deterministic_per_step():
    a = SyntheticLMStream(vocab=64, seq_len=16, batch=4, seed=3)
    b = SyntheticLMStream(vocab=64, seq_len=16, batch=4, seed=3)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              a.batch_at(1)["tokens"])


def test_stream_host_sharding_differs():
    a = SyntheticLMStream(vocab=64, seq_len=16, batch=4, seed=3, host_index=0)
    b = SyntheticLMStream(vocab=64, seq_len=16, batch=4, seed=3, host_index=1)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_labels_are_shifted_tokens():
    s = SyntheticLMStream(vocab=64, seq_len=16, batch=4, seed=0)
    b = s.batch_at(0)
    # contract: labels[t] is the next token after tokens[t]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_chain_is_learnable():
    """Chain transitions are low-entropy: bigram statistics are skewed."""
    chain = MarkovLM(vocab=32, seed=0, topk=4)
    rng = np.random.RandomState(0)
    toks = chain.sample(rng, 64, 128)
    # successor sets are restricted to topk per token
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 4


def test_prefetcher_yields_everything():
    it = iter([{"x": i} for i in range(7)])
    out = list(Prefetcher(it, depth=2))
    assert [o["x"] for o in out] == list(range(7))


def test_cluster_task_difficulty_knob():
    easy = make_cluster_task(10, hard=False, seed=0)
    hard = make_cluster_task(100, hard=True, seed=0)
    # easy clusters are farther apart relative to noise than hard ones
    def margin(task):
        c = task.centers
        d = np.linalg.norm(c[:, None] - c[None], axis=-1)
        np.fill_diagonal(d, np.inf)
        return d.min() / task.noise
    assert margin(easy) > margin(hard)
