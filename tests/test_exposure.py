"""Direct unit tests for the analytic models the simulator validates
against: ExposureModel.exposed, the envelope_sweep panel invariants, the
layout communication-time model, and the IciModel constants."""
import jax
import pytest

from repro.core.buckets import (AdmissionPlan, plan_buckets,
                                resolve_policies)
from repro.core.exposure import (ExposureModel, TpuDatapathModel,
                                 envelope_sweep)
from repro.core.modes import AggregationMode, Schedule
from repro.core.traffic import (IciModel, modeled_comm_time,
                                modeled_layout_comm_time,
                                wire_bytes_per_device)


# ---------------------------------------------------------------------------
# ExposureModel.exposed
# ---------------------------------------------------------------------------

def test_exposed_is_agg_minus_overlapped_service():
    m = ExposureModel(overlap_fraction=0.5)
    n, w, wb = 1 << 20, 16, 4096.0
    r = m.exposed(n, w, wb)
    t_agg = m.datapath.t_agg(n, w)
    t_srv = wb / m.link_bw
    assert r["t_agg_s"] == pytest.approx(t_agg)
    assert r["t_service_s"] == pytest.approx(t_srv)
    assert r["t_exposed_s"] == pytest.approx(max(0.0, t_agg - 0.5 * t_srv))
    assert not r["hidden"]


def test_exposed_zero_service_has_no_div_by_zero():
    m = ExposureModel()
    r = m.exposed(1 << 20, 16, wire_bytes_per_device=0.0)
    assert r["t_service_s"] == 0.0
    assert r["t_exposed_s"] == pytest.approx(r["t_agg_s"])
    assert r["exposed_pct"] == pytest.approx(100.0)   # base falls back to t_agg


def test_exposed_extra_service_extends_hiding_window():
    m = ExposureModel(overlap_fraction=0.5)
    n, w, wb = 8 << 20, 32, 1024.0
    base = m.exposed(n, w, wb)
    more = m.exposed(n, w, wb, extra_service_s=1e-3)
    assert more["t_service_s"] == pytest.approx(base["t_service_s"] + 1e-3)
    # the extra latency hides only overlap_fraction of itself
    assert more["t_exposed_s"] == pytest.approx(
        max(0.0, base["t_exposed_s"] - 0.5 * 1e-3))


# ---------------------------------------------------------------------------
# envelope_sweep panel invariants
# ---------------------------------------------------------------------------

def test_panel_b_routes_through_the_model():
    """Panel (b) rows must be exactly ExposureModel.exposed with the hop
    latency folded into the service path — the old hand-patched dict
    ignored overlap_fraction and divided by an unguarded t_service_s."""
    n, w = 8 << 20, 32
    wb = 3 * n / 8
    rows = envelope_sweep(n_elements=n, num_workers=w,
                          wire_bytes_per_device=wb)
    m = ExposureModel()
    for row in rows["b"]:
        extra = 2 * (w - 1) * row["hop_us"] * 1e-6
        ref = m.exposed(n, w, wb, extra_service_s=extra)
        for k in ("t_agg_s", "t_service_s", "t_exposed_s", "exposed_pct",
                  "hidden"):
            assert row[k] == pytest.approx(ref[k]), (row["hop_us"], k)


def test_panel_b_monotone_in_hop_latency():
    rows = envelope_sweep()["b"]
    exposed = [r["t_exposed_s"] for r in rows]
    service = [r["t_service_s"] for r in rows]
    assert service == sorted(service)
    assert exposed == sorted(exposed, reverse=True)
    assert all(r["exposed_pct"] >= 0.0 for r in rows)


def test_panel_a_reports_link_GBps():
    rows = envelope_sweep()["a"]
    assert all("link_GBps" in r and "link_gbps" not in r for r in rows)


# ---------------------------------------------------------------------------
# layout communication-time model
# ---------------------------------------------------------------------------

def _tree(leaves=5, n=1 << 16):
    return {f"w{i}": jax.ShapeDtypeStruct((n,), "float32")
            for i in range(leaves)}


def test_layout_comm_time_per_leaf_degenerate_equals_leaf_sum():
    """bucket_bytes=1 gives one launch per leaf, so the layout model must
    equal summing modeled_comm_time over the leaves."""
    w = 8
    params = _tree()
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                    schedule=Schedule.PACKED_A2A)
    policies = resolve_policies(params, plan)
    per_leaf = plan_buckets(params, policies, bucket_bytes=1)
    assert per_leaf.num_launches == len(params)
    ici = IciModel()
    ref = sum(modeled_comm_time(1 << 16, AggregationMode.G_BINARY,
                                Schedule.PACKED_A2A, w, ici)
              for _ in range(len(params)))
    assert modeled_layout_comm_time(per_leaf, w, ici) == pytest.approx(ref)


def test_layout_comm_time_fusion_strictly_wins():
    w = 8
    params = _tree(leaves=16)
    plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                    schedule=Schedule.PACKED_A2A)
    policies = resolve_policies(params, plan)
    per_leaf = plan_buckets(params, policies, bucket_bytes=1)
    fused = plan_buckets(params, policies)
    assert fused.num_launches < per_leaf.num_launches
    assert modeled_layout_comm_time(fused, w) < \
        modeled_layout_comm_time(per_leaf, w)


# ---------------------------------------------------------------------------
# IciModel bandwidth field
# ---------------------------------------------------------------------------

def test_ici_link_bytes_per_s_is_canonical():
    m = IciModel(link_bytes_per_s=25e9)
    assert m.link_bytes_per_s == 25e9
    assert m.collective_time(25e9, 2, num_launches=0) == pytest.approx(1.0)


def test_ici_link_gbps_removed():
    # the PR-4 rename shim is gone: the misleading old name must not
    # silently construct a different model
    with pytest.raises(TypeError):
        IciModel(link_gbps=25e9)
    assert not hasattr(IciModel(), "link_gbps")
