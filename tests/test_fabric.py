"""Fabric session + schedule-backend registry.

Covers the registry contract (round-trip, unknown-name error, duplicate
protection), bit-for-bit equivalence of ``Fabric.aggregate`` with the
legacy ``aggregate_gradients`` free function on a mixed plan, EF spec
construction, wire-byte accounting through backends, and — the extension
seam the registry exists for — training with a custom schedule that was
registered without modifying any core file.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, AggregationMode, GroupPolicy,
                        Schedule, aggregate_gradients, init_ef_states,
                        resolve_policies, wire_bytes_per_device)
from repro.fabric import (AggregationContext, Fabric, ScheduleBackend,
                          available_schedules, get_schedule,
                          register_schedule, unregister_schedule)

from conftest import needs_modern_jax

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_builtin_schedules_registered():
    names = available_schedules()
    for expected in ("psum", "fp32", "vote_psum", "packed_a2a",
                     "majority_sign_sgd", "sign_of_mean"):
        assert expected in names
    # enum and string keys resolve to the same backend
    assert get_schedule(Schedule.VOTE_PSUM) is get_schedule("vote_psum")
    assert get_schedule("fp32") is get_schedule(Schedule.PSUM)
    assert isinstance(get_schedule("packed_a2a"), ScheduleBackend)


def test_register_schedule_roundtrip():
    @register_schedule("toy_roundtrip")
    class ToyBackend:
        name = "toy_roundtrip"

        def aggregate(self, ctx, g, policy, ef=None):
            return g, ef

    try:
        backend = get_schedule("toy_roundtrip")
        assert isinstance(backend, ToyBackend)
        assert "toy_roundtrip" in available_schedules()
    finally:
        unregister_schedule("toy_roundtrip")
    assert "toy_roundtrip" not in available_schedules()


def test_unknown_schedule_raises_clear_error():
    with pytest.raises(KeyError, match="unknown schedule backend 'nope'"):
        get_schedule("nope")
    # the error names the registration hook
    with pytest.raises(KeyError, match="register_schedule"):
        get_schedule("nope")


def test_duplicate_registration_raises_unless_override():
    with pytest.raises(ValueError, match="already registered"):
        @register_schedule("vote_psum")
        class Clash:
            name = "vote_psum"

            def aggregate(self, ctx, g, policy, ef=None):
                return g, ef

    # override=True replaces and can be restored
    original = get_schedule("sign_of_mean")

    @register_schedule("sign_of_mean", override=True)
    class Replacement:
        name = "sign_of_mean"

        def aggregate(self, ctx, g, policy, ef=None):
            return g, ef

    try:
        assert isinstance(get_schedule("sign_of_mean"), Replacement)
    finally:
        register_schedule("sign_of_mean", override=True)(original)
    assert get_schedule("sign_of_mean") is original


# ---------------------------------------------------------------------------
# Fabric.aggregate equivalence with the legacy free functions
# ---------------------------------------------------------------------------

def _mixed_plan(error_feedback: bool = False) -> AdmissionPlan:
    return AdmissionPlan.from_dict(
        {"backbone": GroupPolicy(AggregationMode.G_BINARY,
                                 error_feedback=error_feedback),
         "embed": GroupPolicy(AggregationMode.G_TERNARY)},
        default=GroupPolicy(AggregationMode.FP32))


def _params(rng):
    return {"backbone": {"w1": jnp.asarray(rng.randn(64, 64), jnp.float32),
                         "w2": jnp.asarray(rng.randn(64, 32), jnp.float32)},
            "embed": {"table": jnp.asarray(rng.randn(128, 16), jnp.float32)},
            "head": {"w": jnp.asarray(rng.randn(32, 8), jnp.float32)}}


def test_fabric_aggregate_matches_legacy_bitwise(rng):
    grads = _params(rng)
    plan = _mixed_plan()
    policies = resolve_policies(grads, plan)

    want, want_ef = aggregate_gradients(grads, policies, (), 1)
    got, got_ef = Fabric().aggregate(grads, plan)

    for path in (("backbone", "w1"), ("backbone", "w2"), ("embed", "table"),
                 ("head", "w")):
        w, g = want[path[0]][path[1]], got[path[0]][path[1]]
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    assert want_ef is None and got_ef is None
    # sanity: the three modes actually produced three behaviours
    assert set(np.unique(np.asarray(got["backbone"]["w1"]))) <= {-1.0, 1.0}
    assert 0.0 in np.unique(np.asarray(got["embed"]["table"]))
    np.testing.assert_array_equal(np.asarray(got["head"]["w"]),
                                  np.asarray(grads["head"]["w"]))


def test_fabric_aggregate_matches_legacy_with_error_feedback(rng):
    grads = _params(rng)
    plan = _mixed_plan(error_feedback=True)
    policies = resolve_policies(grads, plan)
    ef = init_ef_states(grads, policies)

    want, want_ef = aggregate_gradients(grads, policies, (), 1, ef_states=ef)
    got, got_ef = Fabric().aggregate(grads, plan, ef=ef)

    np.testing.assert_array_equal(np.asarray(want["backbone"]["w1"]),
                                  np.asarray(got["backbone"]["w1"]))
    np.testing.assert_array_equal(np.asarray(want_ef["backbone"]["w1"]),
                                  np.asarray(got_ef["backbone"]["w1"]))
    assert got_ef["backbone"]["w1"].shape == (1, 64, 64)
    assert got_ef["head"]["w"].shape == ()           # sentinel untouched
    assert float(jnp.sum(jnp.abs(got_ef["backbone"]["w1"]))) > 0


def test_fabric_resolve_and_aggregate_accept_policy_tree(rng):
    grads = _params(rng)
    fabric = Fabric()
    policies = fabric.resolve(grads, _mixed_plan())
    via_plan, _ = fabric.aggregate(grads, _mixed_plan())
    via_tree, _ = fabric.aggregate(grads, policies)
    np.testing.assert_array_equal(np.asarray(via_plan["backbone"]["w1"]),
                                  np.asarray(via_tree["backbone"]["w1"]))


def test_fabric_ef_specs_single_implementation(rng):
    from jax.sharding import PartitionSpec as P
    params = _params(rng)
    fabric = Fabric(dp_axes=("pod", "data"), num_workers=4)
    policies = fabric.resolve(params, _mixed_plan(error_feedback=True))
    pspecs = jax.tree.map(lambda _: None, params)
    specs = fabric.ef_specs(policies, pspecs)
    assert specs["backbone"]["w1"] == P(("pod", "data"))   # EF on: DP-sharded
    assert specs["head"]["w"] == P()                       # EF off: sentinel
    ef = fabric.init_ef(params, policies)
    assert ef["backbone"]["w1"].shape == (4, 64, 64)       # leading W dim
    assert ef["head"]["w"].shape == ()


def test_wire_schedule_bypass_only_for_lowbit_only_schedules(rng):
    """FP32 buckets on vote_psum/packed_a2a ride psum; FP32 buckets on a
    named backend (e.g. the sign_of_mean baseline) dispatch as named."""
    from repro.core import wire_schedule
    assert wire_schedule(AggregationMode.FP32, Schedule.PACKED_A2A) \
        == Schedule.PSUM
    assert wire_schedule(AggregationMode.FP32, Schedule.VOTE_PSUM) \
        == Schedule.PSUM
    assert wire_schedule(AggregationMode.FP32, "sign_of_mean") \
        == "sign_of_mean"
    assert wire_schedule(AggregationMode.G_BINARY, Schedule.PACKED_A2A) \
        == Schedule.PACKED_A2A

    # a low-bit mode nominally on psum rides the dense vote path, exactly
    # as the pre-registry dispatch did — never the FP32 mean
    assert wire_schedule(AggregationMode.G_BINARY, Schedule.PSUM) \
        == Schedule.VOTE_PSUM

    g = {"backbone": {"w": jnp.asarray(rng.randn(64), jnp.float32)}}
    plan = AdmissionPlan.lowbit_all(AggregationMode.FP32,
                                    schedule="sign_of_mean")
    agg, _ = Fabric().aggregate(g, plan)
    np.testing.assert_array_equal(np.asarray(agg["backbone"]["w"]),
                                  np.sign(np.asarray(g["backbone"]["w"])))

    lb_plan = AdmissionPlan.lowbit_all(AggregationMode.G_BINARY,
                                       schedule=Schedule.PSUM)
    lb_agg, _ = Fabric().aggregate(g, lb_plan)
    np.testing.assert_array_equal(np.asarray(lb_agg["backbone"]["w"]),
                                  np.sign(np.asarray(g["backbone"]["w"])))


def test_alias_clash_leaves_registry_unchanged():
    """A clash on any alias must not half-register the earlier names."""
    with pytest.raises(ValueError, match="already registered"):
        @register_schedule("toy_fresh_name", "vote_psum")
        class Clash:
            name = "toy_fresh_name"

            def aggregate(self, ctx, g, policy, ef=None):
                return g, ef

    assert "toy_fresh_name" not in available_schedules()


# ---------------------------------------------------------------------------
# wire-byte accounting through backends
# ---------------------------------------------------------------------------

def test_wire_bytes_resolve_through_registry():
    n, w = 1 << 20, 8
    assert (wire_bytes_per_device(n, AggregationMode.G_BINARY,
                                  "majority_sign_sgd", w)
            == wire_bytes_per_device(n, AggregationMode.G_BINARY,
                                     Schedule.VOTE_PSUM, w))

    @register_schedule("toy_no_wire_model")
    class NoWire:
        name = "toy_no_wire_model"

        def aggregate(self, ctx, g, policy, ef=None):
            return g, ef

    try:
        with pytest.raises(ValueError, match="wire-byte model"):
            wire_bytes_per_device(n, AggregationMode.G_BINARY,
                                  "toy_no_wire_model", w)
    finally:
        unregister_schedule("toy_no_wire_model")


# ---------------------------------------------------------------------------
# step-builder fixes: grad_accum divisibility + optimizer introspection
# ---------------------------------------------------------------------------

def test_split_microbatches_raises_on_indivisible_batch():
    """The old reshape silently dropped trailing samples when grad_accum
    did not divide the per-device batch; it must raise at trace time."""
    from repro.fabric.session import _split_microbatches

    batch = {"x": jnp.zeros((8, 4)), "y": jnp.zeros((8,))}
    micro = _split_microbatches(batch, 4)
    assert micro["x"].shape == (4, 2, 4) and micro["y"].shape == (4, 2)

    bad = {"x": jnp.zeros((10, 4)), "y": jnp.zeros((10,))}
    with pytest.raises(ValueError, match=r"grad_accum=4 must divide"):
        _split_microbatches(bad, 4)
    # the error names the offending shape
    with pytest.raises(ValueError, match=r"\(10, 4\)"):
        _split_microbatches(bad, 4)


def test_opt_shardings_detect_nu_by_state_not_class_name():
    """AdamW subclasses / custom adaptive optimizers must get a nu
    sharding tree; SGD-family must not — detected from the actual init
    state, never the class name."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.fabric.session import _opt_shardings, _optimizer_has_nu
    from repro.optim import AdamW, SgdMomentum
    from repro.optim.optimizers import OptState

    class RenamedAdamW(AdamW):          # name check would miss this
        pass

    class DuckAdaptive:                 # no Optimizer base at all
        def init(self, params):
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape), params)
            return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                            nu=zeros)

    assert AdamW().has_nu and RenamedAdamW().has_nu
    assert not SgdMomentum().has_nu
    assert _optimizer_has_nu(DuckAdaptive())

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    mu_sh = {"w": NamedSharding(mesh, P())}
    assert _opt_shardings(RenamedAdamW(), mu_sh, mesh).nu is mu_sh
    assert _opt_shardings(SgdMomentum(), mu_sh, mesh).nu is None


# ---------------------------------------------------------------------------
# the extension seam: custom schedules train without touching core files
# ---------------------------------------------------------------------------

def test_custom_schedule_trains_one_step(rng):
    """A toy registered schedule drives one full training step.

    The backend scales the mean gradient — distinguishable bit-for-bit
    from every built-in — and is selected purely by name through the
    plan, proving admission -> policy -> registry dispatch needs no core
    edits.
    """
    @register_schedule("toy_halfmean")
    class HalfMean:
        name = "toy_halfmean"

        def aggregate(self, ctx, g, policy, ef=None):
            return 0.5 * jax.lax.pmean(g.astype(jnp.float32),
                                       ctx.dp_axes).astype(g.dtype), ef

    try:
        fabric = Fabric()
        plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                             schedule="toy_halfmean")
        assert "toy_halfmean" in plan.signature()

        params = {"backbone": {"w": jnp.asarray(rng.randn(16, 4),
                                                jnp.float32)},
                  "head": {"w": jnp.asarray(rng.randn(4, 2), jnp.float32)}}
        x = jnp.asarray(rng.randn(32, 16), jnp.float32)

        def loss_fn(p):
            h = jnp.tanh(x @ p["backbone"]["w"])
            return jnp.mean((h @ p["head"]["w"]) ** 2)

        loss0, grads = jax.value_and_grad(loss_fn)(params)
        agg, _ = fabric.aggregate(grads, plan)
        # custom backend applied to the backbone, FP32 psum to the head
        np.testing.assert_array_equal(np.asarray(agg["backbone"]["w"]),
                                      0.5 * np.asarray(grads["backbone"]["w"]))
        np.testing.assert_array_equal(np.asarray(agg["head"]["w"]),
                                      np.asarray(grads["head"]["w"]))
        new_params = jax.tree.map(lambda p, a: p - 0.1 * a, params, agg)
        assert float(loss_fn(new_params)) < float(loss0)
    finally:
        unregister_schedule("toy_halfmean")


@pytest.mark.slow
@needs_modern_jax
def test_custom_schedule_trains_via_trainer_on_mesh():
    """Full stack: a registered toy schedule drives the Trainer on a real
    (simulated-device) mesh, selected only by its plan name."""
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {SRC!r})

    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.core import AdmissionPlan, AggregationMode
    from repro.data import SyntheticLMStream
    from repro.fabric import Fabric, register_schedule
    from repro.models import ModelConfig
    from repro.optim import SgdMomentum
    from repro.runtime import Trainer, TrainerConfig

    @register_schedule("toy_signmean")
    class SignMean:
        name = "toy_signmean"
        def aggregate(self, ctx, g, policy, ef=None):
            return jnp.sign(jax.lax.pmean(g, ctx.dp_axes)), ef

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", remat=False)
    data = SyntheticLMStream(vocab=256, seq_len=32, batch=16, seed=0)
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY,
                                         schedule="toy_signmean")
    tr = Trainer(cfg, mesh, SgdMomentum(peak_lr=1e-3), data, plan=plan,
                 fabric=Fabric(mesh, dp_axes=("data",)),
                 tcfg=TrainerConfig(dp_axes=("data",), log_interval=1000))
    h = tr.run(2)
    assert len(h) == 2 and "toy_signmean" in h[-1]["plan"]
    print("CUSTOM_SCHEDULE_TRAINED", h[0]["loss"], h[-1]["loss"])
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "CUSTOM_SCHEDULE_TRAINED" in r.stdout
