"""Checkpoint manager: atomicity, retention, resume, corrupted-tmp safety."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, save_checkpoint


def _tree(step):
    return {"params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.zeros((4,))},
            "step": jnp.asarray(step)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 7, _tree(7), extra={"plan": "fp32"})
    step, tree, extra = restore_latest(d, _tree(0))
    assert step == 7
    assert extra["plan"] == "fp32"
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.full((4, 4), 7.0))


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        save_checkpoint(d, s, _tree(s), keep=3)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(dirs) == 3
    step, tree, _ = restore_latest(d, _tree(0))
    assert step == 5


def test_crash_mid_save_leaves_previous_valid(tmp_path):
    """A stale .tmp dir must not shadow the last durable checkpoint."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(3))
    os.makedirs(os.path.join(d, "step_0000000009.tmp"))  # simulated crash
    with open(os.path.join(d, "step_0000000009.tmp", "garbage"), "w") as f:
        f.write("partial")
    step, tree, _ = restore_latest(d, _tree(0))
    assert step == 3


def test_async_manager_fences(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=2, keep=2)
    for s in range(5):
        m.maybe_save(s, _tree(s))
    m.wait()
    step, tree, _ = m.restore(_tree(0))
    assert step == 4
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.full((4, 4), 4.0))


def test_restore_none_when_empty(tmp_path):
    assert restore_latest(str(tmp_path / "nope"), _tree(0)) is None
